"""Fig 9 — speedup & fairness under occupancy imbalance (1:1, 2:1, 4:1).

Paper claim validated: balanced co-tenants get ~unity speedup; imbalanced
pairs let the big kernel monopolize (large speedup) while fairness stays
HIGH (proportional resource allocation) — the paper's counterintuitive
reconciliation."""
import jax

from repro.core import concurrency as cc
from repro.core.characterization import PRECISIONS, Record, _mk, _matmul_fn


def run():
    out = []
    dtype = PRECISIONS["fp32"]
    fn = _matmul_fn(dtype)
    base = 192
    for ratio in (1, 2, 4):
        sizes = [base * ratio, base]
        def mk(i):
            s = sizes[i % 2]
            a = _mk((s, s), dtype, key=i)
            b = _mk((s, s), dtype, key=100 + i)
            return lambda: fn(a, b)
        rep = cc.characterize_streams(mk, 2, mode="async")
        out.append(Record(
            name=f"fig9/occupancy_ratio={ratio}:1",
            us_per_call=rep.wall_s * 1e6,
            derived={"speedup": round(rep.speedup, 3),
                     "fairness": round(rep.fairness, 4),
                     "fairness_min_max": round(rep.fairness_min_max, 4),
                     "ratio": ratio}))
    return out
