"""Fig 4 — speedup vs number of concurrent streams (512^3-equivalent GEMM).

Paper claim validated: async execution raises aggregate throughput
(speedups > 1 as streams increase) while per-stream progress diverges —
fairness/CV are reported by fig5."""
import jax
import jax.numpy as jnp

from repro.core import concurrency as cc
from repro.core.characterization import PRECISIONS, _mk, _matmul_fn


def run():
    out = []
    S = 256
    for prec in ("fp32", "bf16", "fp8"):
        dtype = PRECISIONS[prec]
        fn = _matmul_fn(dtype)
        b = _mk((S, S), dtype, 1)
        for ns in (1, 2, 4, 8):
            def mk(i):
                a = _mk((S, S), dtype, key=i)
                return lambda: fn(a, b)
            rep = cc.characterize_streams(mk, ns, mode="async")
            # one shared schema: StreamReport.to_record carries the full
            # report (speedup/overlap_efficiency/fairness/cv/per_stream_s
            # + the legacy_timing note) through the same Record dict that
            # autotune.dump_records/load_records and
            # AutotuneStore.add_records consume
            out.append(rep.to_record(f"fig4/{prec}/streams={ns}",
                                     streams=ns, precision=prec))
    return out
