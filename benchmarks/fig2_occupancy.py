"""Fig 2 — throughput vs grid parallelism (occupancy) per precision.

Paper claim validated: throughput scales sublinearly and every precision
needs a minimum parallelism to approach steady state; the lowest-precision
format needs the MOST parallelism to saturate (FP8 ≥ 256 wavefronts on
MI300A; here, FP8's normalized curve lags bf16's at small tile counts
because the MXU drains fp8 tiles faster than HBM refills them).

Side effect: the per-(precision, tiles) throughput samples are persisted
through the autotune store and the FP8-demotion occupancy threshold is
re-calibrated from them — the Fig-2 measurement *is* the evidence the
online policy loop runs on.
"""
from repro.core import autotune
from repro.core.characterization import occupancy_sweep, occupancy_threshold
from repro.core.characterization import Record


def persist(records):
    """Record samples + recalibrate thresholds in the persistent artifact
    (best-effort: a read-only dir or corrupt artifact must not fail the
    benchmark)."""
    try:
        store = autotune.AutotuneStore()
        store.load()
        n = store.add_records(records)
        store.calibrate()
        store.save()
        return n
    except Exception as e:  # noqa: BLE001 — persistence is advisory
        print(f"# fig2: autotune persist skipped ({type(e).__name__}: {e})")
        return 0


def run():
    recs = occupancy_sweep(tile_counts=(1, 2, 4, 8, 16),
                           tile_m=128, k=256, n=256,
                           precisions=("fp32", "bf16", "fp8"), iters=3)
    th = occupancy_threshold(recs, frac=0.9)
    persist(recs)
    recs.append(Record(
        name="fig2/threshold_tiles_to_90pct",
        us_per_call=0.0,
        derived={f"{p}_tiles": t for p, t in th.items()}))
    return recs
