"""Fig 14 — transformer-style FP8 inference kernel: throughput vs dimension.

Paper claim validated: small problem sizes underutilize the matrix units;
throughput (normalized to best) peaks at moderate dimensions. Uses the
paper-transformer case-study config end to end (§8.1)."""
import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.configs import PAPER_TRANSFORMER
from repro.core.characterization import Record
from repro.models import forward, init_params
from repro.models.layers import RuntimeCfg


def run():
    out = []
    rt = RuntimeCfg(chunk_q=64, chunk_kv=64, ssm_chunk=32)
    raw = []
    for d in (128, 256, 512):
        cfg = dataclasses.replace(
            PAPER_TRANSFORMER, d_model=d, d_ff=4 * d,
            num_heads=max(d // 64, 1), num_kv_heads=max(d // 64, 1),
            head_dim=64, num_layers=2, vocab_size=1024)
        params = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                  cfg.vocab_size)
        fwd = jax.jit(lambda p, t, c=cfg: forward(p, t, c, rt)[0])
        dt = time_fn(fwd, params, toks, iters=3)
        flops = 2 * cfg.param_count() * 2 * 64
        raw.append((d, dt, flops / dt))
    best = max(r[2] for r in raw)
    for d, dt, gf in raw:
        out.append(Record(
            name=f"fig14/fp8_transformer/d={d}",
            us_per_call=dt * 1e6,
            derived={"norm_to_best": round(gf / best, 4), "d_model": d}))
    return out
