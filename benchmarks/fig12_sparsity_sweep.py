"""Fig 12 — speedup heatmap across (size x aspect x pattern).

Paper claim validated: the whole isolated parameter space sits near 1.0x
(break-even) — no size/shape/pattern escapes the overhead bound."""
import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import sparsity as sp
from repro.core.characterization import Record


def _dense(x, w):
    return jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def run():
    out = []
    for k in (256, 512):
        for ratio in (0.5, 1.0, 2.0):
            m = max(int(k * ratio) // 8 * 8, 64)
            key = jax.random.PRNGKey(0)
            x = jax.random.normal(key, (m, k), jnp.float32)
            w24 = sp.prune_24(
                jax.random.normal(jax.random.PRNGKey(1), (k, k), jnp.float32))
            vals, meta = sp.pack_24(w24)
            dt_dense = time_fn(jax.jit(_dense), x, w24, iters=3)
            sparse = jax.jit(lambda x, v, mm: sp.sparse24_matmul_ref(
                x, v, mm, out_dtype=jnp.float32))
            dt_sparse = time_fn(sparse, x, vals, meta, iters=3)
            out.append(Record(
                name=f"fig12/k={k}/ratio={ratio}",
                us_per_call=dt_sparse * 1e6,
                derived={"speedup": round(dt_dense / dt_sparse, 3),
                         "k": k, "ratio": ratio}))
    return out
