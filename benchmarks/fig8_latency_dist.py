"""Fig 8 — per-stream kernel latency distribution across stream counts.

Paper claim validated: single-stream latencies are tight; at 4 streams some
streams take 2–3x longer (hardware contention, not scheduler unfairness)."""
import numpy as np

from repro.core import concurrency as cc
from repro.core.characterization import PRECISIONS, Record, _mk, _matmul_fn


def run():
    out = []
    fn = _matmul_fn(PRECISIONS["fp32"])
    b = _mk((256, 256), PRECISIONS["fp32"], 1)
    for ns in (1, 2, 4):
        def mk(i):
            a = _mk((256, 256), PRECISIONS["fp32"], key=i)
            return lambda: fn(a, b)
        rep = cc.characterize_streams(mk, ns, mode="async")
        t = np.asarray(rep.per_stream_s)
        out.append(Record(
            name=f"fig8/streams={ns}",
            us_per_call=float(t.mean()) * 1e6,
            derived={"p0_us": round(float(t.min()) * 1e6, 1),
                     "p100_us": round(float(t.max()) * 1e6, 1),
                     "max_over_min": round(float(t.max() / t.min()), 2),
                     "streams": ns}))
    return out
