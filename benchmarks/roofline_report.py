"""Roofline summary — reads the dry-run artifacts (launch/dryrun.py --all)
and emits one record per (arch x shape) single-pod cell with the three terms
and the bottleneck. This is the §Roofline data path."""
import json
import os

from repro.core.characterization import Record

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts",
                         "dryrun.jsonl")


def run():
    out = []
    if not os.path.exists(ARTIFACTS):
        out.append(Record(name="roofline/missing", us_per_call=0.0,
                          derived={"hint": "run python -m repro.launch.dryrun --all"}))
        return out
    best = {}
    for line in open(ARTIFACTS):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if not r.get("ok") or r.get("mesh") != "single":
            continue
        if "roofline" not in r:
            continue
        best[(r["arch"], r["shape"])] = r
    for (arch, shape), r in sorted(best.items()):
        roof = r["roofline"]
        out.append(Record(
            name=f"roofline/{arch}/{shape}",
            us_per_call=roof["step_s"] * 1e6,
            derived={"compute_s": round(roof["compute_s"], 5),
                     "memory_s": round(roof["memory_s"], 5),
                     "collective_s": round(roof["collective_s"], 5),
                     "bottleneck": roof["bottleneck"],
                     "roofline_fraction": round(roof["roofline_fraction"], 4),
                     "useful_flops_ratio":
                         round(roof["useful_flops_ratio"], 4),
                     "mem_GiB": round(
                         r["memory"]["per_device_total"] / 2 ** 30, 2)}))
    return out
