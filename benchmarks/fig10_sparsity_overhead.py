"""Fig 10 — sparsity encoding overhead vs matrix size.

Paper claim validated: the encoding/dispatch overhead is ~CONSTANT across
problem sizes (3.5–5.8 µs on MI300A via rocSPARSE), so it cannot amortize.
Here the measured quantity is pack_24 (prune+compress) overhead plus the
per-call dispatch delta of the packed kernel vs a plain call."""
import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import sparsity as sp
from repro.core.characterization import Record


def run():
    out = []
    pack = jax.jit(lambda w: sp.pack_24(sp.prune_24(w)))
    for k in (256, 512, 1024):
        w = jax.random.normal(jax.random.PRNGKey(0), (k, k), jnp.float32)
        dt_pack = time_fn(pack, w, iters=3)
        out.append(Record(
            name=f"fig10/pack_overhead/{k}x{k}",
            us_per_call=dt_pack * 1e6,
            derived={"k": k,
                     "bytes_ratio_vs_bf16":
                         round(sp.packed_bytes(k, k, jnp.float8_e4m3fn)
                               / sp.dense_bytes(k, k), 4)}))
    return out
