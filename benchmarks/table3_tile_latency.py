"""Table 3 — dependency-chained per-tile-shape latency.

Paper methodology: chain each matmul's output into the next so the
measurement isolates single-issue latency, not pipelined throughput. The
paper's MFMA tile shapes (16x16x32 etc.) map to MXU-granularity block
shapes; the signature finding — larger tiles pay a latency premium and the
"preferred" shape is precision-dependent — reproduces as block-shape
sensitivity.

Side effects: the measured records are folded into the execution layer's
block-shape autotune cache (core/execution.BLOCK_CACHE) for this process,
AND persisted through the autotune store (core/autotune.AutotuneStore),
so one benchmark run permanently improves every later policy lookup that
loads the artifact.

Beyond the per-shape probe, the run sweeps 2–3 *alternative block
tilings* per (shape, precision) through the Pallas kernel path
(``block_sweep_probe``): the winning tiling — not a clamped prior — is
what the cache and artifact keep for those shapes.
"""
from repro.core import autotune
from repro.core.characterization import block_sweep_probe, latency_probe
from repro.core.execution import seed_cache_from_records


def persist(records):
    """Fold records into the persistent autotune artifact (best-effort: a
    read-only dir or corrupt artifact must not fail the benchmark)."""
    try:
        store = autotune.AutotuneStore()
        store.load()
        n = store.add_records(records)
        store.save()
        return n
    except Exception as e:  # noqa: BLE001 — persistence is advisory
        print(f"# table3: autotune persist skipped "
              f"({type(e).__name__}: {e})")
        return 0


def run():
    records = latency_probe(
        tile_shapes=((128, 128, 128), (256, 256, 128), (128, 128, 256),
                     (256, 256, 256)),
        precisions=("fp32", "bf16", "fp8"), chain=8, iters=3)
    records += block_sweep_probe(
        shapes=((256, 256, 256), (128, 256, 512)),
        precisions=("bf16", "fp8"), iters=2)
    seed_cache_from_records(records)
    persist(records)
    return records
