"""Table 3 — dependency-chained per-tile-shape latency.

Paper methodology: chain each matmul's output into the next so the
measurement isolates single-issue latency, not pipelined throughput. The
paper's MFMA tile shapes (16x16x32 etc.) map to MXU-granularity block
shapes; the signature finding — larger tiles pay a latency premium and the
"preferred" shape is precision-dependent — reproduces as block-shape
sensitivity.

Side effect: the measured records are folded into the execution layer's
block-shape autotune cache (core/execution.BLOCK_CACHE), so running this
benchmark refines the Table-3-seeded defaults every later policy lookup
uses.
"""
from repro.core.characterization import latency_probe
from repro.core.execution import seed_cache_from_records


def run():
    records = latency_probe(
        tile_shapes=((128, 128, 128), (256, 256, 128), (128, 128, 256),
                     (256, 256, 256)),
        precisions=("fp32", "bf16", "fp8"), chain=8, iters=3)
    seed_cache_from_records(records)
    return records
