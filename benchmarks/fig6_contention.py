"""Fig 6–7 — contention vs stream count for thin/medium/thick kernels.

Paper reads L2-miss/LDS counters; the portable observable is per-stream
dilation (concurrent / isolated time): thin kernels dilate least, thick
kernels most — the same working-set-pressure signature."""
from repro.core.characterization import contention_sweep


def run():
    return contention_sweep(sizes={"thin": 128, "medium": 256, "thick": 384},
                            stream_counts=(1, 2, 4), iters=3)
