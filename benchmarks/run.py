"""Run every benchmark (one per paper table/figure) and print
``name,us_per_call,derived`` CSV. ``--only fig2`` filters. ``--out DIR``
additionally writes each figure's records as JSON (via the autotune
store's serializer) so bench trajectories stay machine-readable across
PRs.

``--backend ref,jnp,pallas`` re-runs the selected figures once per named
matmul backend (kernels/registry.py); record names are prefixed with the
backend. The GEMMs in the characterization sweeps (fig2-9, table3, fig16)
and the model-level figures (fig14, fig15, fig17, fig18) route through the
execution-policy layer, so one flag sweeps them across substrates. The
sparsity-primitive figures (fig10-13) measure pack/prune/ref kernels
directly and do not vary by backend (see EXPERIMENTS.md). ``--policy``
pins a full execution policy (e.g. ``fp8:sparse24:pallas``) instead.
"""
import argparse
import importlib
import sys
import time

MODULES = [
    "fig2_occupancy",
    "fig3_shape",
    "table3_tile_latency",
    "fig4_concurrency",
    "fig5_fairness",
    "fig6_contention",
    "fig8_latency_dist",
    "fig9_imbalance",
    "fig10_sparsity_overhead",
    "fig11_sparsity_speedup",
    "fig12_sparsity_sweep",
    "fig13_sparsity_contention",
    "fig14_transformer",
    "fig15_concurrent_fp8",
    "fig16_mixed_precision",
    "fig17_serving_fairness",
    "fig18_partitioned_serving",
    "fig19_migration",
    "fig20_paged_serving",
    "fig21_async_overlap",
    "fig22_speculative",
    "fig23_slo_control",
    "roofline_report",
]


def _run_modules(only, tag: str, out_dir=None) -> int:
    failures = 0
    prefix = f"{tag}/" if tag else ""
    for name in MODULES:
        if only and only not in name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            records = list(mod.run())
            for rec in records:
                print(f"{prefix}{rec.csv()}" if prefix else rec.csv())
            if out_dir:
                from repro.core import autotune
                import os
                stem = f"{tag.replace(':', '_').replace('/', '_')}__{name}" \
                    if tag else name
                path = autotune.dump_records(
                    records, os.path.join(out_dir, f"{stem}.json"))
                print(f"# {prefix}{name}: records -> {path}",
                      file=sys.stderr)
            print(f"# {prefix}{name}: ok in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"{prefix}{name}/ERROR,0.0,error={type(e).__name__}:{e}")
            print(f"# {prefix}{name}: FAILED {e}", file=sys.stderr)
    return failures


def main() -> None:
    from repro.core import execution as ex

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    ap.add_argument("--backend", default=None,
                    help="comma-separated registry backends to sweep "
                         "(ref,jnp,pallas,pallas_sparse24); each selected "
                         "figure runs once per backend")
    ap.add_argument("--policy", default=None,
                    help="execution-policy spec pinned for the whole run, "
                         "e.g. 'fp8:sparse24:pallas' (exclusive with "
                         "--backend sweeps)")
    ap.add_argument("--out", default=None, metavar="DIR",
                    help="also write each figure's records as JSON under "
                         "DIR (one file per figure, per backend/policy "
                         "tag)")
    args = ap.parse_args()
    if args.policy and args.backend:
        ap.error("--policy and --backend are mutually exclusive: a policy "
                 "names its own backend (add it to the spec, e.g. "
                 "'fp8:dense:pallas')")

    print("name,us_per_call,derived")
    failures = 0
    if args.policy:
        ex.set_default_policy(ex.parse_policy(args.policy))
        failures += _run_modules(args.only, args.policy, args.out)
    elif args.backend:
        backends = [b.strip() for b in args.backend.split(",") if b.strip()]
        for b in backends:
            ex.set_default_backend(b)
            failures += _run_modules(args.only, b, args.out)
    else:
        failures += _run_modules(args.only, "", args.out)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
