"""Run every benchmark (one per paper table/figure) and print
``name,us_per_call,derived`` CSV. ``--only fig2`` filters."""
import argparse
import importlib
import sys
import time

MODULES = [
    "fig2_occupancy",
    "fig3_shape",
    "table3_tile_latency",
    "fig4_concurrency",
    "fig5_fairness",
    "fig6_contention",
    "fig8_latency_dist",
    "fig9_imbalance",
    "fig10_sparsity_overhead",
    "fig11_sparsity_speedup",
    "fig12_sparsity_sweep",
    "fig13_sparsity_contention",
    "fig14_transformer",
    "fig15_concurrent_fp8",
    "fig16_mixed_precision",
    "roofline_report",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for rec in mod.run():
                print(rec.csv())
            print(f"# {name}: ok in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"{name}/ERROR,0.0,error={type(e).__name__}:{e}")
            print(f"# {name}: FAILED {e}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
