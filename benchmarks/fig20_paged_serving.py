"""Fig 20 (beyond-paper) — paged serving cache: density, latency, handoff.

The dense serving cache pins ``slots × max_len`` KV rows per layer at
session construction, so resident tenants per partition are capped by
slot count regardless of how little of each slot is written. The paged
cache (core/paging.py + the paged decode path) allocates fixed-size pages
lazily from a shared pool, which converts the same HBM budget into
pages-in-use — short requests stop paying for ``max_len``.

Three studies, dense vs paged at FIXED cache memory (the dense baseline's
``slots × max_len`` token capacity == the paged pool's ``pages ×
page_size``):

* **density** — identical request mix through both layouts; the paged
  session admits by free-*page* headroom and holds ≥4× the concurrent
  residents (the acceptance bar). Greedy outputs are asserted
  token-for-token identical — paging is a memory-layout change, not a
  numerics change.
* **decode latency** — per-step wall time (mean + p99) for both layouts.
* **migration handoff** — a mid-request export/import at growing decode
  depths: dense handoffs move the full ``max_len`` slice no matter what;
  paged handoffs move pages-in-use, so bytes scale with progress.

Results persist to ``BENCH_fig20.json`` at the repo root — the first
``BENCH_*`` perf-trajectory file (ROADMAP) future CI can gate on. The
paged flash-decode tiling sweep (``pagedsweep/...`` records,
kernels/paged_attention.py) rides along so the Table-3 evidence path
ingests the kernel's page geometries.
"""
import json
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import stamp
from repro.configs import get_reduced
from repro.core.characterization import Record
from repro.core.concurrency import fairness
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime.serve_loop import (
    Request, ServeSession, export_nbytes)

RT = RuntimeCfg(ssm_chunk=16)
MAX_LEN = 64
PAGE = 8                             # tokens per page -> 8 pages per slot
DENSE_SLOTS = 2                      # the fixed-memory baseline
POOL_PAGES = DENSE_SLOTS * (MAX_LEN // PAGE)   # same token capacity
N_REQ = 8
PROMPT_LEN = 4
MAX_NEW = 8                          # ~12 written positions -> 2 pages

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_fig20.json"

_MODEL = None


def _model():
    global _MODEL
    if _MODEL is None:
        cfg = get_reduced("llama3-8b")
        _MODEL = (cfg, init_params(jax.random.PRNGKey(0), cfg))
    return _MODEL


def _requests(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=uid, tenant=f"t{uid}",
                    prompt=rng.integers(0, cfg.vocab_size,
                                        PROMPT_LEN).astype(np.int32),
                    max_new=MAX_NEW)
            for uid in range(N_REQ)]


def _session(paged: bool, slots: int) -> ServeSession:
    cfg, params = _model()
    kw = dict(paged=True, page_size=PAGE, pages=POOL_PAGES) if paged else {}
    return ServeSession(params, cfg, batch_slots=slots, max_len=MAX_LEN,
                        rt=RT, **kw)


def _drive(sess, requests):
    """submit-all + drain, tracking peak residents and per-step wall."""
    for r in requests:
        sess.submit(r)
    peak, walls, steps = 0, [], 0
    # warm the decode step outside the timed region (compile once)
    while (sess.queue or sess.n_active) and steps < 10_000:
        sess._admit_from_queue()
        peak = max(peak, sess.n_active)
        t0 = time.perf_counter()
        sess.decode_once()
        walls.append(time.perf_counter() - t0)
        steps += 1
    toks = sum(len(r.out) for r in requests)
    # drop the first (compile-bearing) step from the latency stats
    lat = np.asarray(walls[1:] or walls)
    per_tenant = {r.tenant: len(r.out) for r in requests}
    return {
        "resident_peak": peak,
        "steps": steps,
        "tokens": toks,
        "tokens_per_step": round(toks / max(steps, 1), 3),
        "mean_step_us": round(float(lat.mean()) * 1e6, 1),
        "p99_step_us": round(float(np.percentile(lat, 99)) * 1e6, 1),
        "fairness": round(fairness(list(per_tenant.values())), 4),
    }


def _density():
    cfg, _ = _model()
    dense_reqs = _requests(cfg)
    paged_reqs = _requests(cfg)
    d = _drive(_session(False, DENSE_SLOTS), dense_reqs)
    # paged: one slot per potential resident (slot bookkeeping is host-side
    # metadata; PAGES is the memory), same pool capacity as the dense cache
    p = _drive(_session(True, N_REQ * 2), paged_reqs)
    assert [r.out for r in dense_reqs] == [r.out for r in paged_reqs], \
        "paged greedy decode diverged from dense"
    d["cache_tokens"] = DENSE_SLOTS * MAX_LEN
    p["cache_tokens"] = POOL_PAGES * PAGE
    p["page_size"], p["pages"] = PAGE, POOL_PAGES
    return d, p


def _handoff():
    """Export/import one in-flight request at several decode depths."""
    cfg, _ = _model()
    rows = []
    for paged in (False, True):
        for depth in (2, 6, 14):     # decoded tokens before the handoff
            src = _session(paged, DENSE_SLOTS)
            dst = _session(paged, DENSE_SLOTS)
            req = Request(uid=0, prompt=_requests(cfg)[0].prompt.copy(),
                          max_new=MAX_NEW + 16)
            src.admit(req)
            for _ in range(depth):
                src.decode_once()
            t0 = time.perf_counter()
            export = src.export_slot(0)
            dst.import_slot(export)
            wall = time.perf_counter() - t0
            rows.append({
                "layout": "paged" if paged else "dense",
                "tokens_at_handoff": len(req.out),
                "pages_moved": export.pages,
                "handoff_bytes": export_nbytes(export),
                "wall_us": round(wall * 1e6, 1),
            })
    return rows


def run():
    dense, paged = _density()
    handoff = _handoff()

    records = [
        Record(name="fig20/density/dense", us_per_call=dense["mean_step_us"],
               derived=dense),
        Record(name="fig20/density/paged", us_per_call=paged["mean_step_us"],
               derived=paged),
    ]
    for row in handoff:
        records.append(Record(
            name=(f"fig20/handoff/{row['layout']}/"
                  f"t{row['tokens_at_handoff']}"),
            us_per_call=row["wall_us"], derived=row))

    # paged flash-decode kernel page-geometry sweep -> autotune evidence
    from repro.kernels.paged_attention import sweep_paged_tilings
    sweep = sweep_paged_tilings(batch=DENSE_SLOTS, seq=MAX_LEN,
                                head_dim=_model()[0].head_dim,
                                kv_heads=_model()[0].num_kv_heads,
                                heads=_model()[0].num_heads)
    records.extend(sweep)

    summary = {
        "figure": "fig20_paged_serving",
        "density_ratio": round(paged["resident_peak"]
                               / max(dense["resident_peak"], 1), 2),
        "dense": dense,
        "paged": paged,
        "handoff": handoff,
        "pages_moved": sum(r["pages_moved"] for r in handoff),
        "pagedsweep": [{"name": r.name, "us_per_call": round(r.us_per_call, 2)}
                       for r in sweep],
    }
    stamp(summary, "fig20_paged_serving")
    BENCH_PATH.write_text(json.dumps(summary, indent=2) + "\n")
    return records


if __name__ == "__main__":
    for rec in run():
        print(rec.csv())
    print(f"[fig20] wrote {BENCH_PATH}")
