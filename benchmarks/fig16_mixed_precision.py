"""Fig 16 — per-operation execution time by precision (mixed workload).

Paper claim validated: FP8 ops gain more from batching/occupancy than FP32;
mixed pipelines need precision-aware scheduling. Measures the same GEMM in
fp32/bf16/fp8 at two batch sizes and reports the batching benefit ratio per
precision."""
import jax

from benchmarks.common import time_fn
from repro.core.characterization import PRECISIONS, Record, _matmul_fn, _mk


def run():
    out = []
    k = 256
    for prec in ("fp32", "bf16", "fp8"):
        dtype = PRECISIONS[prec]
        fn = _matmul_fn(dtype)
        b = _mk((k, k), dtype, 1)
        times = {}
        for m in (64, 512):
            a = _mk((m, k), dtype)
            times[m] = time_fn(fn, a, b, iters=3)
        # throughput ratio per unit work: >1 means batching helps
        benefit = (times[64] / 64) / (times[512] / 512)
        out.append(Record(
            name=f"fig16/{prec}",
            us_per_call=times[512] * 1e6,
            derived={"batching_benefit": round(float(benefit), 3),
                     "t64_us": round(times[64] * 1e6, 1),
                     "t512_us": round(times[512] * 1e6, 1),
                     "precision": prec}))
    return out
