"""Fig 3 — absolute throughput vs matrix aspect ratio at fixed total work.

Paper claim validated: shape sensitivity is precision-dependent; non-square
configurations reduce effective tile utilization (up to 16% at 4:1 for FP8
on MI300A; TPU analogue is 128-alignment of the M/N dims on the MXU)."""
from repro.core.characterization import shape_sweep


def run():
    return shape_sweep(total_mn=512 * 512, k=256,
                       ratios=(0.25, 0.5, 1.0, 2.0, 4.0),
                       precisions=("fp32", "bf16", "fp8"), iters=3)
