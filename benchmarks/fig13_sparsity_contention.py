"""Fig 13 — sparsity under resource contention / memory-boundedness.

Paper claim (adapted): on MI300A the 2:4 win appears under concurrency
(1.3x + fairness). On TPU the same context-dependence appears where the
kernel is WEIGHT-BANDWIDTH-BOUND: the packed representation moves 0.3125x
the bytes of dense bf16. The memory-bound proxy here is a batch-1 matvec
(decode shape): bytes dominate, so the byte ratio is the speedup bound;
we report measured time plus the analytic bound."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.core import concurrency as cc
from repro.core import sparsity as sp
from repro.core.characterization import Record


def run():
    out = []
    k = 512
    w24 = sp.prune_24(
        jax.random.normal(jax.random.PRNGKey(1), (k, k), jnp.float32)
        .astype(jnp.bfloat16))
    vals, meta = sp.pack_24(w24)
    vals8 = vals.astype(jnp.float8_e4m3fn)
    x1 = jax.random.normal(jax.random.PRNGKey(0), (1, k), jnp.float32) \
        .astype(jnp.bfloat16)

    dense = jax.jit(lambda x, w: jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32))
    sparse = jax.jit(lambda x, v, m: sp.sparse24_matmul_ref(
        x, v, m, out_dtype=jnp.float32))

    dt_dense = time_fn(dense, x1, w24, iters=5)
    dt_sparse = time_fn(sparse, x1, vals8, meta, iters=5)
    bytes_dense = sp.dense_bytes(k, k)
    bytes_packed = sp.packed_bytes(k, k, jnp.float8_e4m3fn)
    out.append(Record(
        name="fig13/decode_matvec",
        us_per_call=dt_sparse * 1e6,
        derived={"measured_speedup": round(dt_dense / dt_sparse, 3),
                 "bw_bound_speedup": round(bytes_dense / bytes_packed, 3),
                 "bytes_dense": bytes_dense, "bytes_packed": bytes_packed}))

    # fairness under concurrent sparse vs dense streams (paper fig 13a)
    for kind, thunk in (("dense", lambda i: (lambda: dense(x1, w24))),
                        ("sparse", lambda i: (lambda: sparse(x1, vals8, meta)))):
        rep = cc.characterize_streams(thunk, 4, mode="async")
        out.append(Record(
            name=f"fig13/fairness/{kind}",
            us_per_call=rep.wall_s * 1e6,
            derived={"fairness_min_max": round(rep.fairness_min_max, 4),
                     "speedup": round(rep.speedup, 3)}))
    return out
