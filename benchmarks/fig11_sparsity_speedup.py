"""Fig 11 — sparse vs dense speedup across matrix sizes (isolation).

Paper claim validated (TPU form): in isolated COMPUTE-BOUND execution the
packed 2:4 matmul is ~break-even (FLOPs are unchanged on TPU — no sparse
MXU — and decompression adds VPU work), exactly mirroring the paper's
1.0x isolated result. The bandwidth win appears only in the memory-bound
regime (fig13)."""
import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import sparsity as sp
from repro.core.characterization import Record


def _dense(x, w):
    return jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def run():
    out = []
    for k in (256, 512):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (256, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, k), jnp.float32)
        w24 = sp.prune_24(w)
        vals, meta = sp.pack_24(w24)
        dt_dense = time_fn(jax.jit(_dense), x, w, iters=3)
        sparse = jax.jit(lambda x, v, m: sp.sparse24_matmul_ref(
            x, v, m, out_dtype=jnp.float32))
        dt_sparse = time_fn(sparse, x, vals, meta, iters=3)
        out.append(Record(
            name=f"fig11/isolated/{k}^3",
            us_per_call=dt_sparse * 1e6,
            derived={"speedup_vs_dense": round(dt_dense / dt_sparse, 3),
                     "k": k}))
    return out
