"""Fig 17 (beyond-paper) — concurrent-serving fairness sweep.

The Fig-5/Fig-15 result at the application (serving) layer: N tenants of
identical decode workloads share one model through the multi-tenant
StreamScheduler; a shared FIFO queue collapses per-tenant fairness while
the credit-based ``fair_quantum`` admission restores it at the same
aggregate throughput. Overlap efficiency compares against each tenant
served alone (serial), exactly like the raw-matmul stream runs.

Writes ``BENCH_fig17.json`` so ``benchmarks/trajectory.py`` gates the
fair_quantum fairness restoration (the figure's claim) across PRs; the
FIFO collapse and wall percentiles ride along untracked."""
import json
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import stamp
from repro.configs import get_reduced
from repro.core import concurrency as cc
from repro.core.characterization import Record
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime.scheduler import run_tenants
from repro.runtime.serve_loop import Request, ServeSession

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_fig17.json"

N_TENANTS = 4
REQS_PER_TENANT = 2
MAX_NEW = 8
SLOTS = 2
RT = RuntimeCfg(ssm_chunk=16)


def _prompts(cfg):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
            for _ in range(REQS_PER_TENANT)]


def _requests(prompts, tenant):
    return [Request(uid=tenant * 100 + j, prompt=p.copy(), max_new=MAX_NEW)
            for j, p in enumerate(prompts)]


def run():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg)

    def session():
        return ServeSession(params, cfg, batch_slots=SLOTS, max_len=96,
                            rt=RT)

    def solo(t):
        return run_tenants(session(),
                           {f"tenant{t}": _requests(prompts, t)},
                           admission="fifo")

    # untimed warmup pass first: prefill/decode compilation must not land
    # in the serial baseline (the overlap-efficiency denominator) — same
    # bug class as the characterize_streams warm-every-thunk fix
    solo(0)

    # serial baseline: each tenant served alone sums to the no-overlap
    # wall time, the denominator of overlap efficiency
    serial_total = sum(solo(t).wall_s for t in range(N_TENANTS))

    out = []
    admissions = {}
    for admission in ("fifo", "round_robin", "fair_quantum"):
        rep = run_tenants(
            session(),
            {f"tenant{t}": _requests(prompts, t)
             for t in range(N_TENANTS)},
            admission=admission)
        p99 = max(t.p99_latency_s for t in rep.tenants)
        derived = {
            "fairness": round(rep.fairness, 4),
            "cv": round(rep.cv, 4),
            "overlap_eff_steps": round(rep.overlap_efficiency, 4),
            "overlap_eff_wall": round(cc.overlap_efficiency(
                serial_total, rep.wall_s, N_TENANTS), 4),
            "p99_latency_ms": round(p99 * 1e3, 2),
            "tokens": rep.tokens_out,
            "steps": rep.steps,
            "slots": SLOTS}
        admissions[admission] = derived
        out.append(Record(
            name=f"fig17/serving/{admission}/tenants={N_TENANTS}",
            us_per_call=rep.wall_s * 1e6,
            derived=derived))
    summary = {"figure": "fig17_serving_fairness",
               "n_tenants": N_TENANTS, "slots": SLOTS,
               "admissions": admissions}
    stamp(summary, "fig17_serving_fairness")
    BENCH_PATH.write_text(json.dumps(summary, indent=2) + "\n")
    return out
