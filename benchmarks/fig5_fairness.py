"""Fig 5 — overlap efficiency vs fairness across precisions/stream counts.

Paper claim validated: aggregate speedup masks per-stream variance; fairness
degrades as stream count rises even when overlap efficiency improves.
``fairness`` follows the paper's [0, 1] convention (clamped; a collapse
reads 0.0) — ``fairness_raw`` keeps the unbounded diagnostic value and
``fairness_minmax`` the §7.2 min/max-ratio variant."""
import jax

from repro.core import concurrency as cc
from repro.core.characterization import PRECISIONS, Record, _mk, _matmul_fn


def run():
    out = []
    S = 256
    for prec in ("fp32", "fp16", "fp8"):
        dtype = PRECISIONS[prec]
        fn = _matmul_fn(dtype)
        b = _mk((S, S), dtype, 1)
        for ns in (2, 4, 8):
            def mk(i):
                a = _mk((S, S), dtype, key=i)
                return lambda: fn(a, b)
            rep = cc.characterize_streams(mk, ns, mode="async")
            out.append(Record(
                name=f"fig5/{prec}/streams={ns}",
                us_per_call=rep.wall_s * 1e6,
                derived={"fairness": round(rep.fairness, 4),
                         "fairness_raw": round(
                             cc.fairness_raw(rep.per_stream_s), 4),
                         "fairness_minmax": round(rep.fairness_min_max, 4),
                         "cv": round(rep.cv, 4),
                         "overlap_eff": round(rep.overlap_efficiency, 4),
                         "streams": ns, "precision": prec}))
    return out
