"""Fig 5 — overlap efficiency vs fairness across precisions/stream counts.

Paper claim validated: aggregate speedup masks per-stream variance; fairness
degrades as stream count rises even when overlap efficiency improves.
``fairness`` follows the paper's [0, 1] convention (clamped; a collapse
reads 0.0) — ``fairness_raw`` keeps the unbounded diagnostic value and
``fairness_minmax`` the §7.2 min/max-ratio variant."""
import jax

from repro.core import concurrency as cc
from repro.core.characterization import PRECISIONS, _mk, _matmul_fn


def run():
    out = []
    S = 256
    for prec in ("fp32", "fp16", "fp8"):
        dtype = PRECISIONS[prec]
        fn = _matmul_fn(dtype)
        b = _mk((S, S), dtype, 1)
        for ns in (2, 4, 8):
            def mk(i):
                a = _mk((S, S), dtype, key=i)
                return lambda: fn(a, b)
            rep = cc.characterize_streams(mk, ns, mode="async")
            # shared StreamReport schema (see fig4); fairness_raw and the
            # §7.2 min/max variant ride along as extra derived keys
            out.append(rep.to_record(
                f"fig5/{prec}/streams={ns}",
                fairness_raw=round(cc.fairness_raw(rep.per_stream_s), 4),
                streams=ns, precision=prec))
    return out
