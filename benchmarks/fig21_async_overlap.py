"""Fig 21 — scheduled-online sparse+dense lane overlap vs serialized.

The paper characterizes ACE concurrency *offline* (fig4/fig13: contention
is shape- and pairing-dependent); AsyncSparse shows sparse matmul winning
specifically on asynchronous execution. This figure closes the loop
*online*: the OverlapPlanner pairs sparse24 with dense work from the
Tracer's measured per-shape latency EMAs and dispatches the pair through
ExecutionLanes before joining either side.

Two arms at the fig13 contention shape (k=512):

* **contention** — the raw kernel pairing decision: one sparse24-packed
  (fp8 values) decode-batch GEMM against a menu of dense bf16 GEMMs of
  varying M. The planner measures all of them online and pairs the
  sparse op with the dense op of *closest* measured latency (a lopsided
  pair would just serialize behind its slow member); the chosen pair is
  then co-dispatched and its per-op dispatch→ready overlap reported.
  On CPU the XLA executions themselves serialize, so the wall win here
  is reported, not asserted — the asserted win is the serving arm's.
* **serving** — four heterogeneous partitions (2x fp8:sparse24 beside
  2x bf16:dense) drained over the same tenant workload with
  ``ServingSpec(overlap=...)`` on vs off: with lanes, one partition's
  host work (admission/prefill dispatch, token accounting) hides under
  another's in-flight decode. The two runtimes step in lockstep
  alternation (paired per-step walls — separate drains are dominated by
  machine drift at this scale). Tokens are asserted identical;
  ``tok_per_step`` is wall-normalized (tokens per serialized-arm mean
  step wall), so the overlap arm exceeds the serialized arm exactly when
  its wall-clock throughput wins.

Writes ``BENCH_fig21.json`` (the second perf-trajectory point after
``BENCH_fig20.json``); CI asserts overlap >= serialized tok/step on it.
"""
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import stamp
from repro.configs import get_reduced
from repro.core import concurrency as cc
from repro.core import execution as ex
from repro.core import sparsity as sp
from repro.core.characterization import Record
from repro.kernels import registry
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime import telemetry, traceview
from repro.runtime.serve_loop import Request
from repro.runtime.server import (
    MigrationSpec, PartitionSpec, ServingRuntime, ServingSpec)

RT = RuntimeCfg(ssm_chunk=16)
MAX_LEN = 64
N_REQ = 12
PROMPT_LEN = 4
MAX_NEW = 8
SLOTS = 2
TENANTS = ("t0", "t1", "t2", "t3")
# fig13 contention shape: k=512 decode-regime GEMMs. The sparse24 op runs
# at decode batch M=64; the dense menu spans M so the planner has a real
# choice — only one dense M lands within max_imbalance of the sparse op.
SPARSE_M, K, N = 64, 512, 512
DENSE_MS = (256, 2048, 8192)
ROUNDS = 4
REPS = 3

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_fig21.json"
TRACE_PATH = BENCH_PATH.with_name("BENCH_fig21_trace.json")

_MODEL = {}


def _model():
    if not _MODEL:
        cfg = get_reduced("llama3-8b")
        _MODEL["cfg"] = cfg
        _MODEL["params"] = init_params(jax.random.PRNGKey(0), cfg)
    return _MODEL["cfg"], _MODEL["params"]


# ---------------------------------------------------------------------------
# Arm 1: kernel-level pairing at the contention shape
# ---------------------------------------------------------------------------

def _contention():
    be = registry.get_backend("jnp")
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32) \
        .astype(jnp.bfloat16)
    vals, meta = sp.pack_24(sp.prune_24(w))
    vals8 = vals.astype(jnp.float8_e4m3fn)

    # jit with operands as *arguments* — closing over the arrays would let
    # XLA constant-fold the whole GEMM out of the timed region
    sp_jit = jax.jit(lambda a, v, m: be.sparse24(a, v, m,
                                                 out_dtype=jnp.float32))
    dn_jit = jax.jit(lambda a, b: be.dense(a, b, out_dtype=jnp.float32))

    xs = jax.random.normal(jax.random.PRNGKey(0), (SPARSE_M, K),
                           jnp.float32).astype(jnp.bfloat16)
    thunks = {0: (lambda: sp_jit(xs, vals8, meta))}
    shapes = {0: (SPARSE_M, K, N, "fp8_sparse24")}
    sparsities = {0: "sparse24"}
    for i, m in enumerate(DENSE_MS, start=1):
        xd = jax.random.normal(jax.random.PRNGKey(i), (m, K),
                               jnp.float32).astype(jnp.bfloat16)
        thunks[i] = (lambda xd=xd: dn_jit(xd, w))
        shapes[i] = (m, K, N, "bf16")
        sparsities[i] = "dense"

    tracer = telemetry.Tracer()
    # online measurement: run every op serially a few times (first round
    # doubles as jit warmup), feeding the per-shape wall EMAs the planner
    # pairs from
    for r in range(4):
        for idx, fn in thunks.items():
            t0 = time.perf_counter()
            cc._block(fn())
            if r:  # skip the compile round
                tracer.record_matmul(*shapes[idx][:3],
                                     precision=shapes[idx][3],
                                     backend="jnp",
                                     wall_s=time.perf_counter() - t0)

    planner = ex.OverlapPlanner(pair_homogeneous=False)
    plan = planner.plan([
        planner.candidate(i, sparsity=sparsities[i], shape=shapes[i],
                          tracer=tracer)
        for i in sorted(thunks)])
    pair = next((g for g in plan.groups if 0 in g), None)
    partner = next((i for i in pair if i != 0), None) if pair else None
    emas = tracer.shape_latency_ema()

    serial_wall = 0.0
    overlap_wall = 0.0
    ov = {"groups": 0, "mean_efficiency": 0.0}
    if pair:
        lanes = {i: cc.ExecutionLane(f"k{i}", index=i, tracer=tracer)
                 for i in pair}

        def serial_pass():
            t0 = time.perf_counter()
            for _ in range(ROUNDS):
                for idx in pair:
                    lanes[idx].dispatch(thunks[idx]).join()
            return time.perf_counter() - t0

        def overlap_pass(gid0):
            t0 = time.perf_counter()
            for r in range(ROUNDS):
                handles = [(idx, lanes[idx].dispatch(
                    thunks[idx], overlap_group=gid0 + r)) for idx in pair]
                for idx, h in handles:
                    h.join()
                    m_, k_, n_, prec = shapes[idx]
                    tracer.record("matmul", m=m_, k=k_, n=n_,
                                  precision=prec, backend="jnp",
                                  lane=lanes[idx].name,
                                  overlap_group=gid0 + r,
                                  wall_s=h.dispatch_to_ready_s)
            return time.perf_counter() - t0

        serial_wall = min(serial_pass() for _ in range(REPS))
        overlap_wall = min(overlap_pass(1000 * rep) for rep in range(REPS))
        ov = tracer.overlap_summary()

    return {
        "sparse_m": SPARSE_M, "k": K, "n": N, "dense_menu_m": list(DENSE_MS),
        "rounds": ROUNDS,
        "measured_ema_us": {
            f"{sh[3]}:m={sh[0]}": round(emas[sh] * 1e6, 1)
            for sh in shapes.values() if sh in emas},
        "planner_paired": int(pair is not None),
        "paired_dense_m": shapes[partner][0] if partner else None,
        "serialized_wall_us": round(serial_wall * 1e6, 1),
        "overlap_wall_us": round(overlap_wall * 1e6, 1),
        # reported, not asserted: single-process CPU XLA serializes the two
        # device computations, so co-dispatch of a kernel pair is ~1.0x
        # here; the asserted overlap win is the serving arm's tok_per_step
        "speedup": round(serial_wall / max(overlap_wall, 1e-12), 3),
        "group_mean_efficiency": round(ov["mean_efficiency"], 3),
        "groups": ov["groups"],
    }


# ---------------------------------------------------------------------------
# Arm 2: serving drain, overlap on vs off
# ---------------------------------------------------------------------------

def _requests(cfg):
    rng = np.random.default_rng(0)
    return [Request(uid=j,
                    prompt=rng.integers(0, cfg.vocab_size, PROMPT_LEN)
                    .astype(np.int32), max_new=MAX_NEW)
            for j in range(N_REQ)]


def _spec(overlap):
    # two sparse24 + two dense partitions: the planner forms two
    # sparse/dense pairs, and all four dispatch before any join
    return ServingSpec(
        partitions=(PartitionSpec(policy="fp8:sparse24:jnp"),
                    PartitionSpec(policy="bf16:dense:jnp"),
                    PartitionSpec(policy="fp8:sparse24:jnp"),
                    PartitionSpec(policy="bf16:dense:jnp")),
        placement="spread", batch_slots=SLOTS, max_len=MAX_LEN,
        migration=MigrationSpec(), overlap=overlap)


def _build(overlap):
    cfg, params = _model()
    rt = ServingRuntime(params, cfg, _spec(overlap), rt=RT)
    for t in TENANTS:
        rt.add_tenant(t)
    for j, req in enumerate(_requests(cfg)):
        rt.submit(TENANTS[j % len(TENANTS)], req)
    return rt


def _paired_drive():
    """Drain a serialized and an overlap runtime in step-by-step
    lockstep-alternation, accumulating each arm's per-step wall.

    Separate back-to-back drains are dominated by machine drift (CPU
    frequency, allocator state) at this scale; alternating single steps
    exposes both arms to the same instantaneous conditions so the
    accumulated walls are a paired comparison."""
    rts = {"serialized": _build(False), "overlap": _build(True)}
    walls = {k: 0.0 for k in rts}
    done = {k: [] for k in rts}
    while any(rt.pending() or rt.n_active for rt in rts.values()):
        for name, rt in rts.items():
            if rt.pending() or rt.n_active:
                t0 = time.perf_counter()
                done[name].extend(rt.step())
                walls[name] += time.perf_counter() - t0
    toks = {name: {r.uid: list(r.out) for r in ds}
            for name, ds in done.items()}
    steps = {name: rt.step_count for name, rt in rts.items()}
    return toks, steps, walls, rts


def run():
    contention = _contention()

    # warm the shared jit cache (all partitions' prefill+decode traces)
    # outside every timed step
    _build(True).drain()
    arms = {name: {"steps": 0, "wall_s": 0.0}
            for name in ("serialized", "overlap")}
    toks = {}
    for _ in range(REPS):
        tk, steps, walls, rts = _paired_drive()
        for name, arm in arms.items():
            arm["steps"] = steps[name]
            arm["wall_s"] += walls[name]  # aggregate over paired reps
            arm["rt"] = rts[name]
            toks.setdefault(name, tk[name])
            assert toks[name] == tk[name], f"{name} arm is not deterministic"

    assert toks["serialized"] == toks["overlap"], \
        "greedy tokens diverged between serialized and overlap arms"
    tokens = sum(len(v) for v in toks["serialized"].values())

    ser, ovl = arms["serialized"], arms["overlap"]
    # wall-normalized tokens/step: tokens per serialized-arm mean step
    # wall. The serialized arm's value is its literal tokens/step; the
    # overlap arm exceeds it exactly when its wall-clock throughput wins
    # (steps are lockstep-identical across arms by construction).
    base_step_wall = ser["wall_s"] / max(ser["steps"] * REPS, 1)
    for arm in (ser, ovl):
        arm["tok_per_step"] = \
            tokens * REPS * base_step_wall / arm["wall_s"]

    merged = ovl["rt"].merged_tracer()
    lane_evs = [e for e in merged.events("decode")
                if e.lane and e.overlap_group >= 0]
    ov = merged.overlap_summary()
    assert lane_evs, "overlap arm recorded no lane-tagged decode events"
    assert ov["groups"] >= 1, "overlap arm formed no overlap groups"

    # Chrome/Perfetto trace of the overlap arm: planner-paired groups
    # must render as temporally overlapping slices on distinct lane
    # tracks (the figure's whole claim, made visually checkable).
    traceview.export_chrome_trace(merged, TRACE_PATH)
    trace = traceview.validate(traceview.load(TRACE_PATH))
    assert trace["overlap_groups_overlapping"] >= 1, \
        "trace shows no temporally overlapping planner-paired group"

    summary = {
        "figure": "fig21_async_overlap",
        "contention": contention,
        "serialized": {"steps": ser["steps"], "tokens": tokens,
                       "wall_s": round(ser["wall_s"], 4),
                       "tok_per_step": round(ser["tok_per_step"], 4)},
        "overlap": {"steps": ovl["steps"], "tokens": tokens,
                    "wall_s": round(ovl["wall_s"], 4),
                    "tok_per_step": round(ovl["tok_per_step"], 4),
                    "overlap_groups": ov["groups"],
                    "lane_decode_events": len(lane_evs),
                    "group_mean_speedup": round(ov["mean_speedup"], 3)},
        "serving_speedup": round(ser["wall_s"] / max(ovl["wall_s"], 1e-12),
                                 3),
        "tokens_equal": 1,
        "trace": {"path": TRACE_PATH.name, **trace},
    }
    stamp(summary, "fig21_async_overlap")
    BENCH_PATH.write_text(json.dumps(summary, indent=2) + "\n")

    out = [
        Record(name="fig21/contention/pairing",
               us_per_call=contention["overlap_wall_us"],
               derived={k: v for k, v in contention.items()
                        if k not in ("overlap_wall_us",)}),
    ]
    for name in ("serialized", "overlap"):
        arm = arms[name]
        out.append(Record(
            name=f"fig21/serving/{name}",
            us_per_call=arm["wall_s"] * 1e6,
            derived={"steps": arm["steps"], "tokens": tokens,
                     "tok_per_step": round(arm["tok_per_step"], 4)}))
    out.append(Record(
        name="fig21/equality", us_per_call=0.0,
        derived={"tokens_equal": 1, "overlap_groups": ov["groups"],
                 "lane_decode_events": len(lane_evs),
                 "serving_speedup": summary["serving_speedup"]}))
    return out
