"""Fig 15 — two concurrent FP8 transformer workloads on separate queues.

Paper claim validated: concurrent execution of FP8-heavy workloads gives
limited overlap and visible per-stream variability (contention effects of
§6 at application level)."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import PAPER_TRANSFORMER
from repro.core import concurrency as cc
from repro.core.characterization import Record
from repro.models import forward, init_params
from repro.models.layers import RuntimeCfg


def run():
    rt = RuntimeCfg(chunk_q=64, chunk_kv=64)
    cfg = dataclasses.replace(PAPER_TRANSFORMER, num_layers=2,
                              d_model=256, d_ff=1024, num_heads=4,
                              num_kv_heads=4, head_dim=64, vocab_size=1024)
    params = init_params(jax.random.PRNGKey(0), cfg)
    fwd = jax.jit(lambda p, t: forward(p, t, cfg, rt)[0])

    def mk(i):
        toks = jax.random.randint(jax.random.PRNGKey(i), (2, 64), 0,
                                  cfg.vocab_size)
        return lambda: fwd(params, toks)

    out = []
    for ns in (1, 2):
        rep = cc.characterize_streams(mk, ns, mode="async")
        out.append(Record(
            name=f"fig15/fp8_workloads/streams={ns}",
            us_per_call=rep.wall_s * 1e6,
            derived={"speedup": round(rep.speedup, 3),
                     "overlap_eff": round(rep.overlap_efficiency, 3),
                     "fairness": round(rep.fairness, 4),
                     "cv": round(rep.cv, 4)}))
    return out
