"""Fig 23 (beyond-paper) — SLO closed loop under generated traffic:
attainment recovery at bounded batch cost.

The paper's concurrency guidance is about *decisions* — when co-running
workloads helps and when a latency-sensitive stream needs the machine.
PRs 2-8 built every mechanism (admission classes, quotas, freeze/thaw,
SLO attainment measurement) but nothing *acted* on the signal. This
figure closes the loop and prices it.

The workload is generated, not scripted (``runtime/workload.py``): a
Zipf-popular pair of batch-class tenants (long outputs, bursty ON/OFF
arrivals) beside one unpopular latency-class tenant (short interactive
answers, ``latency:20`` turnaround SLO) through a 2-slot FIFO partition
— the fairness-collapse configuration from fig17. Two arms, same seeded
trace:

* **off** — measurement only (the pre-PR runtime): the batch convoy
  starves the latency tenant; attainment lands near zero.
* **on** — ``SLOController``: the starvation/at-risk signal freezes
  batch-class tenants and boosts the latency tenant's slot cap within
  one control interval; hysteresis (low/high band + hold streak)
  releases after the pressure passes.

Asserted headline: latency attainment < 0.7 off, >= 0.95 on, with

* committed tokens per uid IDENTICAL across arms (the controller only
  reorders admission; greedy decode is execution-order exact — the PR 2
  invariant extended to preemption), and
* bounded batch cost: the batch tenants' step-domain throughput ratio
  off/on <= 1.25 and total steps on/off <= 1.25 (freezing delays batch
  work, it never drops it).

Three seeds run; the first is the gated headline, the rest guard
against a seed-lucky controller. Writes ``BENCH_fig23.json`` for the
trajectory gate.
"""
import json
from pathlib import Path

import jax

from benchmarks.common import stamp
from repro.configs import get_reduced
from repro.core.characterization import Record
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime import workload as wl
from repro.runtime.controller import ControllerSpec
from repro.runtime.server import PartitionSpec, ServingRuntime, ServingSpec

RT = RuntimeCfg(ssm_chunk=16)
SLOTS = 2
MAX_LEN = 64
SEEDS = (7, 3, 11)                   # first seed is the gated headline
LAT = "tenant2"                      # the latency-class rank (unpopular)

CONTROLLER = ControllerSpec(interval=2, low=0.9, high=0.97, hold=4)

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_fig23.json"


def _workload(seed: int) -> wl.WorkloadSpec:
    """Two Zipf-head batch tenants flooding long outputs in bursts; one
    tail latency tenant answering short under a 20-step turnaround SLO.
    The target leaves slack for preempt-by-drain: worst-case slot drain
    (12 tokens) + short decode (5) fits inside 20 steps."""
    return wl.WorkloadSpec(
        tenants=3, zipf_s=1.1, arrival="bursty", rate=1.0,
        burst_factor=3.0, burst_len=6, steps=40,
        prompt_len=(4, 8), max_new=(8, 12),
        max_new_overrides=(None, None, (3, 5)),
        slos=("batch", "batch", "latency:20"), seed=seed)


def _run_arm(params, cfg, trace, controller):
    spec = ServingSpec(
        partitions=(PartitionSpec(admission="fifo"),),
        batch_slots=SLOTS, max_len=MAX_LEN, controller=controller)
    runtime = ServingRuntime(params, cfg, spec, rt=RT)
    done = wl.run_trace(runtime, trace)
    rep = runtime.report()
    rows = {t.tenant_id: t for t in rep.tenants}
    batch_tokens = sum(t.tokens_out for t in rep.tenants
                      if t.tenant_id != LAT)
    summary = {
        "steps": rep.steps,
        "tokens": rep.tokens_out,
        "latency_attainment": rows[LAT].slo_attainment,
        "latency_mean_turnaround": round(
            rows[LAT].mean_turnaround_steps, 3),
        "batch_tokens": batch_tokens,
        "batch_tok_per_step": round(batch_tokens / max(1, rep.steps), 4),
        "fairness": round(rep.fairness, 4),
        "wall_s": round(rep.wall_s, 4),
    }
    if runtime.controller is not None:
        summary["controller"] = {
            "checks": runtime.controller.checks,
            "actions": runtime.controller.counts(),
            "ledger": [a.to_dict() for a in runtime.controller.actions],
        }
    return summary, wl.tokens_by_uid(done)


def run():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)

    seeds = {}
    for seed in SEEDS:
        trace = wl.generate(_workload(seed))
        off, toks_off = _run_arm(params, cfg, trace, None)
        on, toks_on = _run_arm(params, cfg, trace, CONTROLLER)

        # The controller must never change WHAT gets decoded — only
        # when. Greedy tokens per uid are the equality unit.
        assert toks_on == toks_off, \
            f"seed {seed}: controller changed committed tokens"
        att_off = off["latency_attainment"]
        att_on = on["latency_attainment"]
        assert att_off is not None and att_off < 0.7, \
            f"seed {seed}: off-arm attainment {att_off} not < 0.7 — " \
            "the workload no longer starves the latency tenant"
        assert att_on is not None and att_on >= 0.95, \
            f"seed {seed}: on-arm attainment {att_on} < 0.95 — " \
            "the controller failed to recover the latency class"
        batch_cost = (off["batch_tok_per_step"]
                      / max(on["batch_tok_per_step"], 1e-9))
        step_cost = on["steps"] / max(1, off["steps"])
        assert batch_cost <= 1.25 and step_cost <= 1.25, \
            f"seed {seed}: batch-class cost unbounded (tok/step ratio " \
            f"{batch_cost:.3f}, step ratio {step_cost:.3f})"
        acts = on["controller"]["actions"]
        assert acts["freeze"] >= 1 and acts["thaw"] == acts["freeze"], \
            f"seed {seed}: controller ledger unbalanced ({acts})"
        seeds[f"seed{seed}"] = {
            "off": off, "on": on, "tokens_equal": 1,
            "batch_cost": round(batch_cost, 4),
            "step_cost": round(step_cost, 4),
        }

    head = seeds[f"seed{SEEDS[0]}"]
    summary = {
        "figure": "fig23_slo_control",
        "workload": _workload(SEEDS[0]).to_dict(),
        "controller": CONTROLLER.to_dict(),
        "seeds": seeds,
        "attainment_off": head["off"]["latency_attainment"],
        "attainment_on": head["on"]["latency_attainment"],
        "batch_cost": head["batch_cost"],
        "step_cost": head["step_cost"],
        "controller_actions": sum(
            head["on"]["controller"]["actions"].values()),
        "tokens_equal": 1,
    }
    stamp(summary, "fig23_slo_control")
    BENCH_PATH.write_text(json.dumps(summary, indent=2) + "\n")

    out = []
    for name, s in seeds.items():
        for arm in ("off", "on"):
            a = s[arm]
            out.append(Record(
                name=f"fig23/slo_control/{name}/{arm}",
                us_per_call=a["wall_s"] * 1e6,
                derived={"steps": a["steps"],
                         "latency_attainment": a["latency_attainment"],
                         "batch_tok_per_step": a["batch_tok_per_step"]}))
    out.append(Record(
        name="fig23/equality", us_per_call=0.0,
        derived={"tokens_equal": 1,
                 "attainment_off": summary["attainment_off"],
                 "attainment_on": summary["attainment_on"],
                 "batch_cost": summary["batch_cost"]}))
    return out
