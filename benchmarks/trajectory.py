"""BENCH_* trajectory store + CI regression gate.

Every figure benchmark that matters for serving performance writes a
``BENCH_<figure>.json`` summary (fig20, fig21, …). This module turns
those one-off artifacts into a *trajectory*: a store
(``TRAJECTORY.json``) appending each run keyed by
``(figure, git_sha, hardware_key)`` — the hardware key following the
``REPRO_AUTOTUNE_DIR`` one-artifact-per-target convention via
``benchmarks.common.hardware_key()`` — plus a ``--check`` mode that
compares the current BENCH files against the stored baseline with
per-metric tolerance bands and exits non-zero on regression. CI runs it
after the fig20/fig21 smokes, so a PR that quietly loses the paged
tokens/step win or the fig21 overlap speedup fails the build instead of
shipping (the ReFrame performance-regression idiom, applied to the
repo's own serving stack).

Metric bands
------------
Deterministic metrics (token counts over scheduler steps, handoff
bytes, fairness indices) gate tightly; wall-clock-derived metrics
(p99 step µs) vary across runners and are *tracked* but never gate;
same-run wall ratios (overlap speedup) gate loosely. An injected 20%
tokens/step regression always trips the gate — pinned by
``tests/test_observability.py``.

Usage::

    python -m benchmarks.trajectory --dir .              # append runs
    python -m benchmarks.trajectory --check --dir .      # gate (CI)
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

STORE_NAME = "TRAJECTORY.json"
STORE_SCHEMA = 1


# ---------------------------------------------------------------------------
# Metric tables
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Metric:
    """One gated (or tracked) scalar of a figure's BENCH summary.

    ``path`` is a dotted path into the summary dict unless ``extract``
    overrides it. ``direction`` says which way is better; a *regression*
    is a move in the bad direction beyond ``tol`` (relative).
    ``gate=False`` metrics are reported for the trajectory but never
    fail the check (wall-clock absolutes across heterogeneous runners).
    """
    name: str
    path: str = ""
    direction: str = "higher"        # "higher" | "lower"
    tol: float = 0.10
    gate: bool = True
    extract: Optional[Callable[[Dict[str, Any]], float]] = None

    def value(self, doc: Dict[str, Any]) -> Optional[float]:
        if self.extract is not None:
            try:
                return float(self.extract(doc))
            except (KeyError, ValueError, TypeError, ZeroDivisionError):
                return None
        cur: Any = doc
        for part in (self.path or self.name).split("."):
            if not isinstance(cur, dict) or part not in cur:
                return None
            cur = cur[part]
        try:
            return float(cur)
        except (TypeError, ValueError):
            return None

    def regressed(self, baseline: float, current: float) -> bool:
        if self.direction == "higher":
            return current < baseline * (1.0 - self.tol)
        return current > baseline * (1.0 + self.tol)


def _max_paged_handoff(doc: Dict[str, Any]) -> float:
    return max(h["handoff_bytes"] for h in doc["handoff"]
               if h["layout"] == "paged")


FIGURE_METRICS: Dict[str, Tuple[Metric, ...]] = {
    # fig17: admission fairness. fair_quantum's restoration is the claim
    # (tight band); tokens/steps are deterministic greedy counts; the FIFO
    # collapse and wall p99 are reported but never gate.
    "fig17_serving_fairness": (
        Metric("admissions.fair_quantum.fairness", tol=0.05),
        Metric("admissions.fair_quantum.overlap_eff_steps", tol=0.10),
        Metric("admissions.fair_quantum.tokens", tol=0.0),
        Metric("admissions.fifo.fairness", gate=False),
        Metric("admissions.fair_quantum.p99_latency_ms",
               direction="lower", gate=False),
    ),
    # fig18: partitioned serving. The headline cell (2 partitions,
    # load_aware placement, fair_quantum/adaptive) must keep its
    # step-domain throughput and fairness; the 1-partition FIFO floor and
    # wall throughput ride along.
    "fig18_partitioned_serving": (
        Metric("fig18_tok_per_step",
               path="cells.p2-load_aware-fair_quantum-adaptive"
                    ".tok_per_step", tol=0.10),
        Metric("fig18_fairness",
               path="cells.p2-load_aware-fair_quantum-adaptive.fairness",
               tol=0.05),
        Metric("fig18_tokens",
               path="cells.p2-load_aware-fair_quantum-adaptive.tokens",
               tol=0.0),
        Metric("fig18_fifo_fairness",
               path="cells.p1-packed-fifo-static.fairness", gate=False),
        Metric("fig18_tok_per_s",
               path="cells.p2-load_aware-fair_quantum-adaptive.tok_per_s",
               gate=False),
    ),
    # fig19: live migration. The crossed-stream equality and the
    # migration count are the handoff bands; victim fairness and
    # step-domain throughput gate on the runtime arm.
    "fig19_migration": (
        Metric("equality.all_equal", tol=0.0),
        Metric("runtime.migrations", tol=0.5),
        Metric("runtime.fairness_victims", tol=0.05),
        Metric("runtime.tok_per_step", tol=0.10),
        Metric("runtime.handoffs", gate=False),
        Metric("runtime.tok_per_s", gate=False),
    ),
    # fig20: paged serving density. tokens_per_step / density / fairness /
    # handoff bytes are deterministic (token counts, page tables); step
    # wall percentiles are runner-dependent -> track only.
    "fig20_paged_serving": (
        Metric("paged.tokens_per_step", tol=0.10),
        Metric("dense.tokens_per_step", tol=0.10),
        Metric("density_ratio", tol=0.05),
        Metric("paged.fairness", tol=0.05),
        Metric("paged.resident_peak", tol=0.05),
        Metric("max_paged_handoff_bytes", direction="lower", tol=0.05,
               extract=_max_paged_handoff),
        Metric("paged.p99_step_us", direction="lower", gate=False),
        Metric("dense.p99_step_us", direction="lower", gate=False),
    ),
    # fig21: async overlap. Serialized tok/step is deterministic
    # (tokens / lockstep steps); the overlap arm folds a wall-clock
    # ratio in -> slightly wider band; the speedup itself is a same-run
    # wall ratio -> loose band (CI runners are noisy but the win must
    # not invert); raw contention walls -> track only.
    "fig21_async_overlap": (
        Metric("serialized.tok_per_step", tol=0.10),
        Metric("overlap.tok_per_step", tol=0.15),
        Metric("serving_speedup", tol=0.40),
        Metric("tokens_equal", tol=0.0),
        Metric("overlap.overlap_groups", tol=0.50),
        Metric("contention.speedup", gate=False),
        Metric("contention.serialized_wall_us", direction="lower",
               gate=False),
        Metric("contention.overlap_wall_us", direction="lower",
               gate=False),
    ),
    # fig22: speculative decoding. Everything gated is step-domain
    # deterministic: greedy tokens over lockstep steps. tokens_equal is
    # the exactness contract (zero tolerance); acceptance rate and
    # effective tokens/step are the figure's whole claim; the hostile-
    # workload acceptance is tracked so draft-quality drift is visible.
    "fig22_speculative": (
        Metric("tokens_equal", tol=0.0),
        Metric("effective_speedup", tol=0.10),
        Metric("fig22_acceptance_rate",
               path="arms.k4_fp8.acceptance_rate", tol=0.10),
        Metric("fig22_tok_per_step",
               path="arms.k4_fp8.tok_per_step", tol=0.10),
        Metric("fig22_baseline_tok_per_step",
               path="arms.k1.tok_per_step", tol=0.10),
        Metric("fig22_sp24_acceptance_rate",
               path="arms.k4_fp8_sp24.acceptance_rate", tol=0.20),
        Metric("fig22_hostile_acceptance_rate",
               path="hostile_k4_fp8.acceptance_rate", gate=False),
    ),
    # fig23: the SLO closed loop. Attainment on both arms is a
    # deterministic step-domain quantity (seeded workload, lockstep
    # steps), so the recovery claim gates tight; the off-arm collapse
    # gates in the "lower is better" direction (a rising off-arm means
    # the workload stopped starving the latency tenant and the figure
    # no longer demonstrates anything); batch cost must stay bounded.
    "fig23_slo_control": (
        Metric("tokens_equal", tol=0.0),
        Metric("attainment_on", tol=0.02),
        Metric("attainment_off", direction="lower", tol=0.5),
        Metric("fig23_batch_cost", path="batch_cost",
               direction="lower", tol=0.15),
        Metric("fig23_step_cost", path="step_cost",
               direction="lower", tol=0.15),
        Metric("fig23_controller_actions", path="controller_actions",
               gate=False),
        Metric("fig23_batch_tok_per_step",
               path="seeds.seed7.on.batch_tok_per_step", tol=0.10),
    ),
}


# ---------------------------------------------------------------------------
# Store
# ---------------------------------------------------------------------------

def _atomic_write(path: str, text: str) -> None:
    import tempfile
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".trajectory-")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def load_store(path: str) -> Dict[str, Any]:
    if not os.path.exists(path):
        return {"schema": STORE_SCHEMA, "runs": []}
    with open(path) as f:
        store = json.load(f)
    if store.get("schema") != STORE_SCHEMA:
        raise ValueError(f"{path}: store schema {store.get('schema')!r} "
                         f"!= {STORE_SCHEMA}")
    return store


def save_store(store: Dict[str, Any], path: str) -> str:
    _atomic_write(path, json.dumps(store, indent=1) + "\n")
    return path


def _doc_meta(doc: Dict[str, Any], path: str) -> Dict[str, str]:
    """(figure, sha, hardware) of one BENCH doc; pre-metadata-era files
    fall back to the current environment's stamp so old artifacts stay
    ingestible."""
    meta = doc.get("meta") or {}
    figure = meta.get("figure") or doc.get("figure") \
        or os.path.basename(path).replace("BENCH_", "").replace(".json", "")
    if not meta:
        from benchmarks.common import run_metadata
        meta = run_metadata(figure)
    return {"figure": figure,
            "git_sha": meta.get("git_sha", ""),
            "hardware_key": meta.get("hardware_key", "unknown")}


def metric_values(figure: str, doc: Dict[str, Any]) -> Dict[str, float]:
    vals = {}
    for m in FIGURE_METRICS.get(figure, ()):
        v = m.value(doc)
        if v is not None:
            vals[m.name] = v
    return vals


def bench_files(directory: str) -> List[str]:
    return sorted(p for p in glob.glob(os.path.join(directory,
                                                    "BENCH_*.json"))
                  if not p.endswith("_trace.json"))


def append_runs(directory: str, store_path: str) -> List[Dict[str, Any]]:
    """Fold every BENCH_*.json under ``directory`` into the store. A run
    with the same (figure, git_sha, hardware_key) replaces its previous
    entry (idempotent re-runs); anything else appends."""
    store = load_store(store_path)
    added = []
    for path in bench_files(directory):
        with open(path) as f:
            doc = json.load(f)
        key = _doc_meta(doc, path)
        figure = key["figure"]
        if figure not in FIGURE_METRICS:
            continue                      # no gated metrics for this figure
        entry = {**key,
                 "recorded_unix": round(time.time(), 3),
                 "metrics": metric_values(figure, doc)}
        store["runs"] = [r for r in store["runs"]
                         if (r["figure"], r["git_sha"], r["hardware_key"])
                         != (figure, key["git_sha"], key["hardware_key"])]
        store["runs"].append(entry)
        added.append(entry)
    save_store(store, store_path)
    return added


def baseline_for(store: Dict[str, Any], figure: str,
                 hardware_key: str) -> Optional[Dict[str, Any]]:
    """Latest stored run of ``figure`` on the same hardware target."""
    runs = [r for r in store["runs"]
            if r["figure"] == figure and r["hardware_key"] == hardware_key]
    return runs[-1] if runs else None


# ---------------------------------------------------------------------------
# The gate
# ---------------------------------------------------------------------------

def check(directory: str, store_path: str, out=sys.stdout) -> int:
    """Compare current BENCH files against stored baselines. Returns the
    number of regressions (0 = pass). Missing baselines and track-only
    metrics report but never fail."""
    store = load_store(store_path)
    regressions = 0
    checked = 0
    for path in bench_files(directory):
        with open(path) as f:
            doc = json.load(f)
        key = _doc_meta(doc, path)
        figure = key["figure"]
        metrics = FIGURE_METRICS.get(figure)
        if not metrics:
            continue
        base = baseline_for(store, figure, key["hardware_key"])
        if base is None:
            print(f"[trajectory] {figure}: no baseline for "
                  f"{key['hardware_key']} — recording only", file=out)
            continue
        for m in metrics:
            cur = m.value(doc)
            ref = base["metrics"].get(m.name)
            if cur is None or ref is None:
                continue
            checked += 1
            bad = m.gate and m.regressed(ref, cur)
            drift = (cur / ref - 1.0) * 100 if ref else 0.0
            tag = "REGRESSION" if bad else (
                "track" if not m.gate else "ok")
            print(f"[trajectory] {figure}/{m.name}: {cur:g} vs "
                  f"baseline {ref:g} ({drift:+.1f}%, want "
                  f"{m.direction}, tol {m.tol * 100:.0f}%) {tag}",
                  file=out)
            if bad:
                regressions += 1
    print(f"[trajectory] {checked} metric(s) checked, "
          f"{regressions} regression(s)", file=out)
    return regressions


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="BENCH_* trajectory store + regression gate")
    ap.add_argument("--dir", default=".",
                    help="directory holding BENCH_*.json artifacts")
    ap.add_argument("--store", default=None,
                    help=f"trajectory store path (default: "
                         f"<dir>/{STORE_NAME})")
    ap.add_argument("--check", action="store_true",
                    help="gate current BENCH files against the stored "
                         "baselines (exit 1 on regression) instead of "
                         "appending them")
    args = ap.parse_args(argv)
    store_path = args.store or os.path.join(args.dir, STORE_NAME)
    if args.check:
        return 1 if check(args.dir, store_path) else 0
    added = append_runs(args.dir, store_path)
    for e in added:
        print(f"[trajectory] recorded {e['figure']} @ {e['git_sha']} "
              f"on {e['hardware_key']}: "
              f"{len(e['metrics'])} metric(s)")
    if not added:
        print(f"[trajectory] no BENCH_*.json with known figures under "
              f"{args.dir!r}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
