"""Fig 18 (beyond-paper) — partitioned serving sweep.

The serving-layer version of the paper's partitioning guidance (§6/§9.2 +
the Instinct partitioning study): the same multi-tenant workload runs on
1 / 2 / 4 spatial partitions, across tenant-placement policies and
admission/quota combinations. The headline: a single shared FIFO queue
collapses per-tenant fairness (~0, the paper's shared-ACE-queue result at
the application layer), while ``load_aware`` placement over 2 partitions
with telemetry-driven ``AdaptiveQuota`` slot caps restores fairness
≥ 0.8 at no worse aggregate step-domain throughput.

Throughput is reported in both domains: ``tok_per_step`` (deterministic
scheduler steps — partitions step in lockstep, so fewer steps at equal
tokens means real concurrency) and wall tok/s (rides along for real
hardware; on a single shared CPU device the logical partitions
time-multiplex it).

Writes ``BENCH_fig18.json`` so ``benchmarks/trajectory.py`` gates the
headline cell (2 partitions, load_aware, fair_quantum/adaptive):
tokens-per-step must not drop and its fairness restoration must hold.
"""
import json
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import stamp
from repro.configs import get_reduced
from repro.core.characterization import Record
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime.partition import run_partitioned
from repro.runtime.serve_loop import Request

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_fig18.json"

N_TENANTS = 4
REQS_PER_TENANT = 2
MAX_NEW = 8
SLOTS = 2                 # per partition — 4 tenants on 2 slots contend
RT = RuntimeCfg(ssm_chunk=16)

# (partitions, placement, admission, quota): the corners that tell the
# story. The full 3x3x2 grid is cut to keep CPU runtime sane — dropped
# cells are placement variants whose routing is identical on this
# balanced workload (logged below so the cut is visible).
SWEEP = (
    (1, "packed", "fifo", "static"),
    (1, "packed", "fair_quantum", "static"),
    (1, "packed", "fair_quantum", "adaptive"),
    (2, "packed", "fifo", "static"),
    (2, "spread", "fifo", "static"),
    (2, "load_aware", "fifo", "static"),
    (2, "packed", "fair_quantum", "adaptive"),
    (2, "spread", "fair_quantum", "adaptive"),
    (2, "load_aware", "fair_quantum", "adaptive"),
    (4, "spread", "fair_quantum", "adaptive"),
    (4, "load_aware", "fair_quantum", "adaptive"),
)


def _workloads(cfg):
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(REQS_PER_TENANT)]
    return {f"tenant{t}": [Request(uid=t * 100 + j, prompt=p.copy(),
                                   max_new=MAX_NEW)
                           for j, p in enumerate(prompts)]
            for t in range(N_TENANTS)}


def run():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)

    def go(n_parts, placement, admission, quota):
        return run_partitioned(
            params, cfg, _workloads(cfg), n_partitions=n_parts,
            placement=placement, admission=admission, quota=quota,
            batch_slots=SLOTS, max_len=96, rt=RT)

    # untimed warmup: prefill/decode compilation must not land in the
    # first measured cell (all cells share the jitted-step cache)
    go(1, "packed", "fifo", "static")

    print(f"# fig18: sweeping {len(SWEEP)} of 3x3x2x{len((1, 2, 4))} "
          "cells (placement variants that route identically on this "
          "balanced workload are cut)")
    out = []
    cells = {}
    for (n_parts, placement, admission, quota) in SWEEP:
        rep = go(n_parts, placement, admission, quota)
        p99 = max((t.p99_latency_s for part in rep.partitions
                   for t in part.tenants), default=0.0)
        derived = {
            "fairness": round(rep.fairness, 4),
            "cv": round(rep.cv, 4),
            "tokens": rep.tokens_out,
            "steps": rep.steps,
            "tok_per_step": round(rep.tokens_out
                                  / max(1, rep.steps), 3),
            "tok_per_s": round(rep.tokens_out
                               / max(rep.wall_s, 1e-9), 1),
            "p99_latency_ms": round(p99 * 1e3, 2),
            "partitions": n_parts,
            "slots_per_partition": SLOTS}
        cells[f"p{n_parts}-{placement}-{admission}-{quota}"] = derived
        out.append(Record(
            name=f"fig18/serving/p{n_parts}/{placement}/"
                 f"{admission}-{quota}",
            us_per_call=rep.wall_s * 1e6,
            derived=derived))
    summary = {"figure": "fig18_partitioned_serving",
               "n_tenants": N_TENANTS, "slots_per_partition": SLOTS,
               "cells": cells}
    stamp(summary, "fig18_partitioned_serving")
    BENCH_PATH.write_text(json.dumps(summary, indent=2) + "\n")
    return out
