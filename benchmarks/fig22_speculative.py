"""Fig 22 (beyond-paper) — speculative multi-token decoding:
FP8/sparse24 draft + bf16 verify, greedy-exact.

The paper's FP8 and 2:4-sparsity case studies price the *kernels*; this
figure prices the *execution structure* that converts cheap low-precision
compute into end-to-end serving throughput without touching output
quality. A draft chain proposes ``k - 1`` tokens under an fp8 (or
``fp8:sparse24``) execution policy, one batched bf16 verify pass scores
all ``k`` candidate positions, and the longest argmax-matching prefix
commits — so every arm below is token-for-token identical to plain
greedy decode (asserted in-benchmark), and the only thing speculation
changes is how many exact tokens land per scheduler step.

Sweep: ``k ∈ {1, 2, 4}`` × draft policy ∈ {fp8, fp8:sparse24} on an
accept-friendly (repetitive-prompt) workload, plus one random-prompt arm
that shows what acceptance does on a draft-hostile stream (tracked, not
asserted). ``k = 1`` is the kill switch — drafting disabled, the plain
decode path — and is the baseline of the headline assert:

* every arm's tokens == plain greedy tokens (``tokens_equal``);
* best-arm effective tokens/step ≥ 1.2× the k=1 baseline;
* per-tenant acceptance rate > 0 on every drafting arm.

Sessions run *paged* (page_size 8) so the sweep also exercises the
speculative page growth (k candidate positions per step) and post-verify
trim path. Writes ``BENCH_fig22.json`` (third perf-trajectory point
after fig20/fig21); CI gates acceptance rate and effective tokens/step
via ``benchmarks/trajectory.py``.
"""
import json
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import stamp
from repro.configs import get_reduced
from repro.core.characterization import Record
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime.serve_loop import Request
from repro.runtime.server import PartitionSpec, ServingRuntime, ServingSpec

RT = RuntimeCfg(ssm_chunk=16)
SLOTS = 2
MAX_LEN = 64
PAGE = 8
MAX_NEW = 16
REQS_PER_TENANT = 2
TENANTS = ("t0", "t1")

# (arm name, SpecDecodeSpec as dict / int / None)
ARMS = (
    ("plain", None),                     # speculative machinery absent
    ("k1", 1),                           # kill switch: drafting disabled
    ("k2_fp8", {"k": 2, "draft_policy": "fp8"}),
    ("k4_fp8", {"k": 4, "draft_policy": "fp8"}),
    ("k2_fp8_sp24", {"k": 2, "draft_policy": "fp8:sparse24"}),
    ("k4_fp8_sp24", {"k": 4, "draft_policy": "fp8:sparse24"}),
)
HEADLINE = "k4_fp8"
BASELINE = "k1"

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_fig22.json"


def _workload(cfg, accept_friendly: bool):
    """tenant -> [Request]. The accept-friendly stream repeats a short
    token pattern — the attractor the greedy model locks onto, which the
    fp8 draft then predicts — while the hostile stream is uniform-random
    (the draft disagrees with bf16 argmax near ties)."""
    rng = np.random.default_rng(0)
    out = {}
    for i, t in enumerate(TENANTS):
        reqs = []
        for j in range(REQS_PER_TENANT):
            if accept_friendly:
                a, b = 5 + 2 * i, 9 + 2 * i
                prompt = np.array([a, b] * 4, np.int32)
            else:
                prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
            reqs.append(Request(uid=100 * i + j, prompt=prompt,
                                max_new=MAX_NEW, tenant=t))
        out[t] = reqs
    return out


def _run_arm(params, cfg, speculative, accept_friendly=True):
    spec = ServingSpec(partitions=(PartitionSpec(),), batch_slots=SLOTS,
                       max_len=MAX_LEN, paged=True, page_size=PAGE,
                       speculative=speculative)
    runtime = ServingRuntime(params, cfg, spec, rt=RT)
    for t in TENANTS:
        runtime.add_tenant(t)
    for t, reqs in _workload(cfg, accept_friendly).items():
        for req in reqs:
            runtime.submit(t, req)
    runtime.drain(max_steps=10_000)
    rep = runtime.report()
    toks = {r.uid: list(r.out)
            for sess in runtime.sessions for r in sess.completed}
    return rep, toks


def _arm_summary(rep):
    tenants = {}
    for row in rep.tenants:
        tenants[row.tenant_id] = {
            "acceptance_rate": row.acceptance_rate,
            "effective_tokens_per_step": row.effective_tokens_per_step,
            "spec_steps": row.spec_steps,
            "spec_drafted": row.spec_drafted,
            "spec_accepted": row.spec_accepted,
        }
    drafted = sum(r.spec_drafted for r in rep.tenants)
    accepted = sum(r.spec_accepted for r in rep.tenants)
    return {
        "steps": rep.steps,
        "tokens": rep.tokens_out,
        # step-domain throughput: deterministic (greedy tokens over
        # lockstep scheduler steps), the quantity the 1.2x headline gates
        "tok_per_step": round(rep.tokens_out / max(1, rep.steps), 4),
        "acceptance_rate": round(accepted / drafted, 4) if drafted
        else None,
        "wall_s": round(rep.wall_s, 4),
        "tenants": tenants,
    }


def run():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)

    # untimed warmup: the plain arm compiles prefill + decode once; each
    # speculative arm then only adds its own draft/verify traces
    _run_arm(params, cfg, None)

    arms = {}
    toks = {}
    for name, spec in ARMS:
        rep, tk = _run_arm(params, cfg, spec)
        arms[name] = _arm_summary(rep)
        toks[name] = tk

    # exactness contract: every arm, drafting or not, emits the plain
    # greedy stream token-for-token
    for name in arms:
        assert toks[name] == toks["plain"], \
            f"{name} arm diverged from plain greedy decode"
    for name, spec in ARMS:
        if name in ("plain", BASELINE):
            continue
        acc = arms[name]["acceptance_rate"]
        assert acc is not None and acc > 0, \
            f"{name}: no drafts accepted (acceptance_rate={acc})"

    base = arms[BASELINE]["tok_per_step"]
    head = arms[HEADLINE]["tok_per_step"]
    eff_speedup = head / max(base, 1e-9)
    assert eff_speedup >= 1.2, \
        f"{HEADLINE} effective tokens/step {head:.3f} < 1.2x the " \
        f"{BASELINE} baseline {base:.3f} (ratio {eff_speedup:.3f})"

    # draft-hostile stream: same sweep point, random prompts — reported
    # so the trajectory shows what acceptance-rate collapse looks like
    hostile_rep, hostile_toks = _run_arm(params, cfg,
                                         {"k": 4, "draft_policy": "fp8"},
                                         accept_friendly=False)
    _, hostile_plain = _run_arm(params, cfg, None, accept_friendly=False)
    assert hostile_toks == hostile_plain, \
        "hostile-workload speculative arm diverged from plain greedy"
    hostile = _arm_summary(hostile_rep)

    summary = {
        "figure": "fig22_speculative",
        "workload": {"tenants": len(TENANTS),
                     "reqs_per_tenant": REQS_PER_TENANT,
                     "max_new": MAX_NEW, "paged": True, "page_size": PAGE},
        "arms": arms,
        "hostile_k4_fp8": hostile,
        "effective_speedup": round(eff_speedup, 4),
        "headline_arm": HEADLINE,
        "baseline_arm": BASELINE,
        "tokens_equal": 1,
    }
    stamp(summary, "fig22_speculative")
    BENCH_PATH.write_text(json.dumps(summary, indent=2) + "\n")

    out = []
    for name, _ in ARMS:
        a = arms[name]
        out.append(Record(
            name=f"fig22/speculative/{name}",
            us_per_call=a["wall_s"] * 1e6,
            derived={"steps": a["steps"], "tokens": a["tokens"],
                     "tok_per_step": a["tok_per_step"],
                     "acceptance_rate": a["acceptance_rate"]}))
    out.append(Record(
        name="fig22/speculative/hostile_k4_fp8",
        us_per_call=hostile["wall_s"] * 1e6,
        derived={"steps": hostile["steps"], "tokens": hostile["tokens"],
                 "tok_per_step": hostile["tok_per_step"],
                 "acceptance_rate": hostile["acceptance_rate"]}))
    out.append(Record(
        name="fig22/equality", us_per_call=0.0,
        derived={"tokens_equal": 1,
                 "effective_speedup": round(eff_speedup, 4)}))
    return out
