"""Shared benchmark utilities. Every benchmark returns List[Record] and
``benchmarks.run`` prints ``name,us_per_call,derived`` CSV (one per paper
table/figure).

Measured wall-times in this container are CPU-XLA numbers — the harness and
its derived statistics (thresholds, fairness, break-even ratios) are the
reproduction; TPU-target absolutes come from the dry-run roofline
(EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import time
from typing import Callable, List

import jax

from repro.core.characterization import Record

__all__ = ["Record", "time_fn", "emit"]


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def emit(records: List[Record]) -> None:
    for r in records:
        print(r.csv())
