"""Shared benchmark utilities. Every benchmark returns List[Record] and
``benchmarks.run`` prints ``name,us_per_call,derived`` CSV (one per paper
table/figure).

Measured wall-times in this container are CPU-XLA numbers — the harness and
its derived statistics (thresholds, fairness, break-even ratios) are the
reproduction; TPU-target absolutes come from the dry-run roofline
(EXPERIMENTS.md §Roofline).

``run_metadata`` stamps the shared provenance block into every
``BENCH_*.json`` artifact so ``benchmarks/trajectory.py`` can key runs by
(figure, git sha, hardware) and never compare across hardware targets —
the same one-artifact-per-target convention ``REPRO_AUTOTUNE_DIR``
established for autotune stores."""
from __future__ import annotations

import os
import subprocess
import time
from typing import Any, Callable, Dict, List

import jax

from repro.core import concurrency as cc
from repro.core.characterization import Record

__all__ = ["Record", "time_fn", "emit", "hardware_key", "git_sha",
           "run_metadata", "stamp", "BENCH_SCHEMA_VERSION"]

BENCH_SCHEMA_VERSION = 1


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def emit(records: List[Record]) -> None:
    for r in records:
        print(r.csv())


def hardware_key() -> str:
    """One string per hardware target: JAX backend platform + the
    effective core count (``REPRO_N_CORES`` override included). Bench
    trajectories are only comparable within one key."""
    return f"{jax.default_backend()}-c{cc.detect_core_count()}"


def git_sha() -> str:
    """Short commit sha of the working tree ('' outside a checkout).
    CI's ``GITHUB_SHA`` wins over asking git (detached merge refs)."""
    env = os.environ.get("GITHUB_SHA")
    if env:
        return env[:12]
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() if out.returncode == 0 else ""
    except OSError:
        return ""


def run_metadata(figure: str) -> Dict[str, Any]:
    """The shared provenance block every ``BENCH_*.json`` carries."""
    from repro.kernels.registry import available_backends
    return {
        "bench_schema": BENCH_SCHEMA_VERSION,
        "figure": figure,
        "hardware_key": hardware_key(),
        "git_sha": git_sha(),
        "n_cores": cc.detect_core_count(),
        "repro_n_cores_env": os.environ.get("REPRO_N_CORES") or None,
        "backends": sorted(available_backends()),
        "recorded_unix": round(time.time(), 3),
    }


def stamp(summary: Dict[str, Any], figure: str) -> Dict[str, Any]:
    """Attach ``run_metadata`` under ``meta`` (and keep the legacy
    top-level ``figure`` field) on a BENCH summary dict, in place."""
    summary["figure"] = figure
    summary["meta"] = run_metadata(figure)
    return summary
