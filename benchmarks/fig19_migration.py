"""Fig 19 (beyond-paper) — live tenant migration + heterogeneous
per-partition execution policies.

The PR 4 router pinned tenants to their registration-time partition
forever and ran ONE execution policy everywhere. The paper's §5/§6/§7
finding is that the right FP8/sparse24 decision is context-dependent, and
the placement studies (PAPERS.md) argue tenants should follow capacity.
This benchmark runs a load-skewed tenant mix twice through the
``ServingRuntime`` control plane (runtime/server.py):

* **static** — the PR 4 baseline: load_aware registration-time placement,
  uniform bf16 policy, no migration. The flooding tenant shares its
  partition with a latency-sensitive victim for the whole run while a
  spare partition idles.
* **runtime** — heterogeneous per-partition policies (a throughput
  partition on ``fp8:sparse24`` while the latency partitions stay bf16)
  plus live migration: the load_aware re-route path detects the skew,
  freezes the flooding tenant, hands its in-flight request's KV/SSM cache
  state to the idle spare partition mid-stream, and moves its backlog.

Headline asserts (checked by the CI smoke and tests/test_server.py):
≥ 1 live migration fires; every tenant — including the one whose request
crossed partitions mid-flight — is token-for-token equal to its solo run;
victim-population fairness ≥ 0.8 (vs collapse under the static router;
the flood source's self-queued turnaround is reported separately, as in
the fig18 adaptive-quota study); aggregate tokens/step ≥ the static
baseline. Step-domain numbers are deterministic; wall tok/s rides along
(on real hardware the fp8/sparse24 partition also wins wall-clock).

Writes ``BENCH_fig19.json`` so ``benchmarks/trajectory.py`` gates the
handoff behavior across PRs: migrations keep firing, the crossed-stream
token equality holds, and victim fairness / tokens-per-step do not slip.
"""
import json
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import stamp
from repro.configs import get_reduced
from repro.core import execution as ex
from repro.core.characterization import Record
from repro.core.concurrency import fairness
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime.serve_loop import Request, ServeSession
from repro.runtime.server import (
    MigrationSpec, PartitionSpec, ServingRuntime, ServingSpec)

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_fig19.json"

RT = RuntimeCfg(ssm_chunk=16)
SLOTS = 2
MAX_LEN = 64
BF16 = "bf16:dense:jnp"
FP8SP = "fp8:sparse24:jnp"
HOG, VICTIMS = "hog", ("victim", "lat", "thr")
PINS = {"hog": 0, "victim": 0, "lat": 1, "thr": 2}   # partition 3: spare


def _spec(heterogeneous: bool, migrate: bool) -> ServingSpec:
    pols = [BF16, BF16, FP8SP if heterogeneous else BF16, BF16]
    return ServingSpec(
        partitions=tuple(PartitionSpec(policy=p) for p in pols),
        placement="load_aware", batch_slots=SLOTS, max_len=MAX_LEN,
        migration=MigrationSpec(enabled=migrate, interval=4,
                                threshold=2.0, cooldown=16,
                                max_migrations=4))


def _schedule(cfg):
    """step -> [(tenant, Request)]: one tenant floods at step 0, the
    other three trickle identical short requests — the skewed mix."""
    rng = np.random.default_rng(0)
    sched = {}

    def sub(step, tid, uid, max_new):
        prompt = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
        sched.setdefault(step, []).append(
            (tid, Request(uid=uid, prompt=prompt, max_new=max_new)))

    for j in range(8):
        sub(0, HOG, j, 16)
    for i, tid in enumerate(VICTIMS):
        for j in range(4):
            sub(8 * j, tid, 100 * (i + 1) + j, 6)
    return sched


def _drive(runtime: ServingRuntime, schedule):
    last = max(schedule)
    while (runtime.pending() or runtime.n_active or runtime._draining
           or runtime.step_count <= last):
        for tid, req in schedule.get(runtime.step_count, ()):
            runtime.submit(tid, req)
        runtime.step()
        if runtime.step_count > 10_000:
            raise RuntimeError("fig19 run did not drain")


def _run_arm(params, cfg, heterogeneous: bool, migrate: bool):
    runtime = ServingRuntime(params, cfg,
                             _spec(heterogeneous, migrate), rt=RT)
    schedule = _schedule(cfg)
    requests = {}                     # tenant -> [Request] (arrival order)
    for subs in schedule.values():
        for tid, req in subs:
            requests.setdefault(tid, []).append(req)
    for tid, part in PINS.items():
        runtime.add_tenant(tid, partition=part)
    _drive(runtime, schedule)
    return runtime, requests


def _solo_outputs(params, cfg, requests, policy_spec):
    """Each tenant's requests served alone on a fresh session with the
    given policy — the token-equality oracle."""
    sess = ServeSession(params, cfg, batch_slots=SLOTS, max_len=MAX_LEN,
                        rt=RT, policy=ex.parse_policy(policy_spec))
    outs = []
    for req in requests:
        solo = Request(uid=req.uid, prompt=req.prompt.copy(),
                       max_new=req.max_new)
        sess.submit(solo)
        outs.append(solo)
    sess.run()
    return [r.out for r in outs]


def run():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)

    static_rt, _ = _run_arm(params, cfg, heterogeneous=False, migrate=False)
    live_rt, reqs = _run_arm(params, cfg, heterogeneous=True, migrate=True)
    static, live = static_rt.report(), live_rt.report()

    # token-for-token equality vs solo runs: bf16 tenants against a bf16
    # session, the throughput tenant against an fp8/sparse24 session —
    # the migrated tenant's stream crossed partitions mid-request
    equal = {}
    for tid in (HOG, "victim", "lat"):
        solo = _solo_outputs(params, cfg, reqs[tid], BF16)
        equal[tid] = all(r.out == s for r, s in zip(reqs[tid], solo))
    solo = _solo_outputs(params, cfg, reqs["thr"], FP8SP)
    equal["thr"] = all(r.out == s for r, s in zip(reqs["thr"], solo))

    merged = live_rt.merged_tracer()
    decode_pols = {(e.partition, e.policy)
                   for e in merged.events("decode") if e.policy}

    def derived(rep, rt_):
        vic = [t.mean_turnaround_steps for t in rep.tenants
               if t.tenant_id != HOG and t.completed]
        return {
            "fairness": round(rep.fairness, 4),
            "fairness_victims": round(fairness(vic), 4),
            "tokens": rep.tokens_out,
            "steps": rep.steps,
            "tok_per_step": round(rep.tokens_out / max(1, rep.steps), 3),
            "tok_per_s": round(rep.tokens_out / max(rep.wall_s, 1e-9), 1),
            "migrations": rep.migrations,
            "handoffs": sum(m.slots_handed_off for m in rt_.migrations),
            "policies": "|".join(p or "ambient" for p in rep.policies),
        }

    equality = {**{f"{t}_equal": int(v) for t, v in equal.items()},
                "all_equal": int(all(equal.values())),
                "hetero_policies":
                    int(any("fp8" in p for _, p in decode_pols)
                        and any("bf16" in p for _, p in decode_pols))}
    summary = {"figure": "fig19_migration",
               "static": derived(static, static_rt),
               "runtime": derived(live, live_rt),
               "equality": equality}
    stamp(summary, "fig19_migration")
    BENCH_PATH.write_text(json.dumps(summary, indent=2) + "\n")

    out = [
        Record(name="fig19/migration/static", us_per_call=static.wall_s
               * 1e6, derived=derived(static, static_rt)),
        Record(name="fig19/migration/runtime", us_per_call=live.wall_s
               * 1e6, derived=derived(live, live_rt)),
        Record(name="fig19/migration/equality", us_per_call=0.0,
               derived=equality),
    ]
    return out
