"""Run the paper's execution-centric characterization suite (§5–§7
methodology) and print the derived guidance — the microbenchmark workflow
an operator would run on a new TPU slice.

  PYTHONPATH=src python examples/characterize.py
"""
from repro.core import characterization as ch


def main():
    print("== Fig 2: occupancy scaling (normalized to per-precision best) ==")
    occ = ch.occupancy_sweep(tile_counts=(1, 2, 4, 8), tile_m=128,
                             k=256, n=256, iters=3)
    for r in occ:
        print(" ", r.csv())
    th = ch.occupancy_threshold(occ)
    print("90% thresholds (tiles):", th)
    fp8_needs_more = th.get("fp8", 0) >= th.get("bf16", 0)
    print(f"paper-claim check — FP8 needs >= bf16 parallelism to saturate: "
          f"{fp8_needs_more}")

    print("\n== Fig 3: shape sensitivity ==")
    for r in ch.shape_sweep(ratios=(0.25, 1.0, 4.0), iters=3):
        print(" ", r.csv())

    print("\n== Table 3: chained tile latency ==")
    for r in ch.latency_probe(tile_shapes=((128, 128, 128), (256, 256, 128)),
                              chain=8, iters=3):
        print(" ", r.csv())

    print("\n== Fig 6-8: contention ==")
    for r in ch.contention_sweep(stream_counts=(1, 2, 4), iters=2):
        print(" ", r.csv())


if __name__ == "__main__":
    main()
