"""Quickstart: build a model from the zoo, run FP8 forward + one train step.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import forward, init_params
from repro.models.layers import RuntimeCfg
from repro.optim import adamw
from repro.runtime import train_loop as tl


def main():
    # 1. pick an architecture (any of the 10 assigned ids works) and a
    #    technique: FP8 matmuls with f32 accumulation (paper §5)
    cfg = dataclasses.replace(get_reduced("llama3-8b"), precision="fp8")
    rt = RuntimeCfg(chunk_q=64, chunk_kv=64, ssm_chunk=32)

    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                              cfg.vocab_size)

    # 2. forward
    logits, _ = jax.jit(lambda p, t: forward(p, t, cfg, rt))(params, toks)
    print("logits:", logits.shape, "finite:",
          bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all()))

    # 3. one training step (AdamW + f32 master weights)
    opt_cfg = adamw.AdamWConfig(total_steps=100)
    state = tl.init_state(params, opt_cfg)
    step = jax.jit(tl.make_train_step(cfg, opt_cfg, rt))
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0,
                                cfg.vocab_size)
    state, metrics = step(state, {"inputs": toks, "labels": labels})
    print("loss:", float(metrics["loss"]), "grad_norm:",
          float(metrics["grad_norm"]))


if __name__ == "__main__":
    main()
