"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps with FP8 matmuls, checkpointing, and straggler monitoring.

  PYTHONPATH=src python examples/train_fp8.py [--steps 200]

(This wraps the production launcher — launch/train.py — with a ~100M
config; on a TPU pod the identical launcher trains the full configs.)
"""
import argparse
import dataclasses
import sys

from repro.configs.base import ArchConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_fp8_ckpt")
    args = ap.parse_args()

    # ~100M params: 12L d=512 llama-style
    cfg = ArchConfig(
        name="llama-100m-fp8", family="dense", num_layers=12, d_model=512,
        d_ff=2048, vocab_size=32000, num_heads=8, num_kv_heads=4,
        head_dim=64, precision="fp8", attn_strategy="head_tp")
    print(f"[example] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"precision={cfg.precision}")

    import repro.configs as configs
    configs.ARCHS[cfg.name] = cfg
    configs.REDUCED[cfg.name] = cfg

    from repro.launch.train import build_argparser, run_once
    targs = build_argparser().parse_args([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--checkpoint-dir", args.checkpoint_dir,
        "--checkpoint-every", "50", "--log-every", "10",
    ])
    return run_once(targs)


if __name__ == "__main__":
    sys.exit(main())
