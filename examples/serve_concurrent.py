"""Serve a small model with batched concurrent requests (continuous
batching), comparing dense vs 2:4-sparse weights, then run the same
workload as FOUR TENANTS through the fairness-aware StreamScheduler and
compare admission policies — the paper's fairness-collapse result (Fig 5)
reproduced at the serving layer, plus the §9.2 fix. Then the same four
tenants run through the serving CONTROL PLANE (runtime/server.py): one
ServingRuntime built from a declarative ServingSpec — 2 spatial
partitions, load-aware placement, telemetry-driven adaptive quotas — the
§9.2 "prefer sub-mesh isolation" guidance as a working server. Finally a
LIVE MIGRATION demo: heterogeneous per-partition policies (bf16 next to
fp8/sparse24) with a flooding tenant re-routed mid-request, its KV/SSM
cache state handed off between partitions.

  PYTHONPATH=src python examples/serve_concurrent.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.concurrency import OccupancyAdvisor, WorkloadProfile
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime.scheduler import run_tenants
from repro.runtime.serve_loop import Request, ServeSession
from repro.runtime.server import (
    MigrationSpec, PartitionSpec, ServingRuntime, ServingSpec, run_serving)

RT = RuntimeCfg(ssm_chunk=16)


def serve(cfg, params, label, n_requests=6):
    sess = ServeSession(params, cfg, batch_slots=4, max_len=96, rt=RT)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(n_requests):
        sess.submit(Request(uid=uid,
                            prompt=rng.integers(0, cfg.vocab_size, 4)
                            .astype(np.int32),
                            max_new=8))
    done = sess.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[{label}] {len(done)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s")
    return toks / dt


def multi_tenant(cfg, params, n_tenants=4, reqs_per_tenant=2, slots=2):
    """Same total workload, three admission policies: fifo collapses
    per-tenant fairness (the paper's shared-queue result), fair_quantum
    restores it."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(reqs_per_tenant)]
    for admission in ("fifo", "round_robin", "fair_quantum"):
        sess = ServeSession(params, cfg, batch_slots=slots, max_len=96,
                            rt=RT)
        workloads = {
            f"tenant{i}": [Request(uid=i * 100 + j, prompt=p.copy(),
                                   max_new=8)
                           for j, p in enumerate(prompts)]
            for i in range(n_tenants)}
        rep = run_tenants(sess, workloads, admission=admission)
        print(rep.summary())


def control_plane(cfg, params, n_tenants=4, reqs_per_tenant=2, slots=2):
    """The same four tenants on 1 shared-FIFO partition vs 2 partitions
    with load-aware placement + adaptive quotas — now expressed as two
    declarative ServingSpecs driving one ServingRuntime each: single-
    queue fairness collapse vs partition-local isolation, fused into one
    report. (The old PartitionedServer facade still works as a deprecated
    shim; see docs/serving_api.md for the migration guide.)"""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(reqs_per_tenant)]

    def workloads():
        return {f"tenant{i}": [Request(uid=i * 100 + j, prompt=p.copy(),
                                       max_new=8)
                               for j, p in enumerate(prompts)]
                for i in range(n_tenants)}

    for spec in (
            ServingSpec(partitions=(PartitionSpec(admission="fifo"),),
                        placement="packed", batch_slots=slots, max_len=96),
            ServingSpec(partitions=tuple(
                PartitionSpec(admission="fair_quantum", quota="adaptive")
                for _ in range(2)),
                placement="load_aware", batch_slots=slots, max_len=96)):
        rep = run_serving(params, cfg, spec, workloads(), rt=RT)
        print(rep.summary())


def migration(cfg, params, slots=2):
    """Live tenant migration under heterogeneous policies: a flooding
    tenant shares a bf16 partition with a latency tenant while a spare
    bf16 partition idles and an fp8/sparse24 partition serves throughput
    traffic. The load_aware re-route path detects the skew and moves the
    flooder — including the in-flight request's KV/SSM cache state —
    onto the spare partition."""
    spec = ServingSpec(
        partitions=(PartitionSpec(policy="bf16:dense:jnp"),
                    PartitionSpec(policy="fp8:sparse24:jnp"),
                    PartitionSpec(policy="bf16:dense:jnp")),
        placement="load_aware", batch_slots=slots, max_len=96,
        migration=MigrationSpec(enabled=True, interval=4, threshold=2.0,
                                cooldown=8))
    runtime = ServingRuntime(params, cfg, spec, rt=RT)
    rng = np.random.default_rng(0)

    def req(uid, max_new):
        return Request(uid=uid, prompt=rng.integers(
            0, cfg.vocab_size, 4).astype(np.int32), max_new=max_new)

    runtime.add_tenant("flood", partition=0)
    runtime.add_tenant("latency", partition=0)
    runtime.add_tenant("throughput", partition=1)
    for i in range(6):
        runtime.submit("flood", req(i, 12))
    runtime.submit("latency", req(100, 6))
    runtime.submit("throughput", req(200, 8))
    runtime.drain()
    rep = runtime.report()
    print(rep.summary())
    for m in runtime.migrations:
        print(f"  [migrate] {m.tenant}: p{m.src}->p{m.dst} at step "
              f"{m.start_step} ({m.queued_moved} queued, "
              f"{m.slots_handed_off} live handoffs), done at step "
              f"{m.done_step}")


def speculative(cfg, params, slots=2):
    """Speculative multi-token decoding: an fp8 draft chain proposes k-1
    tokens, one bf16 verify pass scores all k positions and commits the
    longest matching prefix — greedy output provably identical to plain
    decode, so the comparison below is tokens-per-step, not quality."""
    rng = np.random.default_rng(0)
    prompts = [np.array([5 + 2 * i, 9 + 2 * i] * 3, np.int32)
               for i in range(4)]

    def workloads():
        return {f"tenant{i}": [Request(uid=i * 100, prompt=p.copy(),
                                       max_new=12)]
                for i, p in enumerate(prompts)}

    outs = {}
    for label, spec_arg in (("plain", None),
                            ("spec k=4 fp8", 4),
                            ("spec k=4 fp8 adaptive",
                             {"k": 4, "adaptive": True})):
        spec = ServingSpec(partitions=(PartitionSpec(),),
                           batch_slots=slots, max_len=96,
                           speculative=spec_arg)
        rep = run_serving(params, cfg, spec, workloads(), rt=RT)
        outs[label] = rep
        rows = [t for t in rep.tenants if t.effective_tokens_per_step]
        eff = (f", eff {np.mean([t.effective_tokens_per_step for t in rows]):.2f} tok/step"
               f", accept {np.mean([t.acceptance_rate for t in rows]):.0%}"
               if rows else "")
        print(f"[{label}] {rep.tokens_out} tokens in {rep.steps} steps"
              f" ({rep.tokens_out / max(1, rep.steps):.2f} tok/step{eff})")


def main():
    base = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), base)

    # paper §9.2: ask the advisor whether to enable sparsity for this context
    advisor = OccupancyAdvisor(n_cores=1)   # CPU demo: 1 "core"
    advice = advisor.advise(WorkloadProfile(
        precision="bf16", grid_tiles=4, latency_sensitive=True,
        concurrent_tenants=4))
    print("[advisor]", "; ".join(advice.rationale))

    serve(base, params, "dense")
    if advice.use_sparsity:
        sparse_cfg = dataclasses.replace(base, sparsity_24=True)
        serve(sparse_cfg, init_params(jax.random.PRNGKey(0), sparse_cfg),
              "2:4-sparse")

    print("\n-- multi-tenant admission policies (4 tenants, 2 slots) --")
    multi_tenant(base, params)

    print("\n-- serving control plane (1x fifo vs 2x load_aware+adaptive) --")
    control_plane(base, params)

    print("\n-- live migration + heterogeneous per-partition policies --")
    migration(base, params)

    print("\n-- speculative decoding (fp8 draft + bf16 verify, exact) --")
    speculative(base, params)


if __name__ == "__main__":
    main()
