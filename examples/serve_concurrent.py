"""Serve a small model with batched concurrent requests (continuous
batching), comparing dense vs 2:4-sparse weights, then run the same
workload as FOUR TENANTS through the fairness-aware StreamScheduler and
compare admission policies — the paper's fairness-collapse result (Fig 5)
reproduced at the serving layer, plus the §9.2 fix. Finally the same four
tenants run through the PARTITIONED serving runtime (2 spatial
partitions, load-aware placement, telemetry-driven adaptive quotas) — the
§9.2 "prefer sub-mesh isolation" guidance as a working server.

  PYTHONPATH=src python examples/serve_concurrent.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.concurrency import OccupancyAdvisor, WorkloadProfile
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime.partition import run_partitioned
from repro.runtime.scheduler import run_tenants
from repro.runtime.serve_loop import Request, ServeSession

RT = RuntimeCfg(ssm_chunk=16)


def serve(cfg, params, label, n_requests=6):
    sess = ServeSession(params, cfg, batch_slots=4, max_len=96, rt=RT)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(n_requests):
        sess.submit(Request(uid=uid,
                            prompt=rng.integers(0, cfg.vocab_size, 4)
                            .astype(np.int32),
                            max_new=8))
    done = sess.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[{label}] {len(done)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s")
    return toks / dt


def multi_tenant(cfg, params, n_tenants=4, reqs_per_tenant=2, slots=2):
    """Same total workload, three admission policies: fifo collapses
    per-tenant fairness (the paper's shared-queue result), fair_quantum
    restores it."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(reqs_per_tenant)]
    for admission in ("fifo", "round_robin", "fair_quantum"):
        sess = ServeSession(params, cfg, batch_slots=slots, max_len=96,
                            rt=RT)
        workloads = {
            f"tenant{i}": [Request(uid=i * 100 + j, prompt=p.copy(),
                                   max_new=8)
                           for j, p in enumerate(prompts)]
            for i in range(n_tenants)}
        rep = run_tenants(sess, workloads, admission=admission)
        print(rep.summary())


def partitioned(cfg, params, n_tenants=4, reqs_per_tenant=2, slots=2):
    """The same four tenants on 1 shared-FIFO partition vs 2 partitions
    with load-aware placement + adaptive quotas: single-queue fairness
    collapse vs partition-local isolation, fused into one report."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
               for _ in range(reqs_per_tenant)]

    def workloads():
        return {f"tenant{i}": [Request(uid=i * 100 + j, prompt=p.copy(),
                                       max_new=8)
                               for j, p in enumerate(prompts)]
                for i in range(n_tenants)}

    for n_parts, placement, admission, quota in (
            (1, "packed", "fifo", "static"),
            (2, "load_aware", "fair_quantum", "adaptive")):
        rep = run_partitioned(params, cfg, workloads(),
                              n_partitions=n_parts, placement=placement,
                              admission=admission, quota=quota,
                              batch_slots=slots, max_len=96, rt=RT)
        print(rep.summary())


def main():
    base = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), base)

    # paper §9.2: ask the advisor whether to enable sparsity for this context
    advisor = OccupancyAdvisor(n_cores=1)   # CPU demo: 1 "core"
    advice = advisor.advise(WorkloadProfile(
        precision="bf16", grid_tiles=4, latency_sensitive=True,
        concurrent_tenants=4))
    print("[advisor]", "; ".join(advice.rationale))

    serve(base, params, "dense")
    if advice.use_sparsity:
        sparse_cfg = dataclasses.replace(base, sparsity_24=True)
        serve(sparse_cfg, init_params(jax.random.PRNGKey(0), sparse_cfg),
              "2:4-sparse")

    print("\n-- multi-tenant admission policies (4 tenants, 2 slots) --")
    multi_tenant(base, params)

    print("\n-- partitioned serving (1x fifo vs 2x load_aware+adaptive) --")
    partitioned(base, params)


if __name__ == "__main__":
    main()
