"""Serve a small model with batched concurrent requests (continuous
batching), comparing dense vs 2:4-sparse weights and reporting the paper's
fairness/overlap metrics for the decode streams.

  PYTHONPATH=src python examples/serve_concurrent.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.concurrency import OccupancyAdvisor, WorkloadProfile
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime.serve_loop import Request, ServeSession


def serve(cfg, label, n_requests=6):
    params = init_params(jax.random.PRNGKey(0), cfg)
    sess = ServeSession(params, cfg, batch_slots=4, max_len=96,
                        rt=RuntimeCfg(ssm_chunk=16))
    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(n_requests):
        sess.submit(Request(uid=uid,
                            prompt=rng.integers(0, cfg.vocab_size, 4)
                            .astype(np.int32),
                            max_new=8))
    done = sess.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[{label}] {len(done)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s")
    return toks / dt


def main():
    base = get_reduced("llama3-8b")

    # paper §9.2: ask the advisor whether to enable sparsity for this context
    advisor = OccupancyAdvisor(n_cores=1)   # CPU demo: 1 "core"
    advice = advisor.advise(WorkloadProfile(
        precision="bf16", grid_tiles=4, latency_sensitive=True,
        concurrent_tenants=4))
    print("[advisor]", "; ".join(advice.rationale))

    serve(base, "dense")
    if advice.use_sparsity:
        sparse_cfg = dataclasses.replace(base, sparsity_24=True)
        serve(sparse_cfg, "2:4-sparse")


if __name__ == "__main__":
    main()
