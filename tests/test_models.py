"""Per-architecture smoke tests: REDUCED config of each assigned family runs
one forward + one train step + one decode step on CPU; shapes and finiteness
asserted (assignment requirement (f))."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, REDUCED, get_reduced
from repro.models import (
    decode_step, forward, init_cache, init_params, prefill)
from repro.models.layers import RuntimeCfg

RT = RuntimeCfg(chunk_q=32, chunk_kv=32, ssm_chunk=16)
B, S = 2, 64


def _inputs(cfg, key):
    if cfg.input_mode == "embeddings":
        return jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_reduced(arch)
    params = init_params(key, cfg)
    logits, aux = jax.jit(lambda p, x: forward(p, x, cfg, RT))(
        params, _inputs(cfg, key))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    assert bool(jnp.isfinite(aux))
    # padded vocab entries masked
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e29


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_no_nans(arch, key):
    from repro.optim import adamw
    from repro.runtime import train_loop as tl
    cfg = get_reduced(arch)
    params = init_params(key, cfg)
    opt_cfg = adamw.AdamWConfig(total_steps=10, warmup_steps=2)
    state = tl.init_state(params, opt_cfg)
    step = jax.jit(tl.make_train_step(cfg, opt_cfg, RT))
    batch = {"inputs": _inputs(cfg, key),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    for path, leaf in jax.tree_util.tree_flatten_with_path(state.params)[0]:
        assert bool(jnp.isfinite(leaf).all()), f"non-finite param {path}"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_step_shapes(arch, key):
    cfg = get_reduced(arch)
    params = init_params(key, cfg)
    cache = init_cache(cfg, B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, t, c: decode_step(p, t, c, 3, cfg, RT))(params, tok, cache)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    assert jax.tree_util.tree_structure(new_cache) \
        == jax.tree_util.tree_structure(cache)


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma3-12b", "zamba2-1.2b",
                                  "rwkv6-3b"])
def test_prefill_then_decode_consistency(arch, key):
    """Greedy next token from prefill logits == from step-by-step decode."""
    cfg = get_reduced(arch)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    pre_logits, _ = jax.jit(lambda p, x: prefill(p, x, cfg, RT))(params, toks)

    cache = init_cache(cfg, B, S + 1)
    logits = None
    for t in range(S):
        logits, cache = decode_step(params, toks[:, t:t + 1], cache, t, cfg,
                                    RT)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32)[:, :cfg.vocab_size],
        np.asarray(pre_logits, np.float32)[:, :cfg.vocab_size],
        rtol=0.15, atol=0.3)
    # the argmax (what sampling consumes) must agree
    assert (np.argmax(np.asarray(logits)[:, :cfg.vocab_size], -1)
            == np.argmax(np.asarray(pre_logits)[:, :cfg.vocab_size], -1)).all()


@pytest.mark.parametrize("technique", ["fp8", "sparsity"])
def test_techniques_run_on_transformer(technique, key):
    """The paper's two weight techniques swap into the model unchanged."""
    cfg = get_reduced("llama3-8b")
    cfg = dataclasses.replace(
        cfg, precision="fp8" if technique == "fp8" else "bf16",
        sparsity_24=technique == "sparsity")
    params = init_params(key, cfg)
    logits, _ = jax.jit(lambda p, x: forward(p, x, cfg, RT))(
        params, _inputs(cfg, key))
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())


def test_param_count_matches_params(key):
    for arch in ("llama3-8b", "rwkv6-3b", "granite-moe-3b-a800m",
                 "zamba2-1.2b"):
        cfg = get_reduced(arch)
        params = init_params(key, cfg)
        actual = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(params))
        pad = (cfg.padded_vocab - cfg.vocab_size) * cfg.d_model * 2
        expected = cfg.param_count() + pad
        assert abs(actual - expected) / expected < 0.02, \
            (arch, actual, expected)
