"""Sharding policy tests: every sharded dim divides its mesh axes, for all
10 architectures × both production mesh shapes — the static guarantee that
makes the 512-chip dry-run compile. Uses a lightweight mesh stand-in (specs
are pure functions of axis sizes; no devices needed)."""
import types

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, ARCHS, SHAPES, applicable_shapes
from repro.models import cache_shape, params_shape
from repro.runtime import sharding as sh


class FakeMesh:
    def __init__(self, shape_dict):
        self.shape = dict(shape_dict)
        self.axis_names = tuple(shape_dict)
        self.size = 1
        for v in shape_dict.values():
            self.size *= v


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_divisibility(spec_tree, shape_tree, mesh, what):
    specs = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    shapes = jax.tree_util.tree_leaves(shape_tree)
    assert len(specs) == len(shapes)
    for spec, leaf in zip(specs, shapes):
        for i, axes in enumerate(tuple(spec)):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert leaf.shape[i] % n == 0, (
                f"{what}: dim {i} of {leaf.shape} not divisible by "
                f"{axes}={n} (spec {spec})")


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_specs_divisible(arch, mesh):
    cfg = ARCHS[arch]
    pshape = params_shape(cfg)
    specs = sh.param_specs(cfg, mesh, pshape)
    _check_divisibility(specs, pshape, mesh, f"{arch} params")


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_cache_specs_divisible(arch, mesh):
    cfg = ARCHS[arch]
    for shape in applicable_shapes(cfg):
        if shape.kind != "decode":
            continue
        cshape = cache_shape(cfg, shape.global_batch, shape.seq_len)
        specs = sh.cache_specs(cfg, shape, mesh, cshape)
        _check_divisibility(specs, cshape, mesh,
                            f"{arch}/{shape.name} cache")


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_large_params_are_sharded(arch):
    """No parameter leaf > 1 GiB may be fully replicated on the single-pod
    mesh (16 GiB HBM budget discipline)."""
    cfg = ARCHS[arch]
    pshape = params_shape(cfg)
    specs = sh.param_specs(cfg, SINGLE, pshape)
    leaves = zip(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)),
        jax.tree_util.tree_leaves_with_path(pshape))
    for spec, (path, leaf) in leaves:
        import numpy as np
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if nbytes > 2 ** 30:
            assert any(ax is not None for ax in tuple(spec)), \
                f"{arch}: {jax.tree_util.keystr(path)} {leaf.shape} " \
                f"({nbytes/2**30:.1f} GiB) fully replicated"


def _axes(entry):
    """normalize a PartitionSpec entry to a tuple of axis names"""
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def test_input_and_logits_specs():
    cfg = ARCHS["llama3-8b"]
    tr = SHAPES["train_4k"]
    assert _axes(tuple(sh.input_spec(cfg, tr, SINGLE))[0]) == ("data",)
    dec = SHAPES["decode_32k"]
    ls = sh.logits_spec(cfg, dec, SINGLE)
    assert _axes(tuple(ls)[0]) == ("data",)
    # b=1 long-context: batch unshardable -> None
    long = SHAPES["long_500k"]
    assert _axes(tuple(sh.input_spec(ARCHS["rwkv6-3b"], long, SINGLE))[0]) == ()


def test_embeddings_input_spec():
    cfg = ARCHS["musicgen-medium"]
    tr = SHAPES["train_4k"]
    spec = sh.input_spec(cfg, tr, SINGLE)
    assert len(tuple(spec)) == 3          # (B, S, d) embeddings input


def test_moe_expert_sharding_split():
    """llama4 (16e): expert-parallel on model; granite (40e): per-expert ffn
    sharded instead."""
    l4 = ARCHS["llama4-scout-17b-a16e"]
    specs = sh.param_specs(l4, SINGLE, params_shape(l4))
    moe_spec = specs["layers"]["b0"]["moe"]["w_gate"]
    assert tuple(moe_spec)[1] == "model"      # (layers, E, d, f): E on model
    gr = ARCHS["granite-moe-3b-a800m"]
    specs = sh.param_specs(gr, SINGLE, params_shape(gr))
    moe_spec = specs["layers"]["b0"]["moe"]["w_gate"]
    assert tuple(moe_spec)[1] is None         # E=40 not divisible
    assert tuple(moe_spec)[3] == "model"      # per-expert d_ff sharded
