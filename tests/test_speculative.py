"""Speculative multi-token decoding: the greedy-exactness contract, the
rejected-write rollback, acceptance telemetry, and the adaptive depth
controller.

The whole feature leans on one invariant: a speculative session commits
*exactly* the plain greedy stream — solo, multi-tenant, across a live
migration handoff, paged and dense — and a rejected draft leaves no
trace in the cache (the slot-scrub discipline of ``tests/test_paging.py``
applied per-step instead of per-slot).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.speculative import AdaptiveK, SpecDecodeSpec
from repro.models import init_params
from repro.models import transformer as tf
from repro.models.layers import RuntimeCfg
from repro.runtime.scheduler import run_tenants
from repro.runtime.serve_loop import Request, ServeSession
from repro.runtime.telemetry import Tracer

RT = RuntimeCfg(ssm_chunk=16)
MAX_LEN = 64
PAGE = 8


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _session(model, *, slots=2, paged=False, speculative=None, **kw):
    cfg, params = model
    if paged:
        kw.setdefault("page_size", PAGE)
    return ServeSession(params, cfg, batch_slots=slots, max_len=MAX_LEN,
                        rt=RT, paged=paged, speculative=speculative, **kw)


def _prompts(cfg, n, length=6, seed=0, repetitive=False):
    rng = np.random.default_rng(seed)
    if repetitive:   # accept-friendly: the attractor the draft predicts
        return [np.array([5 + 2 * i, 9 + 2 * i] * (length // 2),
                         np.int32) for i in range(n)]
    return [rng.integers(0, cfg.vocab_size, length).astype(np.int32)
            for _ in range(n)]


def _run_all(sess, prompts, max_new=8, tenants=None):
    reqs = [Request(uid=i, prompt=p.copy(), max_new=max_new,
                    tenant=tenants[i] if tenants else "")
            for i, p in enumerate(prompts)]
    for r in reqs:
        sess.submit(r)
    sess.run()
    return [list(r.out) for r in reqs]


def _pool_leaves(sess):
    for blk, leaves in sess.caches["layers"].items():
        pos = leaves.get("pos")
        if pos is not None and pos.ndim == 3 \
                and pos.shape[1] == sess.pages + 1 \
                and pos.shape[2] == sess.page_size:
            yield blk, leaves


# ---------------------------------------------------------------------------
# The exactness contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [2, 4,
                                  {"k": 4, "draft_policy": "fp8:sparse24"}])
def test_spec_equals_plain_solo_dense(model, spec):
    cfg, _ = model
    prompts = _prompts(cfg, 1, repetitive=True)
    ref = _run_all(_session(model, slots=1), [p.copy() for p in prompts])
    out = _run_all(_session(model, slots=1, speculative=spec), prompts)
    assert out == ref


def test_spec_equals_plain_multi_tenant_paged(model):
    cfg, _ = model
    # mixed stream: accept-friendly + hostile prompts sharing the batch,
    # so acceptance differs per slot within a single verify step
    prompts = _prompts(cfg, 2, repetitive=True) + _prompts(cfg, 2, seed=3)
    tenants = ["a", "b", "a", "b"]
    ref = _run_all(_session(model, slots=2, paged=True),
                   [p.copy() for p in prompts], tenants=tenants)
    out = _run_all(_session(model, slots=2, paged=True, speculative=4),
                   prompts, tenants=tenants)
    assert out == ref


def test_k1_kill_switch_is_plain_path(model):
    """``k = 1`` disables drafting: the plain jitted step runs (same rng
    stream, bit-identical) and no speculative telemetry is recorded."""
    cfg, _ = model
    prompts = _prompts(cfg, 2, seed=1)
    sess = _session(model, speculative=1)
    for i, p in enumerate(prompts):
        sess.submit(Request(uid=i, prompt=p.copy(), max_new=6))
    sess._admit_from_queue()
    ticket = sess.dispatch_decode()
    assert ticket.spec_k == 1 and ticket.draft_handle is None
    sess.join_decode(ticket)
    sess.run()
    assert sess.spec_totals == {}
    ref = _run_all(_session(model), [p.copy() for p in prompts], max_new=6)
    assert [list(r.out) for r in sess.completed] == ref


def test_spec_survives_temperature_refusal(model):
    with pytest.raises(ValueError):
        _session(model, speculative=2, temperature=0.7)


def test_spec_across_migration_handoff(model):
    """Mid-request handoff out of a k=4 speculative session into a k=2
    one: the committed cache is all that moves (drafts are never state),
    and the stream stays token-identical to the uninterrupted plain run."""
    cfg, _ = model
    (p,) = _prompts(cfg, 1, repetitive=True)
    src = _session(model, slots=2, paged=True, speculative=4)
    dst = _session(model, slots=2, paged=True, speculative=2)
    req = Request(uid=7, prompt=p.copy(), max_new=12)
    src.admit(req)
    for _ in range(2):
        src.decode_once()
    assert not req.done
    export = src.export_slot(0)
    dst.import_slot(export)
    while not req.done:
        dst.decode_once()
    ref = Request(uid=8, prompt=p.copy(), max_new=12)
    plain = _session(model, slots=2, paged=True)
    plain.admit(ref)
    while not ref.done:
        plain.decode_once()
    assert req.out == ref.out


# ---------------------------------------------------------------------------
# Rejected-write rollback
# ---------------------------------------------------------------------------

def _prefilled(model, paged):
    """One-slot cache with a short prompt prefilled via a plain session
    (pos > 0 so rollback has history to preserve), plus the step inputs."""
    cfg, _ = model
    sess = _session(model, slots=1, paged=paged)
    (p,) = _prompts(cfg, 1, seed=2)
    sess.admit(Request(uid=0, prompt=p.copy(), max_new=32))
    for _ in range(2):
        sess.decode_once()
    pos = jnp.asarray(sess.slot_pos)
    tok = sess.tokens.astype(jnp.int32)
    pm = sess._page_map if paged else None
    return sess, tok, pos, pm


@pytest.mark.parametrize("paged", [False, True])
def test_all_rejected_step_equals_plain_step(model, paged):
    """Drafts chosen to all mismatch: the multi-token step must leave the
    cache EXACTLY as one plain decode step would — KV appends past the
    accepted position scrubbed (zeros, pos -1), recurrent/window state
    rolled back to the first step's snapshot."""
    cfg, _ = model
    sess, tok, pos, pm = _prefilled(model, paged)
    active = jnp.ones((1,), bool)
    k = 4
    if paged:
        # grow the slot to cover the k candidate positions, as dispatch
        # would, so both runs see the same page table
        sess.pager.extend_slot(0, min(int(pos[0]) + k, MAX_LEN))
        sess._sync_page_map()
        pm = sess._page_map
        logits, plain = tf.paged_decode_step(sess.params, tok, sess.caches,
                                             pos, pm, sess.cfg, sess.rt)
    else:
        logits, plain = tf.decode_step(sess.params, tok, sess.caches, pos,
                                       sess.cfg, sess.rt)
    g0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    bad = (g0 + 1) % cfg.vocab_size          # guaranteed mismatch drafts
    seq = jnp.concatenate([tok] + [bad[:, None]] * (k - 1), axis=1)
    if paged:
        nxt, greedy, n_acc, rolled = tf.paged_multi_decode_step(
            sess.params, seq, sess.caches, pos, active, pm,
            sess.cfg, sess.rt)
    else:
        nxt, greedy, n_acc, rolled = tf.multi_decode_step(
            sess.params, seq, sess.caches, pos, active, sess.cfg, sess.rt)
    assert int(n_acc[0]) == 0
    assert int(nxt[0, 0]) == int(g0[0]) == int(greedy[0, 0])
    mismatched = []

    def cmp(path, a, b):
        a, b = np.asarray(a), np.asarray(b)
        if paged and a.ndim >= 2 and a.shape[1] == sess.pages + 1:
            a, b = a[:, :-1], b[:, :-1]      # trash page is scratch
        if not (a == b).all():
            mismatched.append(jax.tree_util.keystr(path))

    jax.tree_util.tree_map_with_path(cmp, rolled, plain)
    assert not mismatched, f"stale rejected writes in {mismatched}"


def test_idle_slot_untouched_by_verify(model):
    """A free slot (active=False) must behave like plain decode's single
    write, never commit beyond position 0's worth of writes."""
    cfg, _ = model
    sess, tok, pos, _ = _prefilled(model, paged=False)
    active = jnp.zeros((1,), bool)
    seq = jnp.concatenate([tok, tok, tok, tok], axis=1)
    _, _, n_acc, _ = tf.multi_decode_step(sess.params, seq, sess.caches,
                                          pos, active, sess.cfg, sess.rt)
    assert int(n_acc[0]) == 0


def test_spec_pages_trim_and_no_stale_leak(model):
    """Speculative paged decode over-grows k candidate pages per step and
    trims after the verify; after a full drain the pool must be fully
    scrubbed and the LIFO-reused pages must serve the next tenant with
    bit-exact outputs (the test_paging reuse attack, speculative
    edition)."""
    cfg, _ = model
    pa, pb = _prompts(cfg, 2, seed=5)
    sess = _session(model, slots=1, paged=True, speculative=4)
    _run_all(sess, [pa], max_new=10)
    assert sess.pager.pages_in_use == 0
    found = False
    for _, leaves in _pool_leaves(sess):
        found = True
        assert (np.asarray(leaves["pos"])[:, :-1] == -1).all()
        assert (np.asarray(leaves["k"], np.float32)[:, :-1] == 0).all()
        assert (np.asarray(leaves["v"], np.float32)[:, :-1] == 0).all()
    assert found
    (out_b,) = _run_all(sess, [pb], max_new=10)
    (ref_b,) = _run_all(_session(model, slots=1, paged=True),
                        [pb.copy()], max_new=10)
    assert out_b == ref_b


# ---------------------------------------------------------------------------
# Telemetry arithmetic
# ---------------------------------------------------------------------------

def test_acceptance_telemetry_arithmetic(model):
    cfg, _ = model
    sess = _session(model, slots=2, speculative=4, telemetry=Tracer())
    rep = run_tenants(
        sess,
        {"a": [Request(uid=0, prompt=p.copy(), max_new=10, tenant="a")
               for p in _prompts(cfg, 2, repetitive=True)],
         "b": [Request(uid=10, prompt=p.copy(), max_new=10, tenant="b")
               for p in _prompts(cfg, 2, seed=9)]})
    rows = {t.tenant_id: t for t in rep.tenants}
    for tid, tot in sess.spec_totals.items():
        row = rows[tid]
        assert row.spec_steps == tot["steps"] > 0
        assert row.spec_drafted == tot["drafted"] == 3 * tot["steps"]
        assert row.spec_accepted == tot["accepted"] <= tot["drafted"]
        assert row.acceptance_rate == pytest.approx(
            tot["accepted"] / tot["drafted"])
        assert row.effective_tokens_per_step == pytest.approx(
            (tot["accepted"] + tot["steps"]) / tot["steps"])
        assert 1.0 <= row.effective_tokens_per_step <= 4.0
    # the tracer's spec events carry the same totals the session keeps
    ev = [e for e in sess.tracer.events("spec")]
    assert ev, "speculative steps recorded no spec events"
    by_tenant = {}
    for e in ev:
        d = by_tenant.setdefault(e.tenant, {"drafted": 0, "accepted": 0})
        d["drafted"] += e.meta["drafted"]
        d["accepted"] += e.meta["accepted"]
    for tid, d in by_tenant.items():
        assert d["drafted"] == sess.spec_totals[tid]["drafted"]
        assert d["accepted"] == sess.spec_totals[tid]["accepted"]


def test_metrics_sink_folds_spec_events(model):
    from repro.runtime.metrics import MetricsRegistry, MetricsSink
    cfg, _ = model
    reg = MetricsRegistry()
    sess = _session(model, slots=1, speculative=2, telemetry=Tracer())
    MetricsSink(reg).attach(sess.tracer)
    (p,) = _prompts(cfg, 1, repetitive=True)
    _run_all(sess, [p], max_new=8, tenants=["t0"])
    tot = sess.spec_totals["t0"]
    drafted = reg.get("repro_spec_drafted_total").value(tenant="t0")
    accepted = reg.get("repro_spec_accepted_total").value(tenant="t0")
    assert drafted == tot["drafted"] and accepted == tot["accepted"]
    hist = reg.get("repro_spec_committed_tokens").value(tenant="t0")
    assert hist["count"] == tot["steps"]
    assert hist["sum"] == pytest.approx(tot["committed"])


# ---------------------------------------------------------------------------
# Adaptive depth
# ---------------------------------------------------------------------------

def test_adaptive_k_grows_and_shrinks():
    spec = SpecDecodeSpec(k=4, adaptive=True, interval=2, ema_alpha=1.0)
    ak = AdaptiveK(spec)
    assert ak.k == 4
    # sustained rejection walks every tenant down to the floor
    for _ in range(8):
        ak.observe("t", 3, 0)
        ak.on_step()
    assert ak.k == 1
    # sustained acceptance walks it back up to spec.k
    for _ in range(10):
        ak.observe("t", 3, 3)
        ak.on_step()
    assert ak.k == 4
    # the actuated depth is the MIN across tenants sharing the batch
    ak.observe("slow", 3, 0)
    for _ in range(8):
        ak.observe("t", 3, 3)
        ak.observe("slow", 3, 0)
        ak.on_step()
    assert ak.desired["t"] == 4 and ak.desired["slow"] == 1
    assert ak.k == 1
    ak.forget("slow")
    assert ak.k == 4


def test_adaptive_floor_sticky_without_reprobe():
    """Pin the pre-knob behavior: reprobe_interval=0 (the default) keeps
    a floored tenant parked forever — with drafting off no acceptance
    evidence arrives, and the EMA never moves."""
    spec = SpecDecodeSpec(k=4, adaptive=True, interval=2, ema_alpha=1.0)
    assert spec.reprobe_interval == 0
    ak = AdaptiveK(spec)
    for _ in range(8):
        ak.observe("t", 3, 0)
        ak.on_step()
    assert ak.k == 1
    # many evidence-free recalcs later: still parked
    for _ in range(40):
        ak.on_step()
    assert ak.k == 1
    assert ak.reprobes == 0


def test_adaptive_reprobe_retries_the_floor():
    """reprobe_interval=N: after N consecutive recalcs parked at k=1 the
    desired depth retries 2. Recovered acceptance climbs back out;
    sustained rejection falls straight back and re-probes periodically."""
    spec = SpecDecodeSpec(k=4, adaptive=True, interval=2, ema_alpha=1.0,
                          reprobe_interval=3)
    ak = AdaptiveK(spec)
    for _ in range(8):
        ak.observe("t", 3, 0)
        ak.on_step()
    assert ak.k == 1
    # evidence-free recalcs accumulate floor time until the re-probe
    # lifts the depth back to 2 (and no further)
    probes = 0
    while ak.k == 1:
        ak.on_step()
        probes += 1
        assert probes <= 2 * spec.interval * spec.reprobe_interval
    assert ak.k == 2
    assert ak.reprobes == 1
    # the probe finds acceptance recovered -> climbs to max
    for _ in range(8):
        ak.observe("t", 3, 3)
        ak.on_step()
    assert ak.k == 4
    # rejection parks it again... and the probe keeps coming back.
    # (k may read 1 or 2 at any instant depending on the probe phase,
    # but it never climbs while every draft is rejected)
    reprobes_before = ak.reprobes
    for _ in range(10):
        ak.observe("t", 3, 0)
        ak.on_step()
    assert ak.k in (1, 2)
    for _ in range(2 * spec.interval * spec.reprobe_interval):
        ak.on_step()
    assert ak.reprobes > reprobes_before


def test_adaptive_reprobe_capped_by_max_k():
    spec = SpecDecodeSpec(k=1, adaptive=True, interval=1, ema_alpha=1.0,
                          reprobe_interval=1)
    ak = AdaptiveK(spec)
    ak.observe("t", 1, 0)
    ak.ema["t"] = 0.0                   # force a floored record
    for _ in range(5):
        ak.on_step()
    assert ak.k == 1                    # min(2, max_k=1) stays 1
    with pytest.raises(ValueError):
        SpecDecodeSpec(k=2, reprobe_interval=-1)


def test_adaptive_session_actuates_depth(model):
    cfg, _ = model
    sess = _session(model, slots=1,
                    speculative={"k": 4, "adaptive": True})
    assert sess.adaptive_k is not None
    assert sess._next_spec_k() == 4
    sess.adaptive_k.k = 1                 # controller hit the floor
    assert sess._next_spec_k() == 1
    (p,) = _prompts(cfg, 1, repetitive=True)
    sess.submit(Request(uid=0, prompt=p.copy(), max_new=4))
    sess._admit_from_queue()
    ticket = sess.dispatch_decode()
    assert ticket.spec_k == 1             # plain path while floored
    sess.join_decode(ticket)
    sess.run()
    assert sess.adaptive_k.steps > 0      # on_step ticked on plain joins


def test_adaptive_off_by_default(model):
    assert _session(model, speculative=4).adaptive_k is None


# ---------------------------------------------------------------------------
# Jit cache keys (the satellite regression: speculative geometry must key
# the cache — and nothing else about ServingSpec changes traced shapes
# without already being in a key)
# ---------------------------------------------------------------------------

def test_jit_keys_split_by_spec_geometry(model):
    s4 = _session(model, speculative={"k": 4, "draft_policy": "fp8"})
    d4, v4 = s4._spec_fns_for(4)
    d2, v2 = s4._spec_fns_for(2)
    assert d4 is not d2                  # k keys the draft chain
    assert v4 is v2                      # verify retraces by shape, not key
    sp = _session(model,
                  speculative={"k": 4, "draft_policy": "fp8:sparse24"})
    dsp, vsp = sp._spec_fns_for(4)
    assert dsp is not d4                 # draft policy keys the draft
    assert vsp is v4                     # same session policy -> shared
    # identical speculative geometry on a fresh session shares the cache
    s4b = _session(model, speculative={"k": 4, "draft_policy": "fp8"})
    d4b, _ = s4b._spec_fns_for(4)
    assert d4b is d4


def test_paged_spec_keys_include_page_geometry(model):
    a = _session(model, paged=True, page_size=8, speculative=4)
    b = _session(model, paged=True, page_size=16, speculative=4)
    da, _ = a._spec_fns_for(4)
    db, _ = b._spec_fns_for(4)
    assert da is not db


# ---------------------------------------------------------------------------
# Spec surface
# ---------------------------------------------------------------------------

def test_spec_decode_spec_validation():
    assert SpecDecodeSpec.from_any(None) is None
    assert SpecDecodeSpec.from_any(3).k == 3
    s = SpecDecodeSpec.from_any({"k": 2, "draft_policy": "fp8:sparse24"})
    assert s.spec_key().startswith("fp8:sparse24")
    assert SpecDecodeSpec.from_any(s) is s
    with pytest.raises(TypeError):
        SpecDecodeSpec.from_any(True)
    with pytest.raises(ValueError):
        SpecDecodeSpec.from_any({"k": 2, "nope": 1})
    with pytest.raises(ValueError):
        SpecDecodeSpec(k=0)
    with pytest.raises(ValueError):
        SpecDecodeSpec(grow_above=0.2, shrink_below=0.5)
    rt = SpecDecodeSpec.from_any(s.to_dict())
    assert rt == s or rt.spec_key() == s.spec_key()


def test_serving_spec_speculative_roundtrip_and_refusal(model):
    from repro.runtime.server import PartitionSpec, ServingSpec
    spec = ServingSpec(partitions=(PartitionSpec(),
                                   PartitionSpec(speculative=4)),
                       speculative={"k": 2, "draft_policy": "fp8"})
    again = ServingSpec.from_dict(spec.to_dict())
    assert again.to_dict() == spec.to_dict()
    with pytest.raises(ValueError):
        ServingSpec(temperature=0.5, speculative=2)
    with pytest.raises(ValueError):
        ServingSpec(temperature=0.5,
                    partitions=(PartitionSpec(speculative=2),))
