"""End-to-end behaviour tests: the training driver trains, checkpoints,
restarts after failure; the serving session completes requests; the
characterization engine produces the paper's statistics."""
import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import build_argparser, run_once


def _args(**kw):
    ap = build_argparser()
    base = ap.parse_args(["--arch", kw.pop("arch", "llama3-8b"), "--reduced"])
    for k, v in kw.items():
        setattr(base, k, v)
    return base


def test_train_loss_decreases(tmp_path):
    """Synthetic random tokens: CE must move toward ln(vocab) (uniform)."""
    import numpy as np
    from repro.configs import get_reduced
    from repro.data.pipeline import SyntheticLM
    from repro.models import init_params
    from repro.models.layers import RuntimeCfg
    from repro.optim import adamw
    from repro.runtime import train_loop as tl

    cfg = get_reduced("llama3-8b")
    rt = RuntimeCfg(chunk_q=64, chunk_kv=64, ssm_chunk=32)
    opt_cfg = adamw.AdamWConfig(learning_rate=1e-3, total_steps=1000,
                                warmup_steps=5)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = tl.init_state(params, opt_cfg)
    step = jax.jit(tl.make_train_step(cfg, opt_cfg, rt))
    data = SyntheticLM(cfg.vocab_size, 64, 4, seed=0)
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first, (first, last, losses)


def test_train_checkpoint_resume_bitwise(tmp_path):
    """train 20 steps straight == train 10, checkpoint, resume 10 more."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    a = _args(steps=20, batch=2, seq=32, checkpoint_dir=d1,
              checkpoint_every=100, log_every=100)
    assert run_once(a) == 0

    b1 = _args(steps=10, batch=2, seq=32, checkpoint_dir=d2,
               checkpoint_every=5, log_every=100)
    assert run_once(b1) == 0
    b2 = _args(steps=20, batch=2, seq=32, checkpoint_dir=d2, resume=True,
               checkpoint_every=100, log_every=100)
    assert run_once(b2) == 0

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.optim import adamw
    from repro.runtime import train_loop as tl
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    tmpl = tl.init_state(params, adamw.AdamWConfig())
    s1 = CheckpointManager(d1).restore_latest(tmpl)[1]
    s2 = CheckpointManager(d2).restore_latest(tmpl)[1]
    for x, y in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_supervised_restart_after_injected_failure(tmp_path):
    """crash mid-run; the supervisor restarts from the last checkpoint and
    completes the remaining steps."""
    from repro.runtime.fault_tolerance import supervise
    args = _args(steps=20, batch=2, seq=32,
                 checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=5,
                 log_every=100)
    attempts = []

    def attempt():
        a = argparse.Namespace(**vars(args))
        a.resume = len(attempts) > 0
        a.fail_at_step = 0 if attempts else 12
        attempts.append(1)
        try:
            return run_once(a)
        except RuntimeError:
            return 1
    assert supervise(attempt, max_restarts=2, backoff_s=0.0,
                     log=lambda *a: None) == 0
    assert len(attempts) == 2


def test_fp8_and_sparse_training_run():
    for kw in ({"precision": "fp8"}, {"sparsity_24": True}):
        args = _args(steps=5, batch=2, seq=32, log_every=100, **kw)
        assert run_once(args) == 0


def test_grad_compression_training_runs():
    args = _args(steps=5, batch=2, seq=32, log_every=100,
                 grad_compress="int8_ef")
    assert run_once(args) == 0


def test_microbatch_matches_full_batch():
    """Gradient accumulation over 2 microbatches ~= full-batch step."""
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.models.layers import RuntimeCfg
    from repro.optim import adamw
    from repro.runtime import train_loop as tl
    cfg = get_reduced("llama3-8b")
    rt = RuntimeCfg(chunk_q=32, chunk_kv=32, ssm_chunk=16)
    opt_cfg = adamw.AdamWConfig(total_steps=10, warmup_steps=0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "inputs": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                     cfg.vocab_size),
    }
    s_full = tl.init_state(params, opt_cfg)
    s_micro = tl.init_state(params, opt_cfg)
    full = jax.jit(tl.make_train_step(cfg, opt_cfg, rt))
    micro = jax.jit(tl.make_train_step(cfg, opt_cfg, rt, microbatch=2))
    s_full, _ = full(s_full, batch)
    s_micro, _ = micro(s_micro, batch)
    for x, y in zip(jax.tree.leaves(s_full.params),
                    jax.tree.leaves(s_micro.params)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_serve_session_completes_requests():
    from repro.configs import get_reduced
    from repro.models import init_params
    from repro.models.layers import RuntimeCfg
    from repro.runtime.serve_loop import Request, ServeSession
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    sess = ServeSession(params, cfg, batch_slots=2, max_len=64,
                        rt=RuntimeCfg(ssm_chunk=16))
    for uid in range(3):
        sess.submit(Request(uid=uid,
                            prompt=np.array([1, 2, 3], np.int32),
                            max_new=4))
    done = sess.run()
    assert len(done) == 3
    for r in done:
        assert len(r.out) == 4
        assert all(0 <= t < cfg.padded_vocab for t in r.out)


def test_characterization_sweeps_produce_records():
    from repro.core import characterization as ch
    occ = ch.occupancy_sweep(tile_counts=(1, 2), tile_m=64, k=64, n=64,
                             precisions=("fp32", "fp8"), iters=2)
    assert len(occ) == 4
    th = ch.occupancy_threshold(occ)
    assert set(th) == {"fp32", "fp8"}
    shp = ch.shape_sweep(total_mn=128 * 128, k=64, ratios=(1.0, 4.0),
                         precisions=("bf16",), iters=2)
    assert len(shp) == 2
    lat = ch.latency_probe(tile_shapes=((128, 128, 128),),
                           precisions=("bf16",), chain=4, iters=2)
    assert lat and lat[0].us_per_call > 0
    for r in occ + shp + lat:
        assert "," in r.csv()
