"""Execution observatory: telemetry tracer + autotune store/calibration.

Covers the closed loop the subsystem exists for: events are recorded
under the real scheduler (per-tenant accounting is exact), measurements
persist across "process" boundaries (fresh store + fresh cache reproduce
identical lookups), and calibration *changes policy decisions* — the
FP8 demotion flips at the measured knee, not the Table-3 constant.
"""
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, concurrency as cc, execution as ex
from repro.runtime import serve_loop, telemetry
from repro.runtime.scheduler import run_tenants
from repro.runtime.serve_loop import Request, ServeSession

REPO_ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture
def tracer():
    tr = telemetry.Tracer(capacity=512)
    prev = telemetry.set_tracer(None)     # tests opt in explicitly
    yield tr
    telemetry.set_tracer(prev)


@pytest.fixture(autouse=True)
def _clean_default_advisor():
    yield
    ex.set_default_advisor(None)


# ---------------------------------------------------------------------------
# Tracer mechanics
# ---------------------------------------------------------------------------

def test_ring_eviction_keeps_counts_exact():
    tr = telemetry.Tracer(capacity=4)
    for i in range(10):
        tr.record("matmul", m=128, k=128, n=128, wall_s=0.001 * (i + 1))
    assert len(tr) == 4                    # ring holds only the newest
    assert tr.counts()["matmul"] == 10     # counters survive eviction
    assert len(tr.events("matmul")) == 4


def test_tenant_counts_exact_after_ring_eviction():
    """Per-tenant accounting is a monotonic counter, not a ring view: a
    long serving run must report exact request totals even after the
    evicting buffer has dropped the early events."""
    tr = telemetry.Tracer(capacity=8)
    for i in range(50):
        tr.record_request("alpha" if i % 2 else "beta", wall_s=0.01,
                          tokens=1)
    assert tr.tenant_counts("request") == {"alpha": 25, "beta": 25}
    # sample views cover only the retained window, by design
    assert sum(len(v) for v in tr.tenant_latencies().values()) == 8


def test_record_safe_under_concurrent_emitters():
    """Regression: ring append + monotonic counters must be guarded — the
    serving loop, ``run_async_dispatch`` stream threads, and
    multi-partition steps record into one tracer concurrently. Hammer
    ``record`` from many threads and require *exact* counter totals (a
    lost update under a race shows up as a short count)."""
    import threading

    tr = telemetry.Tracer(capacity=256)
    n_threads, per_thread = 8, 500
    start = threading.Barrier(n_threads)

    def hammer(tid):
        start.wait()
        for i in range(per_thread):
            tr.record("matmul", m=128, k=128, n=128, wall_s=1e-4,
                      tenant=f"t{tid}")
            tr.record_request(f"t{tid}", wall_s=1e-3, tokens=1)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    counts = tr.counts()
    assert counts["matmul"] == n_threads * per_thread
    assert counts["request"] == n_threads * per_thread
    assert tr.tenant_counts("request") == {
        f"t{t}": per_thread for t in range(n_threads)}
    assert len(tr) == 256                 # ring stayed capacity-bounded
    assert (128, 128, 128, "") in tr.shape_latency_ema()


def test_shape_latency_ema_converges():
    tr = telemetry.Tracer(ema_alpha=0.5)
    for w in (0.1, 0.2, 0.2, 0.2):
        tr.record("decode", m=8, k=64, n=256, precision="bf16", wall_s=w)
    ema = tr.shape_latency_ema()[(8, 64, 256, "bf16")]
    assert 0.15 < ema < 0.2


def test_ambient_tracer_observes_matmul_and_resolve(tracer):
    telemetry.set_tracer(tracer)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 128), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 256), jnp.bfloat16)
    ex.matmul(x, w)
    ex.resolve_policy(2048, 4096, 2048, precision="fp8")
    telemetry.set_tracer(None)
    ex.matmul(x, w)                        # no tracer: not recorded
    counts = tracer.counts()
    assert counts == {"matmul": 1, "resolve": 1}
    (mm,) = tracer.events("matmul")
    assert (mm.m, mm.k, mm.n) == (64, 128, 256)
    assert mm.policy == "bf16:dense:jnp"
    (rs,) = tracer.events("resolve")
    assert rs.meta["fill"] == pytest.approx(256 / 256)   # 16x16 tiles
    hist = tracer.occupancy_histogram(n_cores=256)
    assert sum(hist.values()) == 2


def test_characterize_streams_emits_stream_events(tracer):
    a = jnp.ones((64, 64), jnp.float32)
    fn = jax.jit(lambda x: x @ x)

    def mk(i):
        return lambda: fn(a)

    rep = cc.characterize_streams(mk, 3, mode="async", tracer=tracer)
    assert tracer.counts()["stream"] == 3
    assert tracer.counts()["stream_report"] == 1
    evs = tracer.events("stream")
    assert sorted(e.stream for e in evs) == [0, 1, 2]
    assert all(e.wall_s > 0 for e in evs)
    (agg,) = tracer.events("stream_report")
    assert agg.meta["fairness"] == pytest.approx(rep.fairness)


# ---------------------------------------------------------------------------
# Tracer accounting under the scheduler (per-tenant counts are exact)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    from repro.configs import get_reduced
    from repro.models import init_params
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_scheduler_event_accounting(model, tracer):
    from repro.models.layers import RuntimeCfg
    cfg, params = model
    sess = ServeSession(params, cfg, batch_slots=2, max_len=64,
                        rt=RuntimeCfg(ssm_chunk=16))
    rng = np.random.default_rng(0)
    workloads = {
        "alpha": [Request(uid=i, max_new=4, prompt=rng.integers(
            0, cfg.vocab_size, 5).astype(np.int32)) for i in range(3)],
        "beta": [Request(uid=10 + i, max_new=4, prompt=rng.integers(
            0, cfg.vocab_size, 5).astype(np.int32)) for i in range(2)],
    }
    rep = run_tenants(sess, workloads, admission="fair_quantum",
                      tracer=tracer)
    # request events match requests served, per tenant, exactly
    assert tracer.tenant_counts("request") == {"alpha": 3, "beta": 2}
    assert tracer.tenant_counts("admit") == {"alpha": 3, "beta": 2}
    assert rep.tokens_out == sum(e.meta["tokens"]
                                 for e in tracer.events("request"))
    pcts = tracer.tenant_percentiles()
    for t in ("alpha", "beta"):
        assert pcts[t]["p99"] >= pcts[t]["p50"] >= 0
    assert 0.0 <= tracer.tenant_fairness() <= 1.0
    # the session piggybacks on the scheduler's tracer: serving ops seen
    assert tracer.counts()["prefill"] == 5
    assert tracer.counts()["decode"] >= 1


# ---------------------------------------------------------------------------
# Autotune store: round-trip, calibration, the policy flip
# ---------------------------------------------------------------------------

def _knee_samples(store, knee_tiles=1024,
                  tiles=(256, 512, 1024, 2048)):
    """FP8 loses below ``knee_tiles``, wins at/above it."""
    for t in tiles:
        win = t >= knee_tiles
        store.record_sample("fp8", t, 120.0 if win else 60.0)
        store.record_sample("bf16", t, 100.0)


def test_store_roundtrip_identical_block_lookups(tmp_path):
    st = autotune.AutotuneStore(str(tmp_path))
    src = ex.BlockShapeCache(seed=False)
    src.record(512, 512, 512, jnp.bfloat16, (256, 256, 128), 1.5e-3)
    src.record(256, 1024, 256, jnp.float8_e4m3fn, (128, 128, 512), 0.8e-3)
    assert st.ingest_cache(src) == 2
    st.save()

    # "fresh process": new store, new cache, nothing shared but the file
    st2 = autotune.AutotuneStore(str(tmp_path))
    assert st2.load()
    dst = ex.BlockShapeCache(seed=False)
    assert st2.apply(dst) == 2
    for (m, k, n, dt) in ((512, 512, 512, jnp.bfloat16),
                          (256, 1024, 256, jnp.float8_e4m3fn)):
        assert dst.lookup(m, k, n, dt) == src.lookup(m, k, n, dt)


def test_seeded_inf_entries_stay_out_of_artifact(tmp_path):
    st = autotune.AutotuneStore(str(tmp_path))
    assert st.ingest_cache(ex.BlockShapeCache(seed=True)) == 0


def test_calibration_monotone_under_more_large_samples(tmp_path):
    st = autotune.AutotuneStore(str(tmp_path))
    _knee_samples(st, knee_tiles=1024)
    thr0 = dict(st.calibrate(n_cores=256))
    assert thr0["knee_tiles"] == 1024
    # more large-shape samples where fp8 wins: threshold must never RISE
    prev = thr0["demote_below_fill"]
    for extra in (4096, 8192, 1024, 2048):
        st.record_sample("fp8", extra, 150.0)
        st.record_sample("bf16", extra, 100.0)
        cur = st.calibrate(n_cores=256)["demote_below_fill"]
        assert cur <= prev, (extra, cur, prev)
        prev = cur
    # evidence of an even earlier knee can only LOWER it
    st.record_sample("fp8", 512, 130.0)
    st.record_sample("fp8", 512, 130.0)
    st.record_sample("fp8", 512, 130.0)
    assert st.calibrate(n_cores=256)["demote_below_fill"] <= prev


def test_calibrated_threshold_flips_resolve_policy(tmp_path):
    """Acceptance: synthetic samples put the measured knee at fill 4.0
    (1024 tiles / 256 cores); after persist + fresh-load, the advisor
    demotes FP8 at fill 2.0 — where the hard-coded thresholds keep it."""
    st = autotune.AutotuneStore(str(tmp_path))
    _knee_samples(st, knee_tiles=1024)
    st.calibrate(n_cores=256)
    st.save()

    st2 = autotune.AutotuneStore(str(tmp_path))
    assert st2.load()
    cal = st2.make_advisor(n_cores=256)
    assert cal.calibrated
    assert cal.demote_below_fill == pytest.approx(4.0)   # measured knee

    # dominant GEMM at fill 2.0: 16 x 32 = 512 tiles over 256 cores
    m, k, n = 2048, 4096, 4096
    prior = ex.resolve_policy(m, k, n, precision="fp8",
                              advisor=cc.OccupancyAdvisor(n_cores=256))
    calibrated = ex.resolve_policy(m, k, n, precision="fp8", advisor=cal)
    assert prior.precision == "fp8"            # 2.0 >= hard-coded 2.0
    assert calibrated.precision == "bf16"      # 2.0 < measured 4.0
    assert any("measured" in r for r in calibrated.rationale)
    # above the measured knee FP8 survives calibration
    high = ex.resolve_policy(2048, 4096, 16384, precision="fp8",
                             advisor=cal)     # 2048 tiles -> fill 8.0
    assert high.precision == "fp8"


def test_install_makes_calibration_the_default(tmp_path):
    st = autotune.AutotuneStore(str(tmp_path))
    _knee_samples(st, knee_tiles=1024)
    st.calibrate(n_cores=256)
    st.record_block(384, 768, 384, "fp8", (128, 128, 512), 1e-3)
    st.save()

    assert autotune.install(art_dir=str(tmp_path)) is not None
    try:
        assert ex.get_default_advisor().calibrated
        # no explicit advisor: resolve_policy now runs on measured knees
        pol = ex.resolve_policy(2048, 4096, 4096, precision="fp8")
        assert pol.precision == "bf16"
        # persisted block entry reached the global cache
        assert ex.BLOCK_CACHE.lookup(384, 768, 384, jnp.float8_e4m3fn) \
            == (128, 128, 512)
    finally:
        ex.set_default_advisor(None)
    # default restored: the hard-coded threshold decides again
    assert ex.resolve_policy(2048, 4096, 4096,
                             precision="fp8").precision == "fp8"


def test_install_without_artifact_is_noop(tmp_path):
    assert autotune.install(art_dir=str(tmp_path / "missing")) is None
    assert not ex.get_default_advisor().calibrated


def test_no_knee_evidence_never_claims_calibrated(tmp_path):
    """A store without comparable fp8/bf16 buckets keeps the priors and
    must not brand its advisor 'measured'."""
    st = autotune.AutotuneStore(str(tmp_path))
    st.record_sample("fp8", 256, 80.0)       # no bf16 at the same tiles
    thr = st.calibrate(n_cores=256)
    assert "demote_below_fill" not in thr
    adv = st.make_advisor(n_cores=256)
    assert not adv.calibrated
    assert adv.demote_below_fill == cc.OccupancyAdvisor.BF16_TILE_THRESHOLD
    st.save()
    assert autotune.install(art_dir=str(tmp_path)) is not None
    assert not ex.get_default_advisor().calibrated   # default untouched


def test_occupancy_records_convert_to_grid_tile_units(tmp_path):
    """occupancy_sweep counts M tiles at a fixed N; the store must fold
    the N-tile factor in so calibrated fills match the advisor's units."""
    from repro.core.characterization import Record
    st = autotune.AutotuneStore(str(tmp_path))
    rec = Record("occupancy/fp8/tiles=4", 10.0,
                 {"gflops": 50.0, "tiles": 4, "precision": "fp8",
                  "m": 512, "k": 256, "n": 256})
    assert st.add_records([rec]) == 1
    (s,) = st.samples
    assert s.tiles == ex.grid_tiles(512, 256) == 8    # 4 M-tiles x 2 N-tiles
    # legacy records without the shape fall back to the raw tile count
    st2 = autotune.AutotuneStore(str(tmp_path))
    st2.add_records([Record("occupancy/fp8/tiles=4", 10.0,
                            {"gflops": 50.0, "tiles": 4})])
    assert st2.samples[0].tiles == 4


# ---------------------------------------------------------------------------
# Profile CLI + benchmark seeding (end-to-end on CPU)
# ---------------------------------------------------------------------------

def test_profile_quick_writes_reloadable_artifact(tmp_path, capsys):
    from repro.launch import profile
    rc = profile.main(["--quick", "--artifact-dir", str(tmp_path)])
    assert rc == 0
    st = autotune.AutotuneStore(str(tmp_path))
    assert st.load(), "profile --quick must write a loadable artifact"
    assert st.thresholds.get("samples", 0) > 0
    assert st.blocks and st.samples
    assert "artifact written" in capsys.readouterr().out
    # ambient tracer must not leak out of the CLI
    assert telemetry.get_tracer() is None


def test_table3_benchmark_seeds_persistent_store(tmp_path, monkeypatch):
    monkeypatch.setenv(autotune.ENV_DIR, str(tmp_path))
    sys.path.insert(0, str(REPO_ROOT))
    try:
        from benchmarks import table3_tile_latency as t3
        from repro.core.characterization import latency_probe
        records = latency_probe(tile_shapes=((128, 128, 128),),
                                precisions=("bf16", "fp8"),
                                chain=2, iters=1)
        assert t3.persist(records) == 2
    finally:
        sys.path.remove(str(REPO_ROOT))
    st = autotune.AutotuneStore()            # env-resolved dir
    assert st.load()
    assert (128, 128, 128, "fp8") in st.blocks
    fresh = ex.BlockShapeCache(seed=False)
    st.apply(fresh)
    assert fresh.lookup(128, 128, 128, jnp.float8_e4m3fn) is not None


def test_record_serializer_roundtrip(tmp_path):
    from repro.core.characterization import Record
    recs = [Record("occupancy/fp8/tiles=4", 12.5,
                   {"gflops": 99.0, "tiles": 4, "precision": "fp8"}),
            Record("latency/bf16/128x128x128", 3.0, {"tile": "128x128x128"})]
    path = autotune.dump_records(recs, str(tmp_path / "figs" / "out.json"))
    loaded = autotune.load_records(path)
    assert [r["name"] for r in loaded] == [r.name for r in recs]
    assert loaded[0]["derived"]["gflops"] == 99.0
    st = autotune.AutotuneStore(str(tmp_path))
    assert st.add_records(recs) == 2         # same rows ingest as evidence


# ---------------------------------------------------------------------------
# Block-shape sweep calibration (alternative tilings, winner persisted)
# ---------------------------------------------------------------------------

def test_block_candidates_distinct_and_clamped():
    from repro.core.characterization import block_candidates
    cands = block_candidates(128, 256, 512, "fp8")
    assert 2 <= len(cands) <= 3
    assert len(set(cands)) == len(cands)          # deduplicated
    for bm, bn, bk in cands:
        assert bm <= 128 and bn <= 256 and bk <= 512
    # fp8's preferred deep-K tiling is among the candidates
    assert (128, 256, 512) in cands
    # a tiny problem collapses every candidate to the problem itself
    assert block_candidates(128, 128, 128, "bf16") == [(128, 128, 128)]


def _sweep_records():
    from repro.core.characterization import Record
    rows = [("128x128x256", 9.0), ("128x128x128", 5.0), ("64x64x256", 7.0)]
    return [Record(f"blocksweep/bf16/128x128x256/{blocks}", us,
                   {"m": 128, "n": 128, "k": 256, "precision": "bf16",
                    "blocks": blocks, "winner": us == 5.0})
            for blocks, us in rows]


def test_blocksweep_records_persist_winning_tiling(tmp_path):
    """The sweep's fastest *measured* tiling — not a clamped prior — is
    what the store keeps and what a fresh cache serves back."""
    st = autotune.AutotuneStore(str(tmp_path))
    assert st.add_records(_sweep_records()) == 3
    blocks, secs = st.blocks[(128, 256, 128, "bf16")]
    assert blocks == (128, 128, 128) and secs == pytest.approx(5e-6)
    st.save()
    st2 = autotune.AutotuneStore(str(tmp_path))
    assert st2.load()
    cache = ex.BlockShapeCache(seed=False)
    st2.apply(cache)
    assert cache.lookup(128, 256, 128, jnp.bfloat16) == (128, 128, 128)


def test_blocksweep_records_seed_block_cache_directly():
    cache = ex.BlockShapeCache(seed=False)
    assert ex.seed_cache_from_records(_sweep_records(), cache) == 3
    assert cache.lookup(128, 256, 128, jnp.bfloat16) == (128, 128, 128)


def test_block_sweep_probe_measures_alternative_tilings():
    """One real (tiny) sweep through the Pallas interpret path: every
    candidate tiling is measured, exactly one winner per (shape,
    precision) group, and the records round-trip into the store."""
    from repro.core.characterization import block_sweep_probe
    recs = block_sweep_probe(shapes=((128, 128, 128),),
                             precisions=("bf16",), iters=1)
    assert len(recs) >= 1
    assert all(r.name.startswith("blocksweep/bf16/128x128x128/")
               for r in recs)
    assert sum(r.derived["winner"] for r in recs) == 1
    st = autotune.AutotuneStore()
    assert st.add_records(recs) == len(recs)
    blocks, secs = st.blocks[(128, 128, 128, "bf16")]
    assert secs == min(r.us_per_call for r in recs) * 1e-6


# ---------------------------------------------------------------------------
# Satellites: jit-cache LRU, advisor core-count detection
# ---------------------------------------------------------------------------

def test_jit_cache_lru_capped_and_clearable():
    serve_loop.clear_jit_cache()
    try:
        for i in range(serve_loop.JIT_CACHE_MAX + 5):
            serve_loop._cached_jit("t", lambda: (lambda x: x), i)
        assert len(serve_loop._JIT_CACHE) == serve_loop.JIT_CACHE_MAX
        # oldest entries evicted, newest kept
        assert ("t", 0) not in serve_loop._JIT_CACHE
        assert ("t", serve_loop.JIT_CACHE_MAX + 4) in serve_loop._JIT_CACHE
        # a hit refreshes recency: key 5 survives the next insertion
        serve_loop._cached_jit("t", lambda: (lambda x: x), 5)
        serve_loop._cached_jit("t", lambda: (lambda x: x), 999)
        assert ("t", 5) in serve_loop._JIT_CACHE
    finally:
        serve_loop.clear_jit_cache()
    assert len(serve_loop._JIT_CACHE) == 0


def test_advisor_core_count_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_N_CORES", "64")
    adv = cc.OccupancyAdvisor()
    assert adv.n_cores == 64
    # fill doubles relative to the 256-core default: 128 tiles saturate
    pol = ex.resolve_policy(2048, 512, 1024, precision="fp8", advisor=adv)
    assert pol.precision == "fp8"


def test_advisor_core_count_cpu_fallback(monkeypatch):
    monkeypatch.delenv("REPRO_N_CORES", raising=False)
    assert cc.detect_core_count() == cc.DEFAULT_N_CORES
    assert cc.OccupancyAdvisor().n_cores == 256
