"""The SLO closed loop: spec plumbing, enforcement, hysteresis, and the
controller-off identity.

The edge-case contracts (ISSUE 10 satellites):

* a STARVED latency tenant (demand, zero completions — attainment 0.0)
  triggers a freeze/boost within ONE control interval of the signal;
* hysteresis (low/high deadband + hold streak) prevents freeze/thaw
  ping-pong — actions stay bounded and balanced over a full run;
* controller-off runs are byte-identical to the pre-PR runtime: same
  committed tokens, same step count, zero ``controller`` events — and a
  ``ServingSpec`` dict WITHOUT the field still loads;
* every action lands in all three ledgers (in-memory, Tracer events,
  ``repro_controller_actions_total{action}``) in agreement;
* the ``cap_overrides`` scheduler seam wins over the quota policy.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime import workload as wl
from repro.runtime.controller import (
    ACTIONS, ControllerSpec, SLOController)
from repro.runtime.serve_loop import Request
from repro.runtime.server import PartitionSpec, ServingRuntime, ServingSpec

RT = RuntimeCfg(ssm_chunk=16)


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _runtime(model, controller=None, *, metrics=False, slots=2):
    cfg, params = model
    spec = ServingSpec(partitions=(PartitionSpec(admission="fifo"),),
                       batch_slots=slots, max_len=64,
                       controller=controller, metrics=metrics)
    return ServingRuntime(params, cfg, spec, rt=RT)


def _req(uid, max_new=4, length=4, seed=0):
    rng = np.random.default_rng(seed + uid)
    return Request(uid=uid, prompt=rng.integers(0, 64, length)
                   .astype(np.int32), max_new=max_new)


def _contended_trace(seed=7):
    """The fig23 shape: two Zipf-head batch tenants flooding long
    outputs, one latency tenant answering short under latency:20."""
    return wl.generate(wl.WorkloadSpec(
        tenants=3, zipf_s=1.1, arrival="bursty", rate=1.0,
        burst_factor=3.0, burst_len=6, steps=40,
        prompt_len=(4, 8), max_new=(8, 12),
        max_new_overrides=(None, None, (3, 5)),
        slos=("batch", "batch", "latency:20"), seed=seed))


# ---------------------------------------------------------------------------
# ControllerSpec plumbing
# ---------------------------------------------------------------------------

def test_controller_spec_validation_and_from_any():
    assert ControllerSpec.from_any(None) is None
    assert ControllerSpec.from_any(False) is None
    assert ControllerSpec.from_any(True) == ControllerSpec()
    spec = ControllerSpec(interval=2, low=0.8, high=0.95)
    assert ControllerSpec.from_any(spec) is spec
    assert ControllerSpec.from_any({"interval": 3}).interval == 3
    with pytest.raises(ValueError):
        ControllerSpec.from_any({"cadence": 3})        # unknown field
    with pytest.raises(ValueError):
        ControllerSpec(low=0.95, high=0.9)             # inverted band
    with pytest.raises(ValueError):
        ControllerSpec(interval=0)
    with pytest.raises(ValueError):
        ControllerSpec(hold=0)


def test_controller_spec_cli_parse():
    assert ControllerSpec.parse(None) is None
    assert ControllerSpec.parse("off") is None
    assert ControllerSpec.parse("on") == ControllerSpec()
    spec = ControllerSpec.parse("interval=3,low=0.8,high=0.9,boost=0")
    assert (spec.interval, spec.low, spec.boost) == (3, 0.8, False)
    with pytest.raises(ValueError):
        ControllerSpec.parse("warp=9")


def test_serving_spec_controller_round_trip():
    spec = ServingSpec(partitions=(PartitionSpec(),), batch_slots=2,
                       max_len=32,
                       controller={"interval": 2, "low": 0.85})
    d = spec.to_dict()
    assert d["controller"]["interval"] == 2
    again = ServingSpec.from_dict(d)
    assert again.to_dict() == d
    # a pre-PR spec dict (no controller key) still loads, controller-off
    legacy = {k: v for k, v in d.items() if k != "controller"}
    assert ServingSpec.from_dict(legacy).controller is None
    with pytest.raises(ValueError):
        ServingSpec(partitions=(PartitionSpec(),), batch_slots=2,
                    max_len=32, controller={"nope": 1})


# ---------------------------------------------------------------------------
# enforcement edge cases
# ---------------------------------------------------------------------------

def test_starved_latency_tenant_triggers_within_one_interval(model):
    """Attainment 0.0 (demand, nothing ever completed) must produce a
    freeze + boost at the FIRST control check after the demand appears."""
    runtime = _runtime(model, ControllerSpec(interval=2, hold=4))
    runtime.add_tenant("batch", slo="batch")
    runtime.add_tenant("lat", slo="latency:10")
    # batch floods both slots with long work
    for uid in range(6):
        runtime.submit("batch", _req(uid, max_new=12))
    for _ in range(4):
        runtime.step()
    assert runtime.controller.actions == []       # no latency demand yet
    runtime.submit("lat", _req(100, max_new=3))
    for _ in range(2):                            # one control interval
        runtime.step()
    acts = [a.action for a in runtime.controller.actions]
    assert "freeze" in acts and "boost" in acts
    frozen = [a for a in runtime.controller.actions
              if a.action == "freeze"]
    assert frozen[0].tenant == "batch"
    assert frozen[0].victim == "lat"
    assert frozen[0].attainment == 0.0
    assert runtime.schedulers[0].tenants["batch"].frozen
    assert runtime.schedulers[0].cap_overrides["lat"] == 2


def test_hysteresis_prevents_ping_pong(model):
    """Over a full contended run the loop must settle: every freeze is
    eventually thawed, episodes are few (no per-check flapping), and
    consecutive freeze→thaw pairs on one tenant are separated by at
    least ``hold`` healthy checks."""
    trace = _contended_trace()
    runtime = _runtime(model, ControllerSpec(interval=2, hold=4))
    wl.run_trace(runtime, trace)
    ctrl = runtime.controller
    counts = ctrl.counts()
    assert counts["freeze"] >= 1
    assert counts["thaw"] == counts["freeze"]       # balanced release
    # bounded: far fewer episodes than control checks (no flapping)
    assert counts["freeze"] + counts["thaw"] <= ctrl.checks // 2
    # the hold streak gates RELEASE: every thaw comes at least
    # hold * interval steps after the episode's most recent freeze.
    # (Re-engagement after a thaw is allowed to be fast — fresh
    # starvation must trigger within one interval — so the deadband
    # shows up as long-held freezes, not slow re-freezes.)
    spec = ctrl.spec
    last_freeze = None
    for a in ctrl.actions:
        if a.action == "freeze":
            last_freeze = a.step
        elif a.action == "thaw":
            assert last_freeze is not None
            gap = a.step - last_freeze
            assert gap >= spec.hold * spec.interval, \
                f"thaw of {a.tenant} only {gap} steps after a freeze"
    # nothing left frozen or boosted at drain
    assert ctrl.frozen_now() == 0
    sched = runtime.schedulers[0]
    assert not any(t.frozen for t in sched.tenants.values())
    assert sched.cap_overrides == {}


def test_controller_recovers_attainment(model):
    """The headline: same trace, controller-off starves the latency
    class; controller-on recovers it; tokens are untouched."""
    trace = _contended_trace()
    off = _runtime(model)
    done_off = wl.run_trace(off, trace)
    on = _runtime(model, ControllerSpec(interval=2, hold=4))
    done_on = wl.run_trace(on, trace)
    att = {t.tenant_id: t.slo_attainment for t in off.report().tenants}
    att_on = {t.tenant_id: t.slo_attainment for t in on.report().tenants}
    assert att["tenant2"] < 0.7
    assert att_on["tenant2"] >= 0.95
    assert wl.tokens_by_uid(done_on) == wl.tokens_by_uid(done_off)


def test_controller_off_identical_to_pre_pr(model):
    """controller=None must be byte-identical to the pre-PR runtime:
    same tokens, same step count, no controller state anywhere."""
    trace = _contended_trace(seed=3)
    a = _runtime(model)                        # default: no controller
    done_a = wl.run_trace(a, trace)
    b = _runtime(model, ControllerSpec(enabled=False, interval=2))
    done_b = wl.run_trace(b, trace)
    assert a.controller is None and b.controller is None
    assert wl.tokens_by_uid(done_a) == wl.tokens_by_uid(done_b)
    assert a.step_count == b.step_count
    assert a.merged_tracer().counts().get("controller", 0) == 0
    assert {r.uid: (r.submit_step, r.admit_step, r.finish_step)
            for r in done_a} \
        == {r.uid: (r.submit_step, r.admit_step, r.finish_step)
            for r in done_b}


# ---------------------------------------------------------------------------
# ledgers agree
# ---------------------------------------------------------------------------

def test_action_ledger_tracer_and_metrics_agree(model):
    trace = _contended_trace()
    runtime = _runtime(model, ControllerSpec(interval=2, hold=4),
                       metrics=True)
    wl.run_trace(runtime, trace)
    ctrl = runtime.controller
    assert ctrl.actions, "contended run produced no actions"
    counts = ctrl.counts()
    assert set(counts) == set(ACTIONS)
    # tracer ledger: monotonic event count matches the in-memory ledger
    assert runtime.merged_tracer().counts()["controller"] \
        == len(ctrl.actions)
    # metrics ledger: repro_controller_actions_total{action=...} sums
    snap = runtime.metrics.snapshot()
    series = snap["repro_controller_actions_total"]["series"]
    by_action = {}
    for labels, v in series.items():
        for a in ACTIONS:
            if f'action="{a}"' in labels:
                by_action[a] = by_action.get(a, 0) + int(v)
    assert by_action == {a: n for a, n in counts.items() if n}


def test_top_renders_ctrl_line_and_trend_arrows(model):
    from repro.launch import top
    trace = _contended_trace()
    runtime = _runtime(model, ControllerSpec(interval=2, hold=4))
    wl.run_trace(runtime, trace)
    frame = top.render(runtime)
    assert "CTRL" in frame
    assert "freeze:" in frame and "thaw:" in frame
    # the latency tenant row carries a trend arrow state
    assert runtime.controller.trend_arrow("tenant2") in ("^", "v", "=")
    assert runtime.controller.trend_arrow("nobody") == ""
    # controller-off frames carry the column header but no CTRL summary
    off = _runtime(model)
    off.add_tenant("t0")
    frame_off = top.render(off)
    assert "CTRL" in frame_off                 # the column header stays
    assert "checks" not in frame_off           # but no controller summary


# ---------------------------------------------------------------------------
# scheduler seam
# ---------------------------------------------------------------------------

def test_cap_override_wins_over_quota(model):
    runtime = _runtime(model, slots=2)
    sched = runtime.schedulers[0]
    runtime.add_tenant("a")
    runtime.add_tenant("b")
    t = sched.tenants["a"]
    base = sched._slot_cap(t)
    sched.cap_overrides["a"] = base + 7
    assert sched._slot_cap(t) == base + 7
    assert sched._slot_cap(sched.tenants["b"]) == base
    sched.cap_overrides["a"] = 0           # floor clamps to 1
    assert sched._slot_cap(t) == 1
    del sched.cap_overrides["a"]
    assert sched._slot_cap(t) == base


def test_controller_duck_types_runtime():
    """The controller never imports the server module (no cycle); it
    drives anything with step_count/schedulers/tracers."""
    import repro.runtime.controller as mod
    src = open(mod.__file__).read()
    assert "from repro.runtime.server" not in src
    assert "import repro.runtime.server" not in src
    ctrl = SLOController(ControllerSpec(interval=1))

    class FakeRuntime:
        step_count = 2
        schedulers = ()
        tracers = ()
    ctrl.on_step(FakeRuntime())            # no partitions: a clean no-op
    assert ctrl.checks == 1
    assert ctrl.actions == []
