"""The ServingRuntime control plane: declarative specs, heterogeneous
per-partition execution policies, live tenant migration, and the
report/fairness accounting fixes.

The migration contracts under test (the tentpole's acceptance criteria):

* token-for-token equality — a tenant migrated MID-REQUEST (its per-slot
  KV/SSM cache state handed off between partitions) produces exactly the
  tokens of the same tenant served solo;
* drain-under-load — a migration with no free target slot defers the
  handoff (the request keeps decoding at the source) and the source
  admits nothing new for the tenant;
* slot isolation — the handed-off slot is left fully cleared, so its
  next occupant cannot attend to the emigrant's KV rows;
* exact accounting — one global lockstep step domain: turnaround equals
  observed runtime steps even when a request crosses partitions, and the
  fused report folds the tenant's history once (no double counting).

Plus the satellite regressions: registered-but-idle and starved tenants
in fairness denominators, and the AdaptiveQuota occupancy signal.
"""
import argparse
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import execution as ex
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime import telemetry
from repro.runtime.scheduler import AdaptiveQuota, StreamScheduler
from repro.runtime.serve_loop import Request, ServeSession
from repro.runtime.server import (
    MigrationSpec, PartitionSpec, ServingRuntime, ServingSpec, TenantSpec,
    run_serving)

RT = RuntimeCfg(ssm_chunk=16)
MAX_LEN = 64
BF16 = "bf16:dense:jnp"
FP8SP = "fp8:sparse24:jnp"


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, tenant_idx, n=2, max_new=6, length=5):
    rng = np.random.default_rng(tenant_idx)
    return [Request(uid=tenant_idx * 100 + j,
                    prompt=rng.integers(0, cfg.vocab_size, length)
                    .astype(np.int32), max_new=max_new)
            for j in range(n)]


def _runtime(model, spec, **kw):
    cfg, params = model
    return ServingRuntime(params, cfg, spec, rt=RT, **kw)


def _spec(n=2, policies=None, migration=None, slots=2, **kw):
    pols = policies or [None] * n
    return ServingSpec(
        partitions=tuple(PartitionSpec(policy=p) for p in pols),
        placement=kw.pop("placement", "load_aware"),
        batch_slots=slots, max_len=MAX_LEN,
        migration=migration or MigrationSpec(), **kw)


def _solo_outputs(model, requests, policy=None, slots=2):
    cfg, params = model
    sess = ServeSession(params, cfg, batch_slots=slots, max_len=MAX_LEN,
                        rt=RT,
                        policy=ex.parse_policy(policy) if policy else None)
    outs = []
    for req in requests:
        solo = Request(uid=req.uid, prompt=req.prompt.copy(),
                       max_new=req.max_new)
        sess.submit(solo)
        outs.append(solo)
    sess.run()
    return [r.out for r in outs]


# ---------------------------------------------------------------------------
# ServingSpec (declarative surface)
# ---------------------------------------------------------------------------

def test_spec_json_round_trip(tmp_path):
    spec = ServingSpec(
        partitions=(PartitionSpec(policy=FP8SP, quota="adaptive"),
                    PartitionSpec(admission="fifo", batch_slots=8)),
        placement="packed", batch_slots=4, max_len=96, temperature=0.5,
        seed=3, policy=BF16,
        migration=MigrationSpec(enabled=True, interval=5, threshold=3.0,
                                cooldown=7, max_migrations=2),
        tenants=(TenantSpec(id="a", weight=2.0, partition=1),
                 TenantSpec(id="b")))
    path = spec.save(str(tmp_path / "spec.json"))
    loaded = ServingSpec.load(path)
    assert loaded == spec
    # an ExecutionPolicy instance serializes through its full spec string
    pol = ex.ExecutionPolicy(precision="fp8", sparsity="sparse24",
                             backend="jnp", block_m=128, block_n=128,
                             block_k=256, streams=4)
    spec2 = ServingSpec(partitions=(PartitionSpec(policy=pol),))
    again = ServingSpec.from_json(spec2.to_json())
    assert again.partitions[0].policy == pol.full_spec()
    assert ex.parse_policy(again.partitions[0].policy) == pol


def test_spec_validation():
    with pytest.raises(ValueError):
        ServingSpec(partitions=())
    with pytest.raises(ValueError):
        ServingSpec(placement="nearest")
    with pytest.raises(ValueError):
        PartitionSpec(admission="lottery")
    with pytest.raises(ValueError):
        PartitionSpec(quota="lottery")
    with pytest.raises(ValueError):
        MigrationSpec(threshold=0.9)
    with pytest.raises(ValueError):
        MigrationSpec(interval=0)
    with pytest.raises(ValueError):            # duplicate tenant ids
        ServingSpec(tenants=(TenantSpec(id="a"), TenantSpec(id="a")))
    with pytest.raises(ValueError):            # pin outside the partitions
        ServingSpec(tenants=(TenantSpec(id="a", partition=1),))
    with pytest.raises(ValueError):            # unknown field
        ServingSpec.from_dict({"partitions": 1, "placment": "spread"})
    # int shorthand builds N default partitions
    assert ServingSpec.from_dict({"partitions": 3}).n_partitions == 3


def test_launch_serve_flags_build_spec(tmp_path):
    """The legacy flag cluster is shorthand for a spec (satellite)."""
    from repro.launch.serve import build_spec
    args = argparse.Namespace(
        partitions=2, placement="load_aware", adaptive_quota=True,
        admission="fair_quantum", slots=3, max_len=48, temperature=0.0,
        seed=1, migrate=True)
    spec = build_spec(args, "auto")
    assert spec.n_partitions == 2
    assert spec.partitions[0].quota == "adaptive"
    assert spec.migration.enabled and spec.placement == "load_aware"
    assert spec.batch_slots == 3 and spec.policy == "auto"
    assert ServingSpec.load(spec.save(str(tmp_path / "s.json"))) == spec


# ---------------------------------------------------------------------------
# Heterogeneous per-partition policies
# ---------------------------------------------------------------------------

def test_per_partition_policies_resolved_and_traced(model):
    """One runtime, two policies: the fp8/sparse24 partition and the bf16
    partition run side by side, sessions reflect their partition-local
    policy (not the ambient default), and the merged tracer's decode
    events carry both policy tags (acceptance criterion)."""
    cfg, _ = model
    rt = _runtime(model, _spec(policies=[BF16, FP8SP]))
    assert rt.sessions[0].cfg.precision == "bf16"
    assert rt.sessions[1].cfg.precision == "fp8"
    assert rt.sessions[1].cfg.sparsity_24
    assert rt.policy_key(0) == BF16 and rt.policy_key(1) == FP8SP
    rt.add_tenant("b", partition=0)
    rt.add_tenant("f", partition=1)
    for r in _requests(cfg, 0, n=1, max_new=4):
        rt.submit("b", r)
    for r in _requests(cfg, 1, n=1, max_new=4):
        rt.submit("f", r)
    rt.drain()
    pols = {(e.partition, e.policy)
            for e in rt.merged_tracer().events("decode")}
    assert (0, BF16) in pols and (1, FP8SP) in pols


def test_partition_local_policy_beats_ambient_default(model):
    """core/execution honors the policy scope over the module default:
    the redesign's resolution seam."""
    scoped = ex.ExecutionPolicy(precision="fp8", backend="jnp")
    ambient = ex.ExecutionPolicy(precision="bf16", backend="ref")
    ex.set_default_policy(ambient)
    try:
        assert ex.get_default_policy() == ambient
        with ex.policy_scope(scoped):
            assert ex.get_default_policy() == scoped
            assert ex.policy_from(model[0], RT) == scoped
            with ex.policy_scope(None):           # nested null scope
                assert ex.get_default_policy() == ambient
        assert ex.get_default_policy() == ambient
    finally:
        ex.set_default_policy(None)
    assert ex.get_scope_policy() is None


def test_partition_batch_slots_override(model):
    spec = ServingSpec(partitions=(PartitionSpec(batch_slots=1),
                                   PartitionSpec()),
                       batch_slots=3, max_len=MAX_LEN)
    rt = _runtime(model, spec)
    assert rt.sessions[0].batch_slots == 1
    assert rt.sessions[1].batch_slots == 3


# ---------------------------------------------------------------------------
# Live migration
# ---------------------------------------------------------------------------

def test_manual_migration_mid_request_token_equality(model):
    """THE core contract: a request whose KV/SSM cache state is handed
    off between partitions mid-stream finishes with exactly the tokens of
    the solo run, and turnaround accounting stays exact (one global step
    domain)."""
    cfg, _ = model
    rt = _runtime(model, _spec())
    rt.add_tenant("mover", partition=0)
    reqs = _requests(cfg, 0, n=2, max_new=10)
    for r in reqs:
        rt.submit("mover", r)
    for _ in range(3):
        rt.step()                      # both slots active, mid-request
    assert rt.sessions[0].n_active == 2
    rec = rt.migrate("mover", 1)
    assert rec.slots_handed_off == 2   # target had two free slots
    assert rec.done                    # queue empty + all slots moved
    assert rt.tenant_partition["mover"] == 1
    assert "mover" not in rt.schedulers[0].tenants
    steps = 3
    while not all(r.done for r in reqs):
        rt.step()
        steps += 1
        assert steps < 100
    assert [r.out for r in reqs] == _solo_outputs(model, reqs)
    # exact accounting: turnaround in the global lockstep domain
    for r in reqs:
        assert r.submit_step == 0 and r.finish_step - r.submit_step <= steps
        assert r.finish_step == 9      # admit step 0 emits token #1
    rep = rt.report()
    (row,) = rep.tenants
    assert row.submitted == 2 and row.completed == 2
    assert row.migrations == 1 and row.partition == 1
    assert rep.migrations == 1
    phases = [e.meta["phase"] for e in rt.merged_tracer().events("migrate")]
    assert phases.count("start") == 2      # recorded on both endpoints
    assert phases.count("handoff") == 4    # 2 slots x both endpoints
    assert phases.count("done") == 2


def test_migration_drains_under_load(model):
    """With no free slot on the target, the handoff defers: the in-flight
    request keeps decoding on the (frozen) source and crosses over only
    when the target frees a slot; the source admits nothing new for the
    tenant after the freeze."""
    cfg, _ = model
    rt = _runtime(model, _spec())
    rt.add_tenant("blocker", partition=1)
    rt.add_tenant("mover", partition=0)
    for r in _requests(cfg, 9, n=2, max_new=12):
        rt.submit("blocker", r)        # fills both target slots
    mover_reqs = _requests(cfg, 0, n=2, max_new=16)
    for r in mover_reqs:
        rt.submit("mover", r)
    for _ in range(2):
        rt.step()
    rec = rt.migrate("mover", 1)
    assert not rec.done and rec.slots_handed_off == 0
    admitted_before = rt.schedulers[0].admitted_order.count("mover")
    rt.drain()
    assert rec.done and rec.slots_handed_off >= 1
    # freeze honored: the source admitted no mover request post-migration
    assert rt.schedulers[0].admitted_order.count("mover") == admitted_before
    assert [r.out for r in mover_reqs] == _solo_outputs(model, mover_reqs)
    rep = rt.report()
    row = {t.tenant_id: t for t in rep.tenants}["mover"]
    assert row.submitted == 2 and row.completed == 2 and row.migrations == 1


def test_handoff_slot_isolation(model):
    """The vacated source slot is bit-clean after a live handoff: pos
    rows read unwritten, k/v zeroed, and the next occupant reproduces its
    solo tokens exactly (cache-handoff slot-isolation)."""
    cfg, _ = model
    rt = _runtime(model, _spec(slots=1))
    rt.add_tenant("mover", partition=0)
    (req,) = _requests(cfg, 0, n=1, max_new=14)
    rt.submit("mover", req)
    for _ in range(3):
        rt.step()
    rt.migrate("mover", 1)
    caches = rt.sessions[0].caches
    assert (np.asarray(caches["layers"]["b0"]["pos"]) == -1).all()
    assert (np.asarray(caches["layers"]["b0"]["k"], np.float32) == 0).all()
    rt.add_tenant("fresh", partition=0)
    (fresh,) = _requests(cfg, 7, n=1, max_new=8)
    rt.submit("fresh", fresh)
    rt.drain()
    assert req.done and fresh.done
    assert [fresh.out] == _solo_outputs(model, [fresh], slots=1)
    assert [req.out] == _solo_outputs(model, [req], slots=1)


def test_live_handoff_requires_policy_compatible_partitions(model):
    """An in-flight request's arithmetic cannot change mid-stream: live
    migration across policy-incompatible partitions is refused, while a
    queued-only tenant migrates freely (it executes wholly under the
    target policy)."""
    cfg, _ = model
    rt = _runtime(model, _spec(policies=[BF16, FP8SP]))
    rt.add_tenant("t", partition=0)
    for r in _requests(cfg, 0, n=3, max_new=8):
        rt.submit("t", r)
    rt.step()
    with pytest.raises(ValueError, match="execution policies"):
        rt.migrate("t", 1)
    rt.drain()
    # queued-only: a fresh tenant with no active slots moves anywhere
    rt2 = _runtime(model, _spec(policies=[BF16, FP8SP]))
    rt2.add_tenant("q", partition=0)
    qreqs = _requests(cfg, 3, n=2, max_new=6)
    for r in qreqs:
        rt2.submit("q", r)
    rec = rt2.migrate("q", 1)          # nothing admitted yet
    assert rec.done and rec.queued_moved == 2
    rt2.drain()
    assert [r.out for r in qreqs] == _solo_outputs(model, qreqs,
                                                   policy=FP8SP)


def test_load_aware_auto_migration_on_skewed_load(model):
    """The re-route path fires on its own: a flooding tenant diverges its
    partition's load past the threshold, migrates to the idle partition
    (live handoff included), and the victims stay token-exact and fair
    (the fig19 headline at test scale)."""
    cfg, _ = model
    rt = _runtime(model, _spec(
        migration=MigrationSpec(enabled=True, interval=4, threshold=2.0,
                                cooldown=8)))
    rt.add_tenant("hog", partition=0)
    rt.add_tenant("victim", partition=0)
    hog_reqs = _requests(cfg, 0, n=6, max_new=8)
    for r in hog_reqs:
        rt.submit("hog", r)
    vic_reqs = _requests(cfg, 1, n=2, max_new=6)
    for r in vic_reqs:
        rt.submit("victim", r)
    rt.drain()
    assert rt.migrations and rt.migrations[0].done
    assert rt.migrations[0].reason == "load_aware"
    assert rt.tenant_partition["hog"] == 1     # flooder took the spare
    assert [r.out for r in hog_reqs] == _solo_outputs(model, hog_reqs)
    assert [r.out for r in vic_reqs] == _solo_outputs(model, vic_reqs)
    rep = rt.report()
    from repro.core.concurrency import fairness
    vic_ta = [t.mean_turnaround_steps for t in rep.tenants
              if t.tenant_id != "hog"]
    assert fairness(vic_ta) >= 0.8
    assert rep.migrations >= 1


def test_migration_disabled_means_static_routing(model):
    """The null hypothesis: with migration off, the same skew never
    re-routes anyone (PR 4 behavior preserved)."""
    cfg, _ = model
    rt = _runtime(model, _spec())
    rt.add_tenant("hog", partition=0)
    rt.add_tenant("victim", partition=0)
    for r in _requests(cfg, 0, n=4, max_new=6):
        rt.submit("hog", r)
    rt.drain()
    assert not rt.migrations
    assert rt.tenant_partition == {"hog": 0, "victim": 0}


# ---------------------------------------------------------------------------
# Report / fairness accounting (satellite regressions)
# ---------------------------------------------------------------------------

def test_registered_but_idle_tenant_appears_in_report(model):
    """A tenant that registered but never submitted must appear in the
    fused report rows and in the merged tracer's tenant enumeration
    instead of silently vanishing; tenants WITH demand keep their
    fairness index (no spurious zero from the idle tenant)."""
    cfg, _ = model
    rt = _runtime(model, _spec(n=1, slots=2))
    for tid in ("busy1", "busy2", "idle"):
        rt.add_tenant(tid)
    for i, tid in enumerate(("busy1", "busy2")):
        for r in _requests(cfg, i, n=1, max_new=4):
            rt.submit(tid, r)
    rt.drain()
    rep = rt.report()
    rows = {t.tenant_id: t for t in rep.tenants}
    assert set(rows) == {"busy1", "busy2", "idle"}
    assert rows["idle"].submitted == 0 and rows["idle"].completed == 0
    assert rep.n_tenants == 3
    assert rep.fairness >= 0.8         # over the two equal demand tenants
    merged = rt.merged_tracer()
    assert "idle" in merged.known_tenants()
    assert "idle: 0 req" in merged.summary()
    # scheduler-level registration is traced too
    assert merged.tenant_counts("register").get("idle") == 1


def test_starved_tenant_drags_fairness_down(model):
    """A tenant with demand that never completes must count against
    fairness via its elapsed wait (previously it vanished entirely and a
    starving scheduler looked perfectly fair). fifo is the starving
    policy: the first tenant's backlog holds the only slot."""
    cfg, _ = model
    spec = ServingSpec(partitions=(PartitionSpec(admission="fifo"),),
                       batch_slots=1, max_len=MAX_LEN)
    rt = _runtime(model, spec)
    rt.add_tenant("served")
    rt.add_tenant("starved")
    for r in _requests(cfg, 0, n=1, max_new=3):
        rt.submit("served", r)
    # a long request behind it keeps the single slot busy at the cutoff
    for r in _requests(cfg, 1, n=1, max_new=40):
        rt.submit("served", r)
    for r in _requests(cfg, 2, n=1, max_new=4):
        rt.submit("starved", r)
    rt.drain(max_steps=12)
    rep = rt.report()
    rows = {t.tenant_id: t for t in rep.tenants}
    assert rows["served"].completed >= 1
    assert rows["starved"].completed == 0 and rows["starved"].submitted == 1
    assert rep.fairness < 0.8, rep.summary()


# ---------------------------------------------------------------------------
# AdaptiveQuota occupancy signal (satellite)
# ---------------------------------------------------------------------------

def test_adaptive_quota_occupancy_signal(model):
    """Grid-fill collapse shrinks the aggregate slot budget (never below
    one slot per tenant) and recovery restores it — the ROADMAP 'fold the
    occupancy histogram into AdaptiveQuota' item."""
    cfg, params = model
    sess = ServeSession(params, cfg, batch_slots=4, max_len=MAX_LEN, rt=RT)
    tracer = telemetry.Tracer()
    aq = AdaptiveQuota(interval=2, fill_floor=0.5, n_cores=4)
    sched = StreamScheduler(sess, admission="fair_quantum", quota=aq,
                            tracer=tracer)
    sched.add_tenant("a")
    sched.add_tenant("b")
    assert sum(aq.slot_cap(sched, t) for t in sched.tenants.values()) == 4
    for _ in range(3):                       # collapsed fill: 1 tile / 4
        tracer.record_matmul(8, 8, 8, precision="bf16")
    for _ in range(3):
        sched.step()                         # interval hits at step 2
    assert aq.occupancy_shrinks == 1
    assert aq.budget(sched) == 3
    assert sum(aq.caps.values()) <= 3
    for _ in range(4):
        sched.step()                         # keeps collapsing to floor
    assert aq.budget(sched) == 2             # floor: one slot per tenant
    assert sum(aq.caps.values()) == 2
    events = [e for e in tracer.events("quota")
              if e.meta.get("signal") == "occupancy"]
    assert events and events[0].meta["fill"] < 0.5
    # recovery: saturate the window with high-fill GEMMs
    for _ in range(20):
        tracer.record_matmul(1024, 1024, 1024, precision="bf16")
    for _ in range(2):
        sched.step()
    assert aq.budget(sched) == 3             # one slot back per interval
    assert sum(aq.caps.values()) == 3        # caps REGROW with the budget
    for _ in range(2):
        sched.step()
    assert aq.budget(sched) == 4             # fully recovered
    assert sum(aq.caps.values()) == 4
    # defaults leave the signal off: no behavior change for existing users
    assert AdaptiveQuota().fill_floor is None


# ---------------------------------------------------------------------------
# Deprecated facades
# ---------------------------------------------------------------------------

def test_partitioned_server_shim_warns_and_serves(model):
    cfg, params = model
    from repro.runtime.partition import PartitionedServer, run_partitioned
    with pytest.warns(DeprecationWarning, match="ServingRuntime"):
        srv = PartitionedServer(params, cfg, n_partitions=2,
                                batch_slots=2, max_len=MAX_LEN, rt=RT,
                                placement="spread")
    srv.add_tenant("t0")
    srv.add_tenant("t1")
    reqs = _requests(cfg, 0, n=2, max_new=4)
    for i, r in enumerate(reqs):
        srv.submit(f"t{i % 2}", r)
    done = srv.run()                   # legacy verb -> drain
    assert len(done) == 2
    rep = srv.report()
    assert rep.n_partitions == 2 and rep.tokens_out == 8
    assert isinstance(srv.runtime, ServingRuntime)
    with pytest.warns(DeprecationWarning):
        run_partitioned(params, cfg,
                        {"t": _requests(cfg, 1, n=1, max_new=4)},
                        n_partitions=1, batch_slots=2, max_len=MAX_LEN,
                        rt=RT)


def test_run_serving_with_declared_tenants(model):
    """Spec-declared tenants are pre-registered (pinned or routed) and
    extra workload tenants are routed on demand."""
    cfg, params = model
    spec = dataclasses.replace(
        _spec(n=2, placement="spread"),
        tenants=(TenantSpec(id="pinned", partition=1),))
    rep = run_serving(params, cfg, spec,
                      {"pinned": _requests(cfg, 0, n=1, max_new=4),
                       "routed": _requests(cfg, 1, n=1, max_new=4)},
                      rt=RT)
    assert rep.tenant_partition["pinned"] == 1
    assert rep.tenant_partition["routed"] == 0   # spread fills the gap
    assert rep.tokens_out == 8


# ---------------------------------------------------------------------------
# Migration hysteresis (cooldown + strict-improvement victim selection)
# ---------------------------------------------------------------------------

def test_migration_cooldown_blocks_ping_pong(model):
    """Oscillating load must not cause migration ping-pong: after the
    first re-route, an immediate skew inversion stays put until the
    cooldown expires, and consecutive migrations are always at least
    ``cooldown`` steps apart."""
    cfg, _ = model
    cool = 12
    rt = _runtime(model, _spec(migration=MigrationSpec(
        enabled=True, interval=2, threshold=2.0, cooldown=cool)))
    rt.add_tenant("hog", partition=0)
    rt.add_tenant("small", partition=0)
    rt.add_tenant("b", partition=1)
    for r in _requests(cfg, 0, n=6, max_new=8):
        rt.submit("hog", r)
    for r in _requests(cfg, 1, n=2, max_new=6):
        rt.submit("small", r)
    steps = 0
    while not rt.migrations and steps < 60:
        rt.step()
        steps += 1
    assert rt.migrations and rt.migrations[0].reason == "load_aware"
    first = rt.migrations[0].start_step
    # oscillation stimulus: invert the skew right away — flood the
    # partition the hog just landed on
    assert rt.tenant_partition["hog"] == 1
    for r in _requests(cfg, 5, n=8, max_new=24):
        rt.submit("b", r)
    guard = 0
    while rt.step_count + 1 < first + cool and guard < 200:
        rt.step()
        guard += 1
        assert len(rt.migrations) == 1   # hysteresis: no ping-pong yet
    rt.drain()
    starts = [m.start_step for m in rt.migrations]
    assert all(b - a >= cool for a, b in zip(starts, starts[1:]))


def test_pick_victim_requires_strict_improvement(model):
    """The victim picker is the other half of the hysteresis: a move
    that merely mirrors the imbalance (or ties it) is refused, and when
    several tenants would help, the best equalizer wins."""
    cfg, _ = model
    rt = _runtime(model, _spec(placement="spread"))
    rt.add_tenant("solo", partition=0)
    rt.add_tenant("peer", partition=1)
    # queued-only work with exact costs: request_cost = len(prompt)+max_new
    (r,) = _requests(cfg, 0, n=1, max_new=11, length=5)      # cost 16
    rt.submit("solo", r)
    works = [rt._partition_work(0), rt._partition_work(1)]
    assert works == [16.0, 0.0]
    # a lone tenant's move mirrors the whole imbalance onto the target:
    # |0 - 16| == |16 - 0| -> not a strict improvement -> no victim
    assert rt._pick_victim(0, 1, works) is None
    # a smaller second tenant and some target-side work break the tie:
    # moving "lite" (cost 8) equalizes 26/8 -> 18/16; moving "solo"
    # (cost 18) overshoots to 8/26 (no better than now) and is refused
    rt.add_tenant("lite", partition=0)
    (r2,) = _requests(cfg, 1, n=1, max_new=13, length=5)     # cost 18
    rt.submit("solo", r2)
    rt.schedulers[0].tenants["solo"].queue.remove(r)
    rt.submit("lite", _requests(cfg, 2, n=1, max_new=3, length=5)[0])
    rt.submit("peer", _requests(cfg, 3, n=1, max_new=3, length=5)[0])
    works = [rt._partition_work(0), rt._partition_work(1)]
    assert works == [26.0, 8.0]
    assert rt._pick_victim(0, 1, works) == "lite"


# ---------------------------------------------------------------------------
# Async execution lanes (overlap on/off equivalence)
# ---------------------------------------------------------------------------

def test_overlap_serving_token_equality_and_lane_events(model):
    """The tentpole contract: stepping heterogeneous partitions through
    execution lanes (planner-paired sparse24 beside dense) changes wall
    time only — greedy tokens match the serialized loop and the solo
    runs, and the overlap decision is visible on the decode events."""
    cfg, _ = model
    outs = {}
    for name, ov in (("overlap", True), ("serialized", False)):
        reqs = _requests(cfg, 0, n=6, max_new=6)
        rt = _runtime(model, _spec(policies=[FP8SP, BF16],
                                   placement="spread", overlap=ov))
        rt.add_tenant("t0")
        rt.add_tenant("t1")
        for j, r in enumerate(reqs):
            rt.submit(f"t{j % 2}", r)
        rt.drain()
        outs[name] = [list(r.out) for r in reqs]
        assert all(r.done for r in reqs)
        if ov:
            merged = rt.merged_tracer()
            evs = [e for e in merged.events("decode")
                   if e.lane and e.overlap_group >= 0]
            assert evs, "overlap on but no lane-tagged decode events"
            assert {e.lane for e in evs} == {"lane0", "lane1"}
            assert merged.overlap_summary()["groups"] >= 1
            solo = {}
        else:
            evs = [e for e in rt.merged_tracer().events("decode")
                   if e.lane.startswith("lane") or e.overlap_group >= 0]
            assert not evs, \
                "serialized loop must not run on planner lanes"
    assert outs["overlap"] == outs["serialized"]
    # per-tenant solo equality under each partition's own policy
    reqs = _requests(cfg, 0, n=6, max_new=6)
    for pol, k in ((FP8SP, 0), (BF16, 1)):
        mine = [r for j, r in enumerate(reqs) if j % 2 == k]
        assert [out for j, out in enumerate(outs["overlap"])
                if j % 2 == k] == _solo_outputs(model, mine, policy=pol)


def test_overlap_token_equality_across_live_migration(model):
    """Lanes stay token-exact through a mid-request live handoff."""
    cfg, _ = model
    outs = {}
    for ov in (True, False):
        reqs = _requests(cfg, 0, n=2, max_new=10)
        rt = _runtime(model, _spec(overlap=ov))
        rt.add_tenant("mover", partition=0)
        for r in reqs:
            rt.submit("mover", r)
        for _ in range(3):
            rt.step()
        rt.migrate("mover", 1)
        rt.drain()
        assert all(r.done for r in reqs)
        outs[ov] = [list(r.out) for r in reqs]
        assert outs[ov] == [list(o) for o in _solo_outputs(model, reqs)]
    assert outs[True] == outs[False]
