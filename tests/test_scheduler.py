"""Multi-tenant serving scheduler: continuous-batching correctness
(per-slot positions, bulk-prefill admission, slot-reuse isolation) and
admission-policy fairness.

The correctness tests are regressions for the two serving bugs the
scheduler refactor fixed: (1) a freed slot's KV cache leaked into the next
occupant (lockstep positions + no clear on free), and (2) admission-time
token-by-token prefill stepped *all* active slots and discarded their
sampled tokens. Both manifest as a multi-tenant greedy run diverging from
the same request served alone — so every test here pins exact token
equality against single-tenant runs.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime.scheduler import (
    ADMISSION_POLICIES, StreamScheduler, run_tenants)
from repro.runtime.serve_loop import Request, ServeSession

RT = RuntimeCfg(ssm_chunk=16)
MAX_LEN = 64


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _session(model, slots=4, **kw):
    cfg, params = model
    return ServeSession(params, cfg, batch_slots=slots, max_len=MAX_LEN,
                        rt=RT, **kw)


def _prompts(cfg, n, length=5, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, length).astype(np.int32)
            for _ in range(n)]


def _solo_run(model, prompt, max_new, slots=4):
    """Reference: the request served alone (same slot count, so the decode
    batch shape — and thus the arithmetic — matches the shared run)."""
    sess = _session(model, slots=slots)
    sess.submit(Request(uid=0, prompt=prompt.copy(), max_new=max_new))
    (done,) = sess.run()
    return done.out


# ---------------------------------------------------------------------------
# Continuous-batching correctness (regression: stale KV / dropped tokens)
# ---------------------------------------------------------------------------

def test_multi_tenant_matches_single_tenant_exactly(model):
    """Greedy multi-tenant decode == each request served alone, token for
    token (acceptance criterion for the scheduler refactor)."""
    cfg, _ = model
    prompts = _prompts(cfg, 4)
    sess = _session(model, slots=4)
    workloads = {f"t{i}": [Request(uid=i, prompt=p.copy(), max_new=6)]
                 for i, p in enumerate(prompts)}
    rep = run_tenants(sess, workloads, admission="fair_quantum")
    assert rep.tokens_out == 4 * 6
    for i, p in enumerate(prompts):
        (req,) = workloads[f"t{i}"]
        assert req.done
        assert req.out == _solo_run(model, p, 6), f"tenant t{i} diverged"


def test_slot_reuse_does_not_leak_previous_kv(model):
    """A request admitted into a reused slot must produce the same tokens
    as in a fresh session — the freed slot's cache rows are cleared.
    (Fails on the old lockstep ServeSession: the new occupant attended to
    the previous occupant's keys/values.)"""
    cfg, _ = model
    pa, pb = _prompts(cfg, 2, seed=1)
    sess = _session(model, slots=1)
    sess.submit(Request(uid=0, prompt=pa.copy(), max_new=8))
    sess.run()
    # slot 0 was freed: its pos rows must read "unwritten"
    pos_buf = np.asarray(sess.caches["layers"]["b0"]["pos"])
    assert (pos_buf == -1).all()
    kv_buf = np.asarray(sess.caches["layers"]["b0"]["k"], np.float32)
    assert (kv_buf == 0).all()
    # reuse the slot for B; output must match B-served-fresh exactly
    sess.submit(Request(uid=1, prompt=pb.copy(), max_new=8))
    done = sess.run()
    assert done[1].out == _solo_run(model, pb, 8, slots=1)


def test_admission_does_not_drop_active_slot_tokens(model):
    """Admitting B while A is mid-decode must not cost A any output:
    admission is one bulk prefill of B only. (Fails on the old _admit,
    which ran a full decode step per prompt token and threw away every
    active slot's sampled tokens.)"""
    cfg, _ = model
    pa, pb = _prompts(cfg, 2, seed=2)
    ref_a = _solo_run(model, pa, 12, slots=2)
    ref_b = _solo_run(model, pb, 6, slots=2)

    sess = _session(model, slots=2)
    a = Request(uid=0, prompt=pa.copy(), max_new=12)
    sess.admit(a)
    for _ in range(4):                   # A decodes alone for a while
        sess.decode_once()
    assert len(a.out) == 5               # 1 at admit + 4 decode steps
    b = Request(uid=1, prompt=pb.copy(), max_new=6)
    sess.admit(b)                        # mid-flight admission
    assert len(a.out) == 5               # admission cost A nothing
    while not (a.done and b.done):
        sess.decode_once()
    assert a.out == ref_a
    assert b.out == ref_b


def test_session_single_queue_still_works(model):
    """Back-compat: submit/run drains more requests than slots."""
    cfg, _ = model
    sess = _session(model, slots=2)
    for uid, p in enumerate(_prompts(cfg, 5, seed=3)):
        sess.submit(Request(uid=uid, prompt=p, max_new=4))
    done = sess.run()
    assert len(done) == 5
    for r in done:
        assert len(r.out) == 4
        assert all(0 <= t < cfg.padded_vocab for t in r.out)


# ---------------------------------------------------------------------------
# Admission policies: ordering + fairness
# ---------------------------------------------------------------------------

def _identical_workloads(cfg, n_tenants=4, reqs=2, max_new=6):
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(reqs)]
    return {f"t{i}": [Request(uid=i * 100 + j, prompt=p.copy(),
                              max_new=max_new)
                      for j, p in enumerate(prompts)]
            for i in range(n_tenants)}


def _run(model, admission, slots):
    cfg, _ = model
    sess = _session(model, slots=slots)
    sched = StreamScheduler(sess, admission=admission)
    wl = _identical_workloads(cfg)
    for tid in wl:
        sched.add_tenant(tid)
    for tid, reqs in wl.items():
        for r in reqs:
            sched.submit(tid, r)
    sched.run()
    return sched


def test_admission_ordering(model):
    """fifo admits tenant t0's whole backlog first; the fair policies
    spread the first admissions across distinct tenants."""
    fifo = _run(model, "fifo", slots=2)
    assert fifo.admitted_order[:2] == ["t0", "t0"]
    rr = _run(model, "round_robin", slots=2)
    assert rr.admitted_order[:2] == ["t0", "t1"]
    fq = _run(model, "fair_quantum", slots=4)
    assert sorted(fq.admitted_order[:4]) == ["t0", "t1", "t2", "t3"]


def test_fair_quantum_fairness_at_least_0p8(model):
    """Acceptance criterion: 4 identical tenants under fair_quantum reach
    per-tenant fairness >= 0.8; under fifo the same workload collapses."""
    fq = _run(model, "fair_quantum", slots=4).report()
    assert fq.fairness >= 0.8, fq.summary()
    assert fq.cv <= 0.2
    fifo = _run(model, "fifo", slots=4).report()
    assert fifo.fairness < fq.fairness, (fifo.summary(), fq.summary())


def test_fair_quantum_beats_fifo_under_contention(model):
    """With fewer slots than tenants (true contention), the credit-based
    policy still dominates fifo on fairness — the serving-layer analogue
    of the paper's Fig-5 collapse."""
    fifo = _run(model, "fifo", slots=2).report()
    fq = _run(model, "fair_quantum", slots=2).report()
    assert fq.fairness > fifo.fairness
    assert fq.cv < fifo.cv
    # aggregate throughput is not sacrificed: same tokens, same steps
    assert fq.tokens_out == fifo.tokens_out
    assert fq.steps == fifo.steps


def test_fair_quantum_respects_weights(model):
    """A weight-2 tenant is charged half the virtual time per unit work,
    so it wins admissions ~2x as often as weight-1 tenants."""
    cfg, _ = model
    sess = _session(model, slots=1)
    sched = StreamScheduler(sess, admission="fair_quantum")
    sched.add_tenant("heavy", weight=2.0)
    sched.add_tenant("light", weight=1.0)
    rng = np.random.default_rng(5)
    for i in range(6):
        p = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
        sched.submit("heavy", Request(uid=i, prompt=p, max_new=4))
        sched.submit("light", Request(uid=100 + i, prompt=p.copy(),
                                      max_new=4))
    sched.run(max_steps=2000)
    first6 = sched.admitted_order[:6]
    assert first6.count("heavy") == 4 and first6.count("light") == 2


def test_scheduler_report_shape(model):
    sched = _run(model, "round_robin", slots=2)
    rep = sched.report()
    d = rep.to_dict()
    assert set(d) >= {"admission", "fairness", "cv", "overlap_efficiency",
                      "tenants", "tokens_out"}
    assert len(rep.tenants) == 4
    for t in rep.tenants:
        assert t.completed == 2
        assert t.p50_latency_s >= 0 and t.p99_latency_s >= t.p50_latency_s
    assert 0.0 <= rep.fairness <= 1.0
    assert rep.overlap_efficiency > 0.0    # tenants did share the batch


def test_unknown_admission_policy_rejected(model):
    with pytest.raises(ValueError):
        StreamScheduler(_session(model, slots=2), admission="lottery")
    assert set(ADMISSION_POLICIES) == {"fifo", "round_robin",
                                       "fair_quantum"}
