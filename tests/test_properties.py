"""Property-based tests (hypothesis) for the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # not in the CPU CI image
from hypothesis import given, settings, strategies as st

from repro.core import concurrency as cc
from repro.core import fp8, sparsity as sp

SET = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# FP8 quantization properties
# ---------------------------------------------------------------------------

@SET
@given(st.lists(st.floats(min_value=-1e4, max_value=1e4,
                          allow_nan=False, width=32),
                min_size=4, max_size=64))
def test_fp8_quantize_bounded_error(vals):
    x = jnp.asarray(vals, jnp.float32)
    amax = float(jnp.max(jnp.abs(x)))
    if amax == 0.0:
        return
    ts = fp8.update_scale(fp8.TensorScale.init(2), jnp.float32(amax))
    xdq = fp8.quantize(x, ts).astype(jnp.float32) / ts.scale
    # E4M3 relative step is 2^-3 at worst within a binade of the max
    assert float(jnp.max(jnp.abs(xdq - x))) <= amax * (2 ** -3) + 1e-6


@SET
@given(st.floats(min_value=1e-6, max_value=1e6, allow_nan=False))
def test_fp8_scale_positive_finite(amax):
    ts = fp8.update_scale(fp8.TensorScale.init(4), jnp.float32(amax))
    assert np.isfinite(float(ts.scale)) and float(ts.scale) > 0


# ---------------------------------------------------------------------------
# 2:4 sparsity properties
# ---------------------------------------------------------------------------

@SET
@given(st.integers(min_value=1, max_value=8).map(lambda g: g * 8),
       st.integers(min_value=1, max_value=16),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_prune_pack_unpack_roundtrip(k, n, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n))
    w24 = sp.prune_24(w)
    assert bool(sp.check_24(w24))
    vals, meta = sp.pack_24(w24)
    np.testing.assert_array_equal(np.asarray(sp.unpack_24(vals, meta)),
                                  np.asarray(w24))


@SET
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_prune_preserves_l1_at_least_half(seed):
    """Keeping the 2 largest of 4 preserves >= 50% of every group's |mass|."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (32, 8))
    w24 = sp.prune_24(w)
    g = np.abs(np.asarray(w)).reshape(8, 4, 8).sum(axis=1)
    g24 = np.abs(np.asarray(w24)).reshape(8, 4, 8).sum(axis=1)
    assert (g24 >= 0.5 * g - 1e-5).all()


@SET
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_sparse_matmul_error_zero(seed):
    keys = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(keys[0], (4, 32))
    w24 = sp.prune_24(jax.random.normal(keys[1], (32, 8)))
    vals, meta = sp.pack_24(w24)
    got = sp.sparse24_matmul_ref(x, vals, meta, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w24),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Concurrency metric properties
# ---------------------------------------------------------------------------

@SET
@given(st.lists(st.floats(min_value=1e-3, max_value=1e3, allow_nan=False),
                min_size=1, max_size=16))
def test_fairness_le_one_and_permutation_invariant(times):
    f = cc.fairness(times)
    assert f <= 1.0 + 1e-9
    assert cc.fairness(list(reversed(times))) == pytest.approx(f)
    # scale invariance
    assert cc.fairness([t * 7.5 for t in times]) == pytest.approx(f)


@SET
@given(st.lists(st.floats(min_value=1e-3, max_value=1e3, allow_nan=False),
                min_size=2, max_size=16))
def test_fairness_min_max_in_unit_interval(times):
    f = cc.fairness_min_max(times)
    assert 0.0 < f <= 1.0 + 1e-9


@SET
@given(st.floats(min_value=0.1, max_value=100.0),
       st.integers(min_value=2, max_value=16))
def test_overlap_efficiency_bounds(serial, n):
    # e == 1 at perfect overlap, 0 at fully serial, negative if concurrency
    # SLOWS things down (real contention regimes) — bounded below by -n/(n-1)
    for conc, lo, hi in ((serial / n, 1.0, 1.0), (serial, 0.0, 0.0),
                         (serial / 2, 0.0, 1.0),
                         (serial * 1.5, -n / (n - 1) - 1e-9, 0.0)):
        e = cc.overlap_efficiency(serial, conc, n)
        assert lo - 1e-9 <= e <= hi + 1e-9, (e, conc)


# ---------------------------------------------------------------------------
# Attention: chunked == dense reference across random shapes
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.sampled_from([(1, 64, 2, 1, 16), (2, 128, 4, 2, 32),
                        (1, 96, 3, 3, 16)]),
       st.sampled_from([32, 64]),
       st.booleans())
def test_chunked_attention_matches_reference(dims, chunk, windowed):
    from repro.kernels.ref import flash_attention_ref
    from repro.models.attention import chunked_attention
    from repro.models.layers import RuntimeCfg
    b, s, h, kvh, hd = dims
    if s % chunk:
        return
    keys = jax.random.split(jax.random.PRNGKey(hash(dims) % 2 ** 31), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd))
    k = jax.random.normal(keys[1], (b, s, kvh, hd))
    v = jax.random.normal(keys[2], (b, s, kvh, hd))
    window = 32 if windowed else 0
    rt = RuntimeCfg(chunk_q=chunk, chunk_kv=chunk, act_dtype=jnp.float32)
    got = chunked_attention(q, k, v, causal=True, window=window, rt=rt)
    if windowed:
        # reference with explicit banded mask
        import math
        kk = jnp.repeat(k, h // kvh, axis=2)
        vv = jnp.repeat(v, h // kvh, axis=2)
        sco = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / math.sqrt(hd)
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        mask = (qi >= ki) & (qi - ki < window)
        sco = jnp.where(mask[None, None], sco, -1e30)
        want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sco, -1), vv)
    else:
        want = flash_attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Scan-vs-static loop equivalence (the memory-probe lowering is numerically
# identical to the cost lowering)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_attention_static_vs_scan_loops(seed):
    from repro.models.attention import chunked_attention
    from repro.models.layers import RuntimeCfg
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(keys[0], (1, 128, 2, 16))
    k = jax.random.normal(keys[1], (1, 128, 2, 16))
    v = jax.random.normal(keys[2], (1, 128, 2, 16))
    a = chunked_attention(q, k, v, causal=True,
                          rt=RuntimeCfg(chunk_q=32, chunk_kv=32,
                                        static_loops=True,
                                        act_dtype=jnp.float32))
    b = chunked_attention(q, k, v, causal=True,
                          rt=RuntimeCfg(chunk_q=32, chunk_kv=32,
                                        static_loops=False,
                                        act_dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
