import jax
import jax.numpy as jnp
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def assert_finite(tree, what=""):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        assert bool(jnp.isfinite(leaf).all()), f"non-finite {what} at {path}"
