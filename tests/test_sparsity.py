"""Unit tests for core/sparsity.py — 2:4 invariants and packed matmul."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparsity as sp


def test_prune_keeps_top2_magnitudes():
    w = jnp.array([[1.0], [-3.0], [2.0], [0.5],
                   [4.0], [0.1], [-0.2], [5.0]])
    w24 = sp.prune_24(w)
    np.testing.assert_array_equal(
        np.asarray(w24[:, 0]), [0.0, -3.0, 2.0, 0.0, 4.0, 0.0, 0.0, 5.0])


def test_prune_is_24(rng):
    w = jax.random.normal(rng, (128, 32))
    w24 = sp.prune_24(w)
    assert bool(sp.check_24(w24))
    assert float((w24 != 0).mean()) == 0.5


def test_prune_idempotent(rng):
    w = jax.random.normal(rng, (64, 16))
    w24 = sp.prune_24(w)
    np.testing.assert_array_equal(np.asarray(sp.prune_24(w24)),
                                  np.asarray(w24))


def test_pack_unpack_exact(rng):
    w24 = sp.prune_24(jax.random.normal(rng, (64, 16)))
    vals, meta = sp.pack_24(w24)
    assert vals.shape == (32, 16)
    assert meta.shape == (8, 16) and meta.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(sp.unpack_24(vals, meta)),
                                  np.asarray(w24))


def test_pack_handles_fewer_than_two_nonzeros():
    w = jnp.zeros((8, 2))
    w = w.at[0, 0].set(3.0)   # group 0 of col 0 has ONE nonzero
    vals, meta = sp.pack_24(w)
    np.testing.assert_array_equal(np.asarray(sp.unpack_24(vals, meta)),
                                  np.asarray(w))


def test_sparse_matmul_matches_dense(rng):
    x = jax.random.normal(rng, (8, 64))
    w24 = sp.prune_24(jax.random.normal(jax.random.PRNGKey(7), (64, 16)))
    vals, meta = sp.pack_24(w24)
    out = sp.sparse24_matmul_ref(x, vals, meta, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w24),
                               rtol=1e-5, atol=1e-4)


def test_block24_prune_and_matmul(rng):
    w = jax.random.normal(rng, (512, 16))
    wp, keep = sp.prune_block24(w, block=64)
    assert float(keep.mean()) == 0.5
    # kept blocks are untouched, dropped blocks all-zero
    nb = 512 // 64
    blocks = np.asarray(wp).reshape(nb, 64, 16)
    for i, k in enumerate(np.asarray(keep)):
        if k:
            np.testing.assert_array_equal(
                blocks[i], np.asarray(w).reshape(nb, 64, 16)[i])
        else:
            assert (blocks[i] == 0).all()
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 512))
    kept_idx = tuple(int(i) for i in np.nonzero(np.asarray(keep))[0])
    out = sp.block24_matmul_ref(x, wp, jnp.asarray(keep), block=64,
                                out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ wp),
                               rtol=1e-4, atol=1e-3)


def test_byte_accounting():
    # packed fp8 = 0.3125x of dense bf16
    assert sp.packed_bytes(128, 64) == 64 * 64 * 1 + 16 * 64
    assert sp.dense_bytes(128, 64) == 128 * 64 * 2
    assert sp.packed_bytes(128, 64) / sp.dense_bytes(128, 64) == 0.3125


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
