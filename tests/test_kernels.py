"""Per-kernel allclose tests: shape/dtype sweeps vs the ref.py oracles.

Kernels execute through the Pallas interpreter on CPU (same BlockSpec
tiling and control flow as the Mosaic TPU path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparsity as sp
from repro.kernels import ops, ref


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),     # single block
    (256, 512, 256, 128, 128, 128),     # multi-block all dims
    (128, 256, 384, 64, 128, 256),      # uneven block mix
])
@pytest.mark.parametrize("dtype", [jnp.float8_e4m3fn, jnp.float8_e5m2])
def test_fp8_matmul_kernel(m, k, n, bm, bn, bk, dtype):
    key = jax.random.PRNGKey(0)
    x = (jax.random.normal(key, (m, k)) * 4).astype(dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 4).astype(dtype)
    out = ops.fp8_matmul(x, w, out_dtype=jnp.float32, bm=bm, bn=bn, bk=bk)
    want = ref.fp8_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-2)


def test_fp8_matmul_dynamic_reshapes_leading_dims():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, 64, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 128), jnp.float32)
    out = ops.fp8_matmul_dynamic(x, w, out_dtype=jnp.float32)
    assert out.shape == (2, 64, 128)
    rel = float(jnp.linalg.norm(out - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.08


@pytest.mark.parametrize("m,k,n", [(128, 256, 128), (64, 512, 256)])
@pytest.mark.parametrize("vdtype", [jnp.bfloat16, jnp.float8_e4m3fn])
def test_sparse24_kernel(m, k, n, vdtype):
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (m, k), jnp.float32).astype(jnp.bfloat16)
    w24 = sp.prune_24(
        jax.random.normal(jax.random.PRNGKey(5), (k, n)).astype(vdtype))
    vals, meta = sp.pack_24(w24)
    out = ops.sparse24_matmul(x, vals, meta, out_dtype=jnp.float32,
                              bm=64, bn=128, bk=128)
    want = ref.sparse24_matmul_ref(x, vals, meta, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_block24_kernel():
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (64, 512), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(7), (512, 128)).astype(jnp.bfloat16)
    wp, keep = sp.prune_block24(w, block=64)
    kept_idx = tuple(int(i) for i in np.nonzero(np.asarray(keep))[0])
    w_packed = jnp.concatenate([wp[i * 64:(i + 1) * 64] for i in kept_idx])
    out = ops.block24_matmul(x, w_packed, kept_idx, block=64,
                             out_dtype=jnp.float32)
    want = ref.block24_matmul_ref(x, w_packed, kept_idx, block=64,
                                  out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("b,h,kvh,s,hd,bq,bk", [
    (1, 4, 4, 128, 64, 64, 64),        # MHA
    (2, 8, 2, 256, 64, 64, 128),       # GQA, rectangular blocks
    (1, 4, 1, 128, 32, 128, 64),       # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_kernel(b, h, kvh, s, hd, bq, bk, causal):
    keys = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(keys[0], (b, s, h, hd)).astype(jnp.bfloat16)
    k = jax.random.normal(keys[1], (b, s, kvh, hd)).astype(jnp.bfloat16)
    v = jax.random.normal(keys[2], (b, s, kvh, hd)).astype(jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=5e-2, atol=5e-2)


def test_flash_matches_model_chunked_attention():
    """The Pallas kernel and the jnp chunked path agree (drop-in swap)."""
    from repro.models.attention import chunked_attention
    from repro.models.layers import RuntimeCfg
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    b, s, h, kvh, hd = 2, 128, 4, 2, 32
    q = jax.random.normal(keys[0], (b, s, h, hd)).astype(jnp.bfloat16)
    k = jax.random.normal(keys[1], (b, s, kvh, hd)).astype(jnp.bfloat16)
    v = jax.random.normal(keys[2], (b, s, kvh, hd)).astype(jnp.bfloat16)
    rt = RuntimeCfg(chunk_q=64, chunk_kv=64)
    a = chunked_attention(q, k, v, causal=True, rt=rt)
    bpal = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(bpal, np.float32),
                               rtol=5e-2, atol=5e-2)
