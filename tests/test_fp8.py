"""Unit tests for core/fp8.py — formats, delayed scaling, matmul numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fp8


def test_fp8_max_values():
    assert fp8.fp8_max(fp8.E4M3) == 448.0
    assert fp8.fp8_max(fp8.E5M2) == 57344.0


def test_quantize_roundtrip_small_error():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 64), jnp.float32)
    ts = fp8.update_scale(fp8.TensorScale.init(4), fp8.current_amax(x))
    xq = fp8.quantize(x, ts)
    xdq = xq.astype(jnp.float32) / ts.scale
    # E4M3 has ~2 decimal digits: relative error bounded by 2^-3 of amax bin
    assert float(jnp.max(jnp.abs(xdq - x))) < float(jnp.max(jnp.abs(x))) * 0.07


def test_delayed_scaling_uses_history_max():
    ts = fp8.TensorScale.init(4)
    for amax in (1.0, 10.0, 2.0):
        ts = fp8.update_scale(ts, jnp.float32(amax))
    # history = [2, 10, 1, 0] -> max 10 -> scale 448/10
    np.testing.assert_allclose(float(ts.scale), 44.8, rtol=1e-5)
    # rolls out after `history` more updates
    for _ in range(4):
        ts = fp8.update_scale(ts, jnp.float32(1.0))
    np.testing.assert_allclose(float(ts.scale), 448.0, rtol=1e-5)


def test_zero_amax_guard():
    ts = fp8.update_scale(fp8.TensorScale.init(2), jnp.float32(0.0))
    assert float(ts.scale) == 1.0


@pytest.mark.parametrize("mk,nk", [(8, 16), (32, 64)])
def test_fp8_matmul_close_to_f32(mk, nk):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (mk, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (64, nk), jnp.float32)
    out = fp8.fp8_matmul(x, w, jnp.float32(1.0), jnp.float32(1.0))
    ref = x @ w
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel < 0.08, rel


def test_fp8_matmul_gradients_close():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (16, 32), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(4), (32, 8), jnp.float32)

    def loss_q(x, w):
        return jnp.sum(fp8.fp8_matmul(x, w, jnp.float32(1.0),
                                      jnp.float32(1.0)) ** 2)

    def loss_f(x, w):
        return jnp.sum((x @ w) ** 2)

    gq = jax.grad(loss_q, argnums=(0, 1))(x, w)
    gf = jax.grad(loss_f, argnums=(0, 1))(x, w)
    for a, b in zip(gq, gf):
        rel = float(jnp.linalg.norm(a - b) / jnp.linalg.norm(b))
        assert rel < 0.15, rel


def test_scale_gradients_are_zero():
    x = jnp.ones((4, 8))
    w = jnp.ones((8, 4))

    def loss(s):
        return jnp.sum(fp8.fp8_matmul(x, w, s, jnp.float32(1.0)))
    g = jax.grad(loss)(jnp.float32(1.0))
    assert float(g) == 0.0


def test_dynamic_fp8_matmul_scales_large_values():
    # values far outside fp8 range still multiply correctly via scaling
    x = jnp.full((4, 8), 1e4, jnp.float32)
    w = jnp.full((8, 4), 2e-6, jnp.float32)
    out = fp8.dynamic_fp8_matmul(x, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), 8 * 1e4 * 2e-6, rtol=0.05)


def test_fp8_linear_state_threading():
    state = fp8.init_fp8_state(["l1"], history=4)
    x = jnp.ones((4, 8))
    w = jnp.full((8, 4), 2.0)
    collect = {}
    out = fp8.fp8_linear(x, w, state, "l1", collect=collect)
    assert out.shape == (4, 4)
    assert set(collect) == {"l1/x", "l1/w"}
    new = fp8.fold_amaxes(state, collect)
    assert float(new["l1/w"].amax_history[0]) == 2.0
