"""MoE router/dispatch invariants + layer behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import moe
from repro.models.layers import RuntimeCfg

RT = RuntimeCfg(ssm_chunk=16)


@pytest.fixture
def cfg():
    return get_reduced("granite-moe-3b-a800m")   # 8e top-2 reduced


def test_capacity_formula(cfg):
    c = moe.capacity(cfg, 64)
    assert c == int(np.ceil(64 * cfg.experts_top_k
                            * cfg.moe_capacity_factor / cfg.num_experts))
    assert moe.capacity(cfg, 1) >= 1


def test_dispatch_respects_capacity(cfg):
    G, gs, E = 2, 64, cfg.num_experts
    cap = moe.capacity(cfg, gs)
    logits = jax.random.normal(jax.random.PRNGKey(0), (G, gs, E))
    combine, dispatch, aux = moe.router_dispatch(logits, cfg, cap)
    assert combine.shape == (G, gs, E, cap)
    # each (expert, slot) holds at most one token
    per_slot = np.asarray(dispatch).sum(axis=1)          # (G, E, C)
    assert per_slot.max() <= 1
    # each token occupies at most top_k slots
    per_token = np.asarray(dispatch).sum(axis=(2, 3))    # (G, gs)
    assert per_token.max() <= cfg.experts_top_k
    # combine weights are convex-ish: within [0, 1], sum <= 1 per token
    cw = np.asarray(combine)
    assert cw.min() >= 0.0 and cw.max() <= 1.0 + 1e-6
    assert cw.sum(axis=(2, 3)).max() <= 1.0 + 1e-5


def test_dispatch_weights_match_topk_softmax(cfg):
    """Where capacity is not binding, combine == renormalized top-k gates."""
    big = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    G, gs, E = 1, 16, big.num_experts
    cap = moe.capacity(big, gs)
    logits = jax.random.normal(jax.random.PRNGKey(1), (G, gs, E))
    combine, dispatch, _ = moe.router_dispatch(logits, big, cap)
    gates = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(gates, big.experts_top_k)
    topv = topv / topv.sum(-1, keepdims=True)
    got = np.asarray(combine).sum(axis=-1)               # (G, gs, E)
    want = np.zeros_like(got)
    for g in range(G):
        for s in range(gs):
            for j in range(big.experts_top_k):
                want[g, s, int(topi[g, s, j])] += float(topv[g, s, j])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_aux_loss_balanced_router_near_one(cfg):
    # random logits route ~uniformly in expectation -> aux ~ 1.0; a badly
    # imbalanced router (all tokens to expert 0) scores higher
    G, gs, E = 2, 512, cfg.num_experts
    cap = moe.capacity(cfg, gs)
    logits = jax.random.normal(jax.random.PRNGKey(0), (G, gs, E)) * 0.01
    _, _, aux = moe.router_dispatch(logits, cfg, cap)
    np.testing.assert_allclose(float(aux), 1.0, rtol=0.25)
    hot = jnp.zeros((G, gs, E)).at[..., 0].set(10.0)
    _, _, aux_hot = moe.router_dispatch(hot, cfg, cap)
    assert float(aux_hot) > float(aux) * 1.5


def test_moe_layer_forward_and_grads(cfg):
    p = moe.init_moe(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model),
                          jnp.float32)

    def loss(p, x):
        out, aux = moe.moe_mlp(x, p, cfg, RT)
        return jnp.mean(out ** 2) + 0.01 * aux
    val, grads = jax.value_and_grad(loss)(p, x)
    assert np.isfinite(float(val))
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.isfinite(g).all()), path
    # router receives gradient (learnable routing)
    assert float(jnp.abs(grads["router"]).sum()) > 0


def test_shared_expert_added():
    cfg = get_reduced("llama4-scout-17b-a16e")
    p = moe.init_moe(jax.random.PRNGKey(4), cfg, jnp.float32)
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 64, cfg.d_model),
                          jnp.float32)
    out, _ = moe.moe_mlp(x, p, cfg, RT)
    # zeroing the shared expert changes the output
    p2 = dict(p)
    p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    out2, _ = moe.moe_mlp(x, p2, cfg, RT)
    assert float(jnp.abs(out - out2).max()) > 1e-4
