"""Execution-policy layer + matmul backend registry tests.

Backend agreement (ref / jnp / pallas-interpret) for bf16, FP8, and
2:4-packed inputs, policy resolution against OccupancyAdvisor thresholds,
policy parsing, and the block-shape autotune cache.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import concurrency as cc
from repro.core import execution as ex
from repro.core import sparsity as sp
from repro.kernels import registry

BACKENDS = ("ref", "jnp", "pallas")


def _operands(m=64, k=128, n=256, dtype=jnp.bfloat16):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    return x.astype(dtype), w.astype(dtype)


# ---------------------------------------------------------------------------
# Registry basics
# ---------------------------------------------------------------------------

def test_registry_lists_all_backends():
    names = registry.available_backends()
    for want in ("ref", "jnp", "pallas", "pallas_sparse24"):
        assert want in names


def test_unknown_backend_raises_with_available_list():
    with pytest.raises(KeyError, match="pallas"):
        registry.get_backend("rocblas")


# ---------------------------------------------------------------------------
# Backend agreement: bf16 dense, FP8, 2:4-packed
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_dense_bf16_matches_f32_oracle(backend):
    x, w = _operands()
    out = ex.matmul(x, w, ex.ExecutionPolicy(backend=backend),
                    out_dtype=jnp.float32)
    want = x.astype(jnp.float32) @ w.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("backend", BACKENDS)
def test_fp8_backends_agree(backend):
    x, w = _operands()
    base = ex.matmul(x, w, ex.ExecutionPolicy(precision="fp8", backend="ref"),
                     out_dtype=jnp.float32)
    out = ex.matmul(x, w, ex.ExecutionPolicy(precision="fp8",
                                             backend=backend),
                    out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=2e-2, atol=2e-2)
    # and within quantization error of the exact product
    ref = x.astype(jnp.float32) @ w.astype(jnp.float32)
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel < 0.08, rel


@pytest.mark.parametrize("backend", BACKENDS)
def test_sparse24_backends_agree(backend):
    x, w = _operands()
    packed = ex.pack_weight(w)
    base = ex.matmul(x, packed, ex.ExecutionPolicy(backend="ref"),
                     out_dtype=jnp.float32)
    out = ex.matmul(x, packed, ex.ExecutionPolicy(backend=backend),
                    out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               rtol=2e-2, atol=2e-2)
    # the packed product equals the dense product of the pruned weight
    w24 = sp.prune_24(w)
    want = x.astype(jnp.float32) @ w24.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_pallas_untileable_shapes_fall_back():
    # M=30 cannot tile to an 8-multiple block: the pallas backend must
    # fall back to the jnp path and still be correct.
    x, w = _operands(m=30, k=56, n=24, dtype=jnp.float32)
    out = ex.matmul(x, w, ex.ExecutionPolicy(backend="pallas"),
                    out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)


def test_pallas_sparse24_dense_entry_prunes():
    x, w = _operands()
    out = ex.matmul(x, w, ex.ExecutionPolicy(backend="pallas_sparse24"),
                    out_dtype=jnp.float32)
    w24 = sp.prune_24(w)
    want = x.astype(jnp.float32) @ w24.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_leading_batch_dims_preserved():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 64), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 32), jnp.bfloat16)
    for backend in BACKENDS:
        out = ex.matmul(x, w, ex.ExecutionPolicy(backend=backend))
        assert out.shape == (2, 16, 32), backend


# ---------------------------------------------------------------------------
# resolve_policy ↔ OccupancyAdvisor thresholds
# ---------------------------------------------------------------------------

def test_resolve_demotes_fp8_below_occupancy_threshold():
    # 1 MXU tile of output: far below the FP8 occupancy threshold — the
    # advisor's §9.2 rule demotes to bf16.
    pol = ex.resolve_policy(128, 512, 128, precision="fp8")
    assert pol.precision == "bf16"
    assert any("occupancy" in r or "HBM" in r for r in pol.rationale)


def test_resolve_keeps_fp8_when_grid_fills():
    # 16×16 MXU tiles = 256 = advisor cores: fill 1.0 — fp8 retained
    # (with a batch-up suggestion, not a demotion).
    pol = ex.resolve_policy(2048, 4096, 2048, precision="fp8")
    assert pol.precision == "fp8"


def test_resolve_disables_sparsity_for_isolated_compute_bound():
    pol = ex.resolve_policy(8192, 4096, 8192, precision="fp8", tenants=1)
    assert pol.sparsity == "dense"
    assert any("break-even" in r for r in pol.rationale)


def test_resolve_enables_sparsity_for_multi_tenant():
    pol = ex.resolve_policy(8192, 4096, 8192, precision="fp8", tenants=4)
    assert pol.sparsity == "sparse24"


def test_resolve_caps_streams_for_latency_sensitive():
    lat = ex.resolve_policy(512, 512, 512, latency_sensitive=True,
                            streams=16)
    thr = ex.resolve_policy(512, 512, 512, latency_sensitive=False,
                            streams=16)
    assert lat.streams <= 4 < thr.streams <= 8


def test_resolve_respects_custom_advisor_threshold():
    # an advisor with tiny core count sees every workload as saturating:
    # fp8 must never be demoted
    adv = cc.OccupancyAdvisor(n_cores=1)
    pol = ex.resolve_policy(128, 512, 128, precision="fp8", advisor=adv)
    assert pol.precision == "fp8"


def test_advisor_derives_core_count(monkeypatch):
    """n_cores is detected, not hard-coded: REPRO_N_CORES wins, and a
    CPU-only container falls back to the TPU-class table value (256)."""
    monkeypatch.delenv("REPRO_N_CORES", raising=False)
    assert cc.OccupancyAdvisor().n_cores == cc.DEFAULT_N_CORES == 256
    monkeypatch.setenv("REPRO_N_CORES", "32")
    adv = cc.OccupancyAdvisor()
    assert adv.n_cores == 32
    # same GEMM, smaller machine: 64 tiles now saturate -> fp8 retained
    # where the 256-core default advisor would demote it
    pol = ex.resolve_policy(1024, 512, 1024, precision="fp8", advisor=adv)
    assert pol.precision == "fp8"
    monkeypatch.delenv("REPRO_N_CORES")
    demoted = ex.resolve_policy(1024, 512, 1024, precision="fp8")
    assert demoted.precision == "bf16"


def test_advisor_calibrated_thresholds_override_constants():
    adv = cc.OccupancyAdvisor(n_cores=256, fp8_fill_target=4.0,
                              demote_below_fill=4.0, calibrated=True)
    # fill 2.0: fine for the constant advisor, demoted by the measured one
    pol = ex.resolve_policy(2048, 512, 4096, precision="fp8", advisor=adv)
    assert pol.precision == "bf16"
    assert any("measured" in r for r in pol.rationale)


def test_resolve_picks_table3_seeded_blocks():
    pol = ex.resolve_policy(2048, 4096, 2048, precision="fp8")
    assert (pol.block_m, pol.block_n, pol.block_k) == \
        ex.BlockShapeCache.TABLE3_PREFERRED["fp8"]


# ---------------------------------------------------------------------------
# Policy plumbing
# ---------------------------------------------------------------------------

def test_parse_policy_roundtrip_and_errors():
    pol = ex.parse_policy("fp8:sparse24:pallas:streams=4:256x256x128")
    assert pol.spec() == "fp8:sparse24:pallas"
    assert pol.streams == 4
    assert (pol.block_m, pol.block_n, pol.block_k) == (256, 256, 128)
    assert ex.parse_policy(pol.spec()).spec() == pol.spec()
    with pytest.raises(ValueError, match="unrecognized"):
        ex.parse_policy("int4")


def test_policy_validates_fields():
    with pytest.raises(ValueError):
        ex.ExecutionPolicy(precision="int8")
    with pytest.raises(ValueError):
        ex.ExecutionPolicy(sparsity="blocksparse")


def test_policy_from_precedence():
    from repro.configs import PAPER_TRANSFORMER
    from repro.models.layers import RuntimeCfg

    cfg = PAPER_TRANSFORMER                       # precision="fp8"
    rt = RuntimeCfg()
    derived = ex.policy_from(cfg, rt)
    assert derived.precision == "fp8" and derived.backend == "jnp"

    rt_pallas = dataclasses.replace(rt, use_pallas=True)
    assert ex.policy_from(cfg, rt_pallas).backend == "pallas"

    explicit = ex.ExecutionPolicy(precision="bf16", backend="ref")
    rt_pol = dataclasses.replace(rt, policy=explicit)
    assert ex.policy_from(cfg, rt_pol) is explicit

    ex.set_default_backend("pallas")
    try:
        assert ex.policy_from(cfg, rt).backend == "pallas"
    finally:
        ex.set_default_backend("jnp")


def test_apply_policy_folds_into_cfg_and_rt():
    from repro.configs import PAPER_TRANSFORMER
    from repro.models.layers import RuntimeCfg

    pol = ex.ExecutionPolicy(precision="bf16", sparsity="sparse24",
                             backend="pallas_sparse24")
    cfg, rt = ex.apply_policy(PAPER_TRANSFORMER, RuntimeCfg(), pol)
    assert cfg.precision == "bf16" and cfg.sparsity_24
    assert rt.policy is pol
    # use_pallas (the flash-attention gate) must NOT be flipped by the
    # matmul policy — the flash kernel is forward-only
    assert not rt.use_pallas


def test_dense_routes_through_policy():
    """models.layers.dense honors rt.policy over cfg switches."""
    from repro.configs import PAPER_TRANSFORMER
    from repro.models.layers import RuntimeCfg, dense

    x, w = _operands(m=32, k=64, n=64)
    rt = RuntimeCfg(policy=ex.ExecutionPolicy(precision="bf16",
                                              backend="ref"))
    out = dense(x, w, PAPER_TRANSFORMER, rt)      # cfg says fp8; policy wins
    want = x.astype(jnp.float32) @ w.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), rtol=1e-2, atol=1e-1)


def test_pack_model_params_serving_path():
    """Pre-packed params: eligible projections become PackedWeight, the
    protected leaves stay dense, and the packed model still decodes."""
    import dataclasses as dc
    from repro.configs import PAPER_TRANSFORMER
    from repro.models import decode_step, init_cache, init_params
    from repro.models.layers import RuntimeCfg

    cfg = dc.replace(PAPER_TRANSFORMER, num_layers=2, d_model=64, d_ff=128,
                     num_heads=2, num_kv_heads=2, head_dim=32,
                     vocab_size=256, precision="bf16")
    params = init_params(jax.random.PRNGKey(0), cfg)
    packed = ex.pack_model_params(params)

    w_q = packed["layers"]["b0"]["attn"]["w_q"]
    assert isinstance(w_q, ex.PackedWeight)
    assert w_q.values.shape[-2] * 2 == cfg.d_model        # stacked (L, K/2, N)
    assert not isinstance(packed["embed"], ex.PackedWeight)
    assert not isinstance(packed["head"], ex.PackedWeight)

    rt = RuntimeCfg(ssm_chunk=16)
    caches = init_cache(cfg, 2, 16)
    toks = jnp.zeros((2, 1), jnp.int32)
    logits, _ = decode_step(packed, toks, caches, 0, cfg, rt)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


# ---------------------------------------------------------------------------
# Block-shape autotune cache
# ---------------------------------------------------------------------------

def test_block_cache_seeded_from_table3():
    cache = ex.BlockShapeCache()
    assert len(cache) > 0
    assert cache.lookup(256, 256, 256, jnp.bfloat16) == (256, 256, 256)
    # fp8 prefers the deepest K block the problem allows
    assert cache.lookup(256, 256, 256, jnp.float8_e4m3fn) == (256, 256, 256)
    assert cache.lookup(1024, 4096, 1024, jnp.float8_e4m3fn) == (256, 256, 512)


def test_block_cache_record_keeps_best():
    cache = ex.BlockShapeCache(seed=False)
    cache.record(512, 512, 512, jnp.bfloat16, (128, 128, 128), 2.0)
    cache.record(512, 512, 512, jnp.bfloat16, (256, 256, 128), 1.0)
    cache.record(512, 512, 512, jnp.bfloat16, (64, 64, 64), 3.0)
    assert cache.lookup(512, 512, 512, jnp.bfloat16) == (256, 256, 128)


def test_seed_cache_from_latency_records():
    from repro.core.characterization import Record
    cache = ex.BlockShapeCache(seed=False)
    recs = [Record("latency/fp8/128x128x256", 3.0, {}),
            Record("occupancy/fp8/tiles=4", 1.0, {})]     # ignored
    assert ex.seed_cache_from_records(recs, cache) == 1
    assert cache.lookup(128, 256, 128, jnp.float8_e4m3fn) == (128, 128, 256)


# ---------------------------------------------------------------------------
# Delayed-scaling FP8 training path through the registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_fp8_matmul_backend_thread(backend):
    from repro.core import fp8 as f8
    x, w = _operands(m=32, k=64, n=64, dtype=jnp.float32)
    out = f8.fp8_matmul(x, w, jnp.float32(1.0), jnp.float32(1.0),
                        f8.E4M3, f8.E5M2, backend)
    ref = f8.fp8_matmul(x, w, jnp.float32(1.0), jnp.float32(1.0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_fp8_matmul_grad_dtype_matches_bf16_params():
    """Regression: dw must come back in the weight's dtype (bf16), not f32."""
    from repro.core import fp8 as f8
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 32), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 8), jnp.bfloat16)

    def loss(x, w):
        out = f8.fp8_matmul(x, w, jnp.float32(1.0), jnp.float32(1.0))
        return jnp.sum(out.astype(jnp.float32) ** 2)

    dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert dx.dtype == jnp.bfloat16
    assert dw.dtype == jnp.bfloat16
