"""Mamba2 / RWKV6 chunked implementations vs. naive per-token recurrences.

The chunked algorithms (quadratic-within-chunk + state across chunks) must
match a direct step-by-step evaluation of the same recurrence — this pins
the mathematics, independent of the surrounding block plumbing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import mamba2 as m2
from repro.models import rwkv6 as rk
from repro.models.layers import RuntimeCfg


# ---------------------------------------------------------------------------
# Mamba2 SSD chunk math vs. naive recurrence
# ---------------------------------------------------------------------------

def naive_ssd(xh, dt, dA, B, C, h0):
    """Token-by-token: h = exp(dA_t) h + dt_t x_t ⊗ B_t;  y = C_t·h."""
    b, S, nh, hp = xh.shape
    N = B.shape[-1]
    h = np.asarray(h0, np.float64).copy()
    ys = np.zeros((b, S, nh, hp))
    xh, dt, dA, B, C = (np.asarray(t, np.float64) for t in (xh, dt, dA, B, C))
    for t in range(S):
        h = h * np.exp(dA[:, t])[..., None, None] \
            + np.einsum("bh,bhp,bn->bhpn", dt[:, t], xh[:, t], B[:, t])
        ys[:, t] = np.einsum("bhpn,bn->bhp", h, C[:, t])
    return ys, h


@pytest.mark.parametrize("chunks", [1, 4])
def test_ssd_chunk_matches_naive(chunks):
    b, S, nh, hp, N = 2, 32, 3, 4, 5
    Lc = S // chunks
    keys = jax.random.split(jax.random.PRNGKey(0), 6)
    xh = jax.random.normal(keys[0], (b, S, nh, hp))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, S, nh)))
    dA = -jax.nn.softplus(jax.random.normal(keys[2], (b, S, nh)))  # < 0
    B = jax.random.normal(keys[3], (b, S, N))
    C = jax.random.normal(keys[4], (b, S, N))
    h = jnp.zeros((b, nh, hp, N))

    ys = []
    for i in range(chunks):
        sl = slice(i * Lc, (i + 1) * Lc)
        yi, h = m2._ssd_chunk(xh[:, sl], dt[:, sl],
                              jnp.cumsum(dA[:, sl], axis=1),
                              B[:, sl], C[:, sl], h)
        ys.append(yi)
    y = jnp.concatenate(ys, axis=1)

    y_ref, h_ref = naive_ssd(xh, dt, dA, B, C, jnp.zeros((b, nh, hp, N)))
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-4)


def test_mamba2_block_static_vs_scan():
    cfg = get_reduced("zamba2-1.2b")
    p = m2.init_mamba2(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model),
                          jnp.float32)
    rt_s = RuntimeCfg(ssm_chunk=16, static_loops=True, act_dtype=jnp.float32)
    rt_d = RuntimeCfg(ssm_chunk=16, static_loops=False, act_dtype=jnp.float32)
    a = m2.mamba2_block(x, p, cfg, rt_s)
    b = m2.mamba2_block(x, p, cfg, rt_d)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


def test_mamba2_decode_matches_prefill_state():
    """Decoding token-by-token reproduces the chunked prefill states/output."""
    cfg = get_reduced("zamba2-1.2b")
    p = m2.init_mamba2(jax.random.PRNGKey(3), cfg, jnp.float32)
    S = 32
    x = jax.random.normal(jax.random.PRNGKey(4), (1, S, cfg.d_model),
                          jnp.float32) * 0.3
    rt = RuntimeCfg(ssm_chunk=8, act_dtype=jnp.float32)
    out_full, (h_full, conv_full) = m2.mamba2_block_with_state(x, p, cfg, rt)

    state = m2.init_mamba2_state(1, cfg)
    outs = []
    for t in range(S):
        o, state = m2.mamba2_decode(x[:, t:t + 1], p, cfg, state, rt)
        outs.append(o)
    out_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(out_full),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(state[0]), np.asarray(h_full),
                               rtol=5e-3, atol=5e-3)


# ---------------------------------------------------------------------------
# RWKV6 wkv chunk math vs. naive recurrence
# ---------------------------------------------------------------------------

def naive_wkv(r, k, v, w, u, S0):
    """y_t = r_t (S + u ⊙ kᵀv);  S = diag(w_t) S + kᵀ_t v_t."""
    b, T, nh, hd = r.shape
    S = np.asarray(S0, np.float64).copy()
    ys = np.zeros((b, T, nh, hd))
    r, k, v, w = (np.asarray(t, np.float64) for t in (r, k, v, w))
    u = np.asarray(u, np.float64)
    for t in range(T):
        kv = np.einsum("bhi,bhj->bhij", k[:, t], v[:, t])
        ys[:, t] = np.einsum("bhi,bhij->bhj", r[:, t],
                             S + u[None, :, :, None] * kv)
        S = S * w[:, t][..., None] + kv
    return ys, S


@pytest.mark.parametrize("chunks", [1, 4])
def test_wkv_chunk_matches_naive(chunks):
    b, T, nh, hd = 2, 32, 2, 4
    Lc = T // chunks
    keys = jax.random.split(jax.random.PRNGKey(5), 5)
    r = jax.random.normal(keys[0], (b, T, nh, hd))
    k = jax.random.normal(keys[1], (b, T, nh, hd))
    v = jax.random.normal(keys[2], (b, T, nh, hd))
    w = jax.nn.sigmoid(jax.random.normal(keys[3], (b, T, nh, hd))) * 0.98 + 0.01
    u = jax.random.normal(keys[4], (nh, hd))
    S = jnp.zeros((b, nh, hd, hd))

    ys = []
    for i in range(chunks):
        sl = slice(i * Lc, (i + 1) * Lc)
        yi, S = rk._wkv_chunk(r[:, sl], k[:, sl], v[:, sl], w[:, sl], u, S)
        ys.append(yi)
    y = jnp.concatenate(ys, axis=1)

    y_ref, S_ref = naive_wkv(r, k, v, w, u, jnp.zeros((b, nh, hd, hd)))
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=1e-4, atol=1e-4)


def test_wkv_strong_decay_no_overflow():
    """Pairwise-decay formulation stays finite where the factorized form
    would overflow f32 (exp(+cum) with cum ~ -300)."""
    b, T, nh, hd = 1, 64, 1, 4
    r = jnp.ones((b, T, nh, hd)) * 0.1
    k = jnp.ones((b, T, nh, hd)) * 0.1
    v = jnp.ones((b, T, nh, hd))
    w = jnp.full((b, T, nh, hd), 0.005)     # log w ≈ -5.3; cum ≈ -340
    u = jnp.zeros((nh, hd))
    y, S = rk._wkv_chunk(r, k, v, w, u, jnp.zeros((b, nh, hd, hd)))
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(S).all())


def test_rwkv6_decode_matches_block():
    cfg = get_reduced("rwkv6-3b")
    p = rk.init_rwkv6(jax.random.PRNGKey(6), cfg, jnp.float32)
    S = 16
    x = jax.random.normal(jax.random.PRNGKey(7), (1, S, cfg.d_model),
                          jnp.float32) * 0.3
    rt = RuntimeCfg(ssm_chunk=8, act_dtype=jnp.float32)
    out_full, (S_full, _) = rk.rwkv6_block_with_state(x, p, cfg, rt)

    d = cfg.d_model
    nh = d // cfg.ssm_head_dim
    state = (jnp.zeros((1, nh, cfg.ssm_head_dim, cfg.ssm_head_dim)),
             jnp.zeros((1, 1, d), jnp.float32))
    outs = []
    for t in range(S):
        o, state = rk.rwkv6_decode(x[:, t:t + 1], p, cfg, state, rt)
        outs.append(o)
    out_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(out_full),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(state[0]), np.asarray(S_full),
                               rtol=5e-3, atol=5e-3)
