"""The workload plane: seed-deterministic generation, JSON trace
round-trip, and bit-for-bit replay through the serving runtime.

The contracts under test (ISSUE 10 satellites):

* same (spec, seed) ⇒ identical ``WorkloadTrace`` — the generator has
  ONE documented sampling order and no hidden global state;
* a trace survives JSON save/load exactly (events carry their prompts
  inline, so replay is generator-independent);
* replaying a saved trace through a fresh ``ServingRuntime`` reproduces
  the generating run's committed tokens token-for-token;
* the Zipf popularity law actually skews arrivals toward rank 0, and
  the arrival modulations (bursty/diurnal) actually modulate.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime import workload as wl
from repro.runtime.scheduler import StreamScheduler
from repro.runtime.server import PartitionSpec, ServingRuntime, ServingSpec

RT = RuntimeCfg(ssm_chunk=16)


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _runtime(model, **kw):
    cfg, params = model
    spec = ServingSpec(partitions=(PartitionSpec(admission="fifo"),),
                       batch_slots=2, max_len=64, **kw)
    return ServingRuntime(params, cfg, spec, rt=RT)


# ---------------------------------------------------------------------------
# LengthDist / WorkloadSpec
# ---------------------------------------------------------------------------

def test_length_dist_forms_and_bounds():
    assert wl.LengthDist.from_any(5) == wl.LengthDist(5, 5)
    assert wl.LengthDist.from_any((3, 9)) == wl.LengthDist(3, 9)
    assert wl.LengthDist.from_any({"lo": 2, "hi": 4}) == wl.LengthDist(2, 4)
    d = wl.LengthDist(2, 4, long_lo=10, long_hi=12, long_frac=0.5)
    rng = np.random.default_rng(0)
    draws = {d.sample(rng) for _ in range(200)}
    assert draws <= set(range(2, 5)) | set(range(10, 13))
    assert draws & set(range(2, 5)) and draws & set(range(10, 13))
    with pytest.raises(ValueError):
        wl.LengthDist(0, 4)
    with pytest.raises(ValueError):
        wl.LengthDist(4, 2)
    with pytest.raises(ValueError):
        wl.LengthDist(2, 4, long_frac=0.5)      # missing long range


def test_workload_spec_validation():
    with pytest.raises(ValueError):
        wl.WorkloadSpec(arrival="fractal")
    with pytest.raises(ValueError):
        wl.WorkloadSpec(tenants=0)
    with pytest.raises(ValueError):
        wl.WorkloadSpec(rate=0.0)
    with pytest.raises(ValueError):
        wl.WorkloadSpec(tenants=3, slos=("batch",))     # length mismatch
    with pytest.raises(ValueError):
        wl.WorkloadSpec(tenants=2, max_new_overrides=((3, 5),))
    with pytest.raises(ValueError):
        wl.WorkloadSpec.from_dict({"tenants": 2, "n_users": 1e6})


def test_workload_spec_dict_round_trip():
    spec = wl.WorkloadSpec(
        tenants=3, zipf_s=1.3, arrival="diurnal", rate=2.0, period=32,
        amplitude=0.5, steps=16, prompt_len=(2, 6),
        max_new={"lo": 3, "hi": 5, "long_lo": 9, "long_hi": 12,
                 "long_frac": 0.25},
        max_new_overrides=(None, (2, 3), None),
        slos=("batch", None, "latency:10"), weights=(1.0, 2.0, 1.0),
        seed=42)
    again = wl.WorkloadSpec.from_dict(spec.to_dict())
    assert again == spec
    assert again.to_dict() == spec.to_dict()


def test_zipf_weights_skew():
    w = wl.zipf_weights(8, 1.2)
    assert w.shape == (8,)
    assert abs(w.sum() - 1.0) < 1e-12
    assert all(w[i] > w[i + 1] for i in range(7))
    flat = wl.zipf_weights(8, 0.0)
    assert np.allclose(flat, 1 / 8)


# ---------------------------------------------------------------------------
# generation determinism + distribution shape
# ---------------------------------------------------------------------------

def test_generate_deterministic():
    spec = wl.WorkloadSpec(tenants=4, arrival="bursty", rate=1.5,
                           steps=32, seed=9)
    a, b = wl.generate(spec), wl.generate(spec)
    assert a == b
    assert a.events and a.events == b.events
    # a different seed moves the trace
    c = wl.generate(wl.WorkloadSpec(tenants=4, arrival="bursty",
                                    rate=1.5, steps=32, seed=10))
    assert c != a


def test_generate_zipf_concentrates_head():
    spec = wl.WorkloadSpec(tenants=4, zipf_s=1.5, rate=4.0, steps=64,
                           seed=1)
    per = wl.generate(spec).arrivals_per_tenant()
    assert per["tenant0"] > per["tenant3"] * 2


def test_generate_uids_sequential_and_steps_bounded():
    spec = wl.WorkloadSpec(tenants=2, rate=2.0, steps=16, seed=3)
    tr = wl.generate(spec)
    assert [e.uid for e in tr.events] == list(range(len(tr.events)))
    assert all(0 <= e.step < spec.steps for e in tr.events)
    assert all(len(e.prompt) >= 1 for e in tr.events)
    assert all(max(e.prompt) < spec.vocab for e in tr.events)


def test_diurnal_modulates_arrivals():
    spec = wl.WorkloadSpec(tenants=1, arrival="diurnal", rate=4.0,
                           period=32, amplitude=0.9, steps=64, seed=5)
    tr = wl.generate(spec)
    # fold arrivals by phase: the peak half-cycle must out-arrive the
    # trough half-cycle
    peak = sum(1 for e in tr.events if (e.step % 32) < 16)
    trough = len(tr.events) - peak
    assert peak > trough


def test_bursty_has_on_and_off_phases():
    spec = wl.WorkloadSpec(tenants=1, arrival="bursty", rate=2.0,
                           burst_factor=6.0, burst_len=8, steps=64,
                           seed=2)
    per_step = [0] * spec.steps
    for e in wl.generate(spec).events:
        per_step[e.step] += 1
    # ON phases push well past the mean; OFF phases go quiet
    assert max(per_step) >= 6
    assert min(per_step) == 0


# ---------------------------------------------------------------------------
# trace JSON round-trip
# ---------------------------------------------------------------------------

def test_trace_json_round_trip(tmp_path):
    spec = wl.WorkloadSpec(tenants=3, arrival="bursty", rate=1.0,
                           steps=24, slos=("batch", "batch", "latency:9"),
                           seed=11)
    tr = wl.generate(spec)
    path = tmp_path / "trace.json"
    tr.save(path)
    again = wl.WorkloadTrace.load(path)
    assert again == tr
    assert again.spec == spec
    assert again.to_dict() == tr.to_dict()


def test_trace_schema_guard():
    with pytest.raises(ValueError):
        wl.WorkloadTrace.from_dict({"schema": 99, "events": []})


def test_specless_trace_steps_and_tenants():
    ev = [wl.WorkloadEvent(step=4, tenant="b", uid=0, prompt=(1, 2),
                           max_new=3),
          wl.WorkloadEvent(step=7, tenant="a", uid=1, prompt=(3,),
                           max_new=2)]
    tr = wl.WorkloadTrace(events=ev)
    assert tr.steps == 8
    assert tr.tenant_ids() == ["b", "a"]          # discovery order
    again = wl.WorkloadTrace.from_json(tr.to_json())
    assert again == tr


def test_event_requests_are_fresh():
    ev = wl.WorkloadEvent(step=0, tenant="t", uid=7, prompt=(1, 2, 3),
                          max_new=4)
    r1, r2 = ev.to_request(), ev.to_request()
    assert r1 is not r2
    r1.out.append(99)
    assert r2.out == []
    assert r1.prompt.dtype == np.int32


# ---------------------------------------------------------------------------
# replay exactness through the runtime
# ---------------------------------------------------------------------------

def test_replay_reproduces_tokens(model, tmp_path):
    """The tentpole exactness contract: generate → run → save; load →
    fresh runtime → run; committed tokens match token-for-token."""
    spec = wl.WorkloadSpec(tenants=3, zipf_s=1.2, arrival="bursty",
                           rate=0.8, burst_len=4, steps=16,
                           prompt_len=(3, 6), max_new=(3, 5),
                           slos=("batch", "batch", "latency:12"), seed=21)
    trace = wl.generate(spec)
    done = wl.run_trace(_runtime(model), trace)
    assert len(done) == len(trace.events)
    tokens = wl.tokens_by_uid(done)

    path = tmp_path / "w.json"
    trace.save(path)
    replayed = wl.WorkloadTrace.load(path)
    done2 = wl.run_trace(_runtime(model), replayed)
    assert wl.tokens_by_uid(done2) == tokens
    assert wl.token_checksum(done2) == wl.token_checksum(done)
    # submit steps follow the trace exactly
    subs = {r.uid: r.submit_step for r in done2}
    assert subs == {e.uid: e.step for e in trace.events}


def test_run_trace_registers_slos_and_weights(model):
    spec = wl.WorkloadSpec(tenants=2, rate=1.0, steps=8,
                           slos=(None, "latency:6"), weights=(2.0, 1.0),
                           seed=4)
    runtime = _runtime(model)
    wl.run_trace(runtime, wl.generate(spec), drain=True)
    sched = runtime.schedulers[0]
    assert sched.tenants["tenant0"].slo is None
    assert sched.tenants["tenant1"].slo.kind == "latency"
    assert sched.tenants["tenant0"].weight == 2.0


def test_run_trace_drives_stream_scheduler(model):
    """run_trace is facade-duck-typed: a bare StreamScheduler (no
    runtime) accepts the same trace."""
    cfg, params = model
    from repro.runtime.serve_loop import ServeSession
    sess = ServeSession(params, cfg, batch_slots=2, max_len=64, rt=RT)
    sched = StreamScheduler(sess, admission="fifo")
    spec = wl.WorkloadSpec(tenants=2, rate=0.8, steps=8, seed=6,
                           prompt_len=(3, 5), max_new=(3, 4))
    trace = wl.generate(spec)
    done = wl.run_trace(sched, trace)
    assert len(done) == len(trace.events)
