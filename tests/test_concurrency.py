"""Concurrency layer: metric properties + stream characterization runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import concurrency as cc


def test_fairness_bounds():
    assert cc.fairness([1.0, 1.0, 1.0]) == 1.0
    assert cc.fairness([1.0, 2.0]) == pytest.approx(1 - 1 / 1.5)
    assert cc.fairness([]) == 1.0
    # paper convention: fairness is reported in [0, 1] — severe imbalance
    # clamps to 0.0 (full collapse); the unbounded diagnostic is
    # fairness_raw (paper reports 0.016 at 8 streams, still in range)
    assert cc.fairness([0.1, 10.0]) == 0.0
    assert cc.fairness_raw([0.1, 10.0]) < 0.0
    assert 0.0 <= cc.fairness([0.1, 10.0, 0.5]) <= 1.0


def test_latency_percentiles():
    p = cc.latency_percentiles([1.0, 2.0, 3.0, 4.0])
    assert p["p50"] == pytest.approx(2.5)
    assert p["p99"] <= 4.0
    assert cc.latency_percentiles([]) == {"p50": 0.0, "p99": 0.0}


def test_characterize_streams_warms_every_thunk():
    calls = []

    def mk(i):
        def thunk():
            calls.append(i)
            return jnp.zeros(())
        return thunk

    cc.characterize_streams(mk, 3, warmup=1, mode="async")
    # warmup (one pass over ALL streams) + serial pass + async pass
    assert calls[:3] == [0, 1, 2]
    assert len(calls) == 9


def test_fairness_min_max():
    assert cc.fairness_min_max([2.0, 2.0]) == 1.0
    assert cc.fairness_min_max([1.0, 4.0]) == 0.25


def test_cv():
    assert cc.cv([1.0, 1.0]) == 0.0
    assert cc.cv([1.0, 3.0]) == pytest.approx(0.5)


def test_overlap_efficiency():
    # perfect overlap: 4 streams of 1s each complete in 1s total
    assert cc.overlap_efficiency(4.0, 1.0, 4) == 1.0
    # no overlap: concurrent == serial
    assert cc.overlap_efficiency(4.0, 4.0, 4) == 0.0
    # halfway
    assert cc.overlap_efficiency(4.0, 2.5, 4) == pytest.approx(0.5)


def test_characterize_streams_runs():
    def mk(i):
        x = jax.random.normal(jax.random.PRNGKey(i), (128, 128))
        f = jax.jit(lambda a: (a @ a).sum())
        return lambda: f(x)
    rep = cc.characterize_streams(mk, 2, mode="async")
    assert rep.n_streams == 2
    assert len(rep.per_stream_s) == 2
    assert rep.wall_s > 0 and rep.serial_wall_s > 0
    assert -5.0 <= rep.fairness <= 1.0
    d = rep.to_dict()
    assert set(d) >= {"speedup", "overlap_efficiency", "fairness", "cv"}


def test_run_serial_returns_per_stream():
    f = jax.jit(lambda a: a * 2)
    x = jnp.ones((8, 8))
    times = cc.run_serial([lambda: f(x)] * 3)
    assert len(times) == 3 and all(t > 0 for t in times)


# ---------------------------------------------------------------------------
# Occupancy advisor (paper §9.2 rules)
# ---------------------------------------------------------------------------

def test_advisor_fp8_low_occupancy_prefers_bf16():
    adv = cc.OccupancyAdvisor(n_cores=256)
    a = adv.advise(cc.WorkloadProfile(precision="fp8", grid_tiles=128,
                                      latency_sensitive=True))
    assert a.suggested_precision == "bf16"
    assert any("HBM latency" in r for r in a.rationale)


def test_advisor_fp8_mid_occupancy_batches_up():
    adv = cc.OccupancyAdvisor(n_cores=256)
    a = adv.advise(cc.WorkloadProfile(precision="fp8", grid_tiles=300))
    assert a.suggested_precision == "fp8"
    assert a.batch_multiplier >= 2


def test_advisor_sparsity_context_dependent():
    adv = cc.OccupancyAdvisor(n_cores=256)
    # isolated compute-bound: break-even -> off (paper §7.1)
    iso = adv.advise(cc.WorkloadProfile(precision="bf16", grid_tiles=1024,
                                        latency_sensitive=True,
                                        concurrent_tenants=1))
    assert not iso.use_sparsity
    # multi-tenant: on (paper §7.2)
    multi = adv.advise(cc.WorkloadProfile(precision="bf16", grid_tiles=1024,
                                          latency_sensitive=True,
                                          concurrent_tenants=4))
    assert multi.use_sparsity


def test_advisor_stream_limits():
    adv = cc.OccupancyAdvisor()
    lat = adv.advise(cc.WorkloadProfile("bf16", 512, latency_sensitive=True))
    thr = adv.advise(cc.WorkloadProfile("bf16", 512, latency_sensitive=False))
    assert lat.max_streams == 4 and thr.max_streams == 8
