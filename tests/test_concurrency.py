"""Concurrency layer: metric properties + stream characterization runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import concurrency as cc


def test_fairness_bounds():
    assert cc.fairness([1.0, 1.0, 1.0]) == 1.0
    assert cc.fairness([1.0, 2.0]) == pytest.approx(1 - 1 / 1.5)
    assert cc.fairness([]) == 1.0
    # paper convention: fairness is reported in [0, 1] — severe imbalance
    # clamps to 0.0 (full collapse); the unbounded diagnostic is
    # fairness_raw (paper reports 0.016 at 8 streams, still in range)
    assert cc.fairness([0.1, 10.0]) == 0.0
    assert cc.fairness_raw([0.1, 10.0]) < 0.0
    assert 0.0 <= cc.fairness([0.1, 10.0, 0.5]) <= 1.0


def test_latency_percentiles():
    p = cc.latency_percentiles([1.0, 2.0, 3.0, 4.0])
    assert p["p50"] == pytest.approx(2.5)
    assert p["p99"] <= 4.0
    assert cc.latency_percentiles([]) == {"p50": 0.0, "p99": 0.0}


def test_characterize_streams_warms_every_thunk():
    calls = []

    def mk(i):
        def thunk():
            calls.append(i)
            return jnp.zeros(())
        return thunk

    cc.characterize_streams(mk, 3, warmup=1, mode="async")
    # warmup (one pass over ALL streams) + serial pass + async pass
    assert calls[:3] == [0, 1, 2]
    assert len(calls) == 9


def test_fairness_min_max():
    assert cc.fairness_min_max([2.0, 2.0]) == 1.0
    assert cc.fairness_min_max([1.0, 4.0]) == 0.25


def test_cv():
    assert cc.cv([1.0, 1.0]) == 0.0
    assert cc.cv([1.0, 3.0]) == pytest.approx(0.5)


def test_overlap_efficiency():
    # perfect overlap: 4 streams of 1s each complete in 1s total
    assert cc.overlap_efficiency(4.0, 1.0, 4) == 1.0
    # no overlap: concurrent == serial
    assert cc.overlap_efficiency(4.0, 4.0, 4) == 0.0
    # halfway
    assert cc.overlap_efficiency(4.0, 2.5, 4) == pytest.approx(0.5)


def test_characterize_streams_runs():
    def mk(i):
        x = jax.random.normal(jax.random.PRNGKey(i), (128, 128))
        f = jax.jit(lambda a: (a @ a).sum())
        return lambda: f(x)
    rep = cc.characterize_streams(mk, 2, mode="async")
    assert rep.n_streams == 2
    assert len(rep.per_stream_s) == 2
    assert rep.wall_s > 0 and rep.serial_wall_s > 0
    assert -5.0 <= rep.fairness <= 1.0
    d = rep.to_dict()
    assert set(d) >= {"speedup", "overlap_efficiency", "fairness", "cv"}


def test_run_serial_returns_per_stream():
    f = jax.jit(lambda a: a * 2)
    x = jnp.ones((8, 8))
    times = cc.run_serial([lambda: f(x)] * 3)
    assert len(times) == 3 and all(t > 0 for t in times)


# ---------------------------------------------------------------------------
# Occupancy advisor (paper §9.2 rules)
# ---------------------------------------------------------------------------

def test_advisor_fp8_low_occupancy_prefers_bf16():
    adv = cc.OccupancyAdvisor(n_cores=256)
    a = adv.advise(cc.WorkloadProfile(precision="fp8", grid_tiles=128,
                                      latency_sensitive=True))
    assert a.suggested_precision == "bf16"
    assert any("HBM latency" in r for r in a.rationale)


def test_advisor_fp8_mid_occupancy_batches_up():
    adv = cc.OccupancyAdvisor(n_cores=256)
    a = adv.advise(cc.WorkloadProfile(precision="fp8", grid_tiles=300))
    assert a.suggested_precision == "fp8"
    assert a.batch_multiplier >= 2


def test_advisor_sparsity_context_dependent():
    adv = cc.OccupancyAdvisor(n_cores=256)
    # isolated compute-bound: break-even -> off (paper §7.1)
    iso = adv.advise(cc.WorkloadProfile(precision="bf16", grid_tiles=1024,
                                        latency_sensitive=True,
                                        concurrent_tenants=1))
    assert not iso.use_sparsity
    # multi-tenant: on (paper §7.2)
    multi = adv.advise(cc.WorkloadProfile(precision="bf16", grid_tiles=1024,
                                          latency_sensitive=True,
                                          concurrent_tenants=4))
    assert multi.use_sparsity


def test_advisor_stream_limits():
    adv = cc.OccupancyAdvisor()
    lat = adv.advise(cc.WorkloadProfile("bf16", 512, latency_sensitive=True))
    thr = adv.advise(cc.WorkloadProfile("bf16", 512, latency_sensitive=False))
    assert lat.max_streams == 4 and thr.max_streams == 8


# ---------------------------------------------------------------------------
# Execution lanes (dispatch-and-join seam)
# ---------------------------------------------------------------------------

def test_lane_handle_join_and_timing():
    lane = cc.ExecutionLane("l0")
    f = jax.jit(lambda a: (a @ a).sum())
    x = jnp.ones((64, 64))
    h = lane.dispatch(lambda: f(x), label="gemm", overlap_group=3)
    assert h.lane == "l0" and h.label == "gemm" and h.overlap_group == 3
    out = h.join()
    assert float(out) == pytest.approx(64.0 * 64 * 64)
    assert h.done and h.dispatch_to_ready_s > 0
    ready = h.ready_t
    assert h.join() is out             # idempotent: ready_t stamped once
    assert h.ready_t == ready
    assert lane.join_all() == [out]


def test_lane_dispatch_returns_before_join():
    """Dispatch enqueues; the handle is not ready until joined."""
    lane = cc.ExecutionLane("l0")
    h = lane.dispatch(lambda: jnp.zeros(()), label="z")
    assert not h.done and h.ready_t is None
    h.join()
    assert h.done


def test_run_async_dispatch_per_handle_timing():
    """Satellite regression: per-stream times are per-handle
    dispatch->ready, not offsets from one global t0 — so they no longer
    sum to more than the wall just because a stream joined late."""
    f = jax.jit(lambda a: (a @ a).sum())
    xs = [jax.random.normal(jax.random.PRNGKey(i), (128, 128))
          for i in range(3)]
    times = cc.run_async_dispatch([lambda x=x: f(x) for x in xs])
    assert len(times) == 3 and all(t > 0 for t in times)


def test_stream_report_legacy_timing_note():
    def mk(i):
        return lambda: jnp.zeros(())
    rep = cc.characterize_streams(mk, 2, mode="async")
    assert rep.timing == "dispatch_to_ready"
    d = rep.to_dict()
    assert "per_stream_s" in d and "legacy_timing" in d
    assert "global t0" in d["legacy_timing"]


def test_stream_report_to_record_round_trips():
    """fig4/fig5 share one Record schema with the autotune store."""
    from repro.core import autotune
    def mk(i):
        return lambda: jnp.zeros(())
    rep = cc.characterize_streams(mk, 2, mode="async")
    rec = rep.to_record("fig4/test/streams=2", streams=2)
    assert rec.us_per_call == pytest.approx(rep.wall_s * 1e6)
    assert rec.derived["streams"] == 2
    d = autotune.record_to_dict(rec)
    per_stream = d["derived"]["per_stream_s"]
    assert isinstance(per_stream, list) and len(per_stream) == 2
    store = autotune.AutotuneStore()
    store.add_records([rec])           # stream records ingest cleanly


# ---------------------------------------------------------------------------
# REPRO_N_CORES env validation
# ---------------------------------------------------------------------------

def test_detect_core_count_env_valid(monkeypatch):
    monkeypatch.setenv("REPRO_N_CORES", "37")
    assert cc.detect_core_count() == 37


@pytest.mark.parametrize("bad", ["notanum", "0", "-3", "1.5"])
def test_detect_core_count_env_invalid_warns_and_falls_back(
        monkeypatch, bad):
    monkeypatch.setenv("REPRO_N_CORES", bad)
    with pytest.warns(RuntimeWarning, match="REPRO_N_CORES"):
        assert cc.detect_core_count(default=99) == 99
