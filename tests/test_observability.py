"""Observability plane: metrics registry, Tracer→metrics sink,
Chrome-trace export, SLO attainment arithmetic, and the BENCH trajectory
gate.

The accounting contracts pinned here are the plane's whole value:
counter instruments agree *exactly* with the Tracer's monotonic per-kind
counts even past ring eviction, the exported trace renders planner-paired
work as genuinely overlapping slices on distinct lane tracks, and the
trajectory gate fails (non-zero) on an injected 20% tokens/step
regression while passing an unchanged run.
"""
import json

import pytest

from repro.runtime import telemetry, traceview
from repro.runtime.metrics import (
    Histogram, MetricsRegistry, MetricsSink, observe_runtime)
from repro.runtime.scheduler import SLO, attainment_from_tracer

from benchmarks import trajectory


# ---------------------------------------------------------------------------
# Metrics instruments
# ---------------------------------------------------------------------------

def test_counter_is_monotonic_and_labeled():
    r = MetricsRegistry()
    c = r.counter("repro_things_total", "things")
    c.inc(tenant="a")
    c.inc(2, tenant="b")
    assert c.value(tenant="a") == 1
    assert c.value(tenant="b") == 2
    assert c.value(tenant="missing") == 0
    with pytest.raises(ValueError):
        c.inc(-1, tenant="a")


def test_histogram_cumulative_bucket_math():
    h = Histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0, 100.0):
        h.observe(v)
    s = h.value()
    # cumulative Prometheus semantics: bucket i counts observations <= bound
    assert s["bucket_counts"] == [1, 2, 3]
    assert s["count"] == 4                       # +Inf bucket
    assert s["sum"] == 105.0
    snap = h.snapshot()["total"]
    assert snap["per_bin"] == [1, 1, 1, 1]       # derived non-cumulative
    assert snap["mean"] == pytest.approx(105.0 / 4)


def test_histogram_rejects_unsorted_buckets():
    with pytest.raises(ValueError):
        Histogram("h", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", buckets=())


def test_registry_get_or_create_and_kind_mismatch():
    r = MetricsRegistry()
    c1 = r.counter("repro_x_total")
    assert r.counter("repro_x_total") is c1      # same instrument back
    with pytest.raises(ValueError):
        r.gauge("repro_x_total")                 # kind flip is a bug


def test_prometheus_exposition_golden_text():
    r = MetricsRegistry()
    c = r.counter("repro_requests_total", "completed requests per tenant")
    c.inc(tenant="a")
    c.inc(2, tenant="b")
    r.gauge("repro_pages_in_use").set(5, partition="0")
    h = r.histogram("repro_lat", buckets=(0.5, 1.0))
    h.observe(0.25)
    h.observe(0.75)
    assert r.to_prometheus() == """\
# TYPE repro_lat histogram
repro_lat_bucket{le="0.5"} 1
repro_lat_bucket{le="1"} 2
repro_lat_bucket{le="+Inf"} 2
repro_lat_sum 1
repro_lat_count 2
# TYPE repro_pages_in_use gauge
repro_pages_in_use{partition="0"} 5
# HELP repro_requests_total completed requests per tenant
# TYPE repro_requests_total counter
repro_requests_total{tenant="a"} 1
repro_requests_total{tenant="b"} 2
"""


def test_registry_save_picks_format_by_extension(tmp_path):
    r = MetricsRegistry()
    r.counter("repro_x_total").inc()
    prom = tmp_path / "m.prom"
    js = tmp_path / "m.json"
    r.save(str(prom))
    r.save(str(js))
    assert "# TYPE repro_x_total counter" in prom.read_text()
    doc = json.loads(js.read_text())
    assert doc["repro_x_total"]["series"]["total"] == 1


# ---------------------------------------------------------------------------
# Tracer -> metrics sink
# ---------------------------------------------------------------------------

def test_sink_counters_agree_with_tracer_counts_past_eviction():
    """The core accounting contract: events_total{kind} tracks the same
    stream as Tracer.counts(), so both stay exact after the ring has
    evicted most of the window — and evictions land in the dropped
    counter."""
    tr = telemetry.Tracer(capacity=4)
    sink = MetricsSink().attach(tr)
    with pytest.warns(RuntimeWarning):
        for i in range(10):
            tr.record_matmul(128, 128, 128, wall_s=0.001)
        for _ in range(3):
            tr.record_request("a", wall_s=0.01, tokens=2,
                              turnaround_steps=3)
    counts = tr.counts()
    ev = sink.events
    assert ev.value(kind="matmul") == counts["matmul"] == 10
    assert ev.value(kind="request") == counts["request"] == 3
    assert len(tr) == 4                          # ring only holds the tail
    dropped = tr.dropped()
    assert sum(dropped.values()) == 9            # 13 recorded, 4 retained
    for kind, n in dropped.items():
        assert sink.dropped.value(kind=kind) == n


def test_sink_folds_requests_pages_and_latency():
    tr = telemetry.Tracer(capacity=64, partition=1)
    sink = MetricsSink().attach(tr)
    tr.record("decode", m=2, k=64, n=64, wall_s=0.004)
    tr.record_request("alpha", wall_s=0.02, tokens=8, turnaround_steps=5)
    tr.record("admit", tenant="alpha")
    tr.record("paging", meta={"phase": "alloc", "pages_in_use": 7,
                              "utilization": 0.75, "fragmentation": 0.25})
    assert sink.tokens.value(tenant="alpha") == 8
    assert sink.requests.value(tenant="alpha") == 1
    assert sink.admissions.value(tenant="alpha") == 1
    lat = sink.decode_lat.value(partition="1")
    assert lat["count"] == 1 and lat["sum"] == pytest.approx(0.004)
    ta = sink.turnaround.value(tenant="alpha")
    assert ta["count"] == 1
    assert sink.pages_in_use.value(partition="1") == 7
    assert sink.page_frag.value(partition="1") == pytest.approx(0.25)


def test_sink_counts_each_migration_once():
    """migrate events are recorded on BOTH endpoints' tracers for
    provenance; the sink dedups by counting only the source partition's
    copy."""
    src = telemetry.Tracer(partition=0)
    dst = telemetry.Tracer(partition=2)
    sink = MetricsSink().attach(src, dst)
    for tr in (src, dst):
        tr.record_migrate("a", src=0, dst=2, phase="handoff",
                          handoff_bytes=4096, uid=7)
    assert sink.migrations.value(phase="handoff", src="0", dst="2") == 1
    assert sink.handoff_bytes.value() == 4096
    assert sink.events.value(kind="migrate") == 2   # raw stream still exact


def test_sink_overlap_group_gauges():
    tr = telemetry.Tracer()
    sink = MetricsSink().attach(tr)
    tr.record("decode", wall_s=0.010, lane="sparse", overlap_group=0)
    assert sink.overlap_groups.value() == 0      # one member isn't a pair
    tr.record("decode", wall_s=0.010, lane="dense", overlap_group=0)
    assert sink.overlap_groups.value() == 1
    # equal walls: serial/concurrent = 2x, efficiency = ideal
    assert sink.overlap_speedup.value() == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# SLO arithmetic
# ---------------------------------------------------------------------------

def test_slo_parse_spec_round_trip():
    assert SLO.parse("latency:12").spec() == "latency:12"
    assert SLO.parse("latency:0.05@wall_s").spec() == "latency:0.05@wall_s"
    assert SLO.parse("throughput:1.5").spec() == "throughput:1.5"
    assert SLO.parse("batch").target == 1.0      # default full completion
    assert SLO.parse(None) is None
    slo = SLO.parse({"kind": "latency", "target": 8})
    assert SLO.parse(slo) is slo


@pytest.mark.parametrize("bad", ["bogus:1", "latency", "latency:-2",
                                 "latency:1@bogus_metric"])
def test_slo_parse_rejects_malformed(bad):
    with pytest.raises(ValueError):
        SLO.parse(bad)


def test_slo_latency_attainment_fraction_and_starvation():
    slo = SLO("latency", 10)
    assert slo.attainment(samples=(2, 4, 20), completed=3,
                          submitted=3) == pytest.approx(2 / 3)
    # demand but nothing finished: attainment is 0, not undefined
    assert slo.attainment(submitted=3, completed=0) == 0.0
    # no demand at all: no claim either way
    assert slo.attainment(submitted=0) is None


def test_slo_throughput_and_batch_classes():
    assert SLO("throughput", 2.0).attainment(
        tokens_out=10, steps=10, submitted=1) == pytest.approx(0.5)
    assert SLO("throughput", 0.5).attainment(
        tokens_out=10, steps=10, submitted=1) == 1.0   # capped
    assert SLO("batch", 1.0).attainment(
        completed=3, submitted=4) == pytest.approx(0.75)


def test_attainment_from_tracer_survives_eviction():
    """The telemetry-only path: demand from monotonic counters, samples
    from the retained window."""
    tr = telemetry.Tracer(capacity=8)
    slo = SLO("latency", 6)
    with pytest.warns(RuntimeWarning):
        for i in range(20):
            tr.record("admit", tenant="a")
            tr.record_request("a", wall_s=0.01, tokens=1,
                              turnaround_steps=4 if i % 2 else 8)
    att = attainment_from_tracer(tr, "a", slo, steps=20)
    # retained window alternates 8,4,... -> half meet the bound
    assert att == pytest.approx(0.5)
    assert attainment_from_tracer(tr, "ghost", slo, steps=20) is None
    assert attainment_from_tracer(tr, "a", None, steps=20) is None


# ---------------------------------------------------------------------------
# Chrome-trace export
# ---------------------------------------------------------------------------

def _overlapping_tracer():
    tr = telemetry.Tracer(partition=0)
    # joined "now" with 10ms walls: slice starts rebase to ~the same
    # instant, so the pair genuinely overlaps on two lane tracks
    tr.record("decode", m=2, k=64, n=64, wall_s=0.010, lane="sparse",
              overlap_group=0)
    tr.record("decode", m=4, k=64, n=64, wall_s=0.010, lane="dense",
              overlap_group=0)
    tr.record_request("alpha", wall_s=0.02, tokens=8, turnaround_steps=5,
                      uid=1)
    return tr


def test_chrome_trace_round_trip_and_overlap_geometry(tmp_path):
    tr = _overlapping_tracer()
    path = tmp_path / "trace.json"
    traceview.export_chrome_trace(tr, str(path))
    doc = traceview.load(str(path))              # valid JSON round-trip
    summary = traceview.validate(doc)
    assert summary["overlap_groups"] == 1
    assert summary["overlap_groups_overlapping"] == 1
    # the pair sits on distinct lane tracks of the same partition pid
    slices = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    pair = [e for e in slices if e["args"].get("overlap_group") == 0]
    assert len(pair) == 2
    assert pair[0]["pid"] == pair[1]["pid"]
    assert pair[0]["tid"] != pair[1]["tid"]
    # per-tenant request span survives as an async b/e pair
    spans = [e["ph"] for e in doc["traceEvents"] if e.get("cat") == "request"]
    assert "b" in spans and "e" in spans


def test_chrome_trace_migration_flow_events():
    src = telemetry.Tracer(partition=0)
    dst = telemetry.Tracer(partition=2)
    for tr in (src, dst):
        tr.record_migrate("a", src=0, dst=2, phase="handoff",
                          handoff_bytes=4096, uid=7)
    doc = traceview.to_chrome_trace(telemetry.Tracer.merge(src, dst))
    flows = traceview.migration_flow_pairs(doc)
    assert flows == [(1, 3)]                     # pid = partition + 1


def test_chrome_trace_empty_tracer_is_still_valid():
    doc = traceview.to_chrome_trace(telemetry.Tracer())
    assert doc["traceEvents"] == []
    assert traceview.validate(doc)["n_slices"] == 0


# ---------------------------------------------------------------------------
# BENCH trajectory gate
# ---------------------------------------------------------------------------

def _fig21_doc(tok_per_step=6.0, sha="abcdef123456"):
    return {
        "figure": "fig21_async_overlap",
        "meta": {"figure": "fig21_async_overlap", "git_sha": sha,
                 "hardware_key": "test-c256"},
        "serialized": {"tok_per_step": tok_per_step, "steps": 14},
        "overlap": {"tok_per_step": tok_per_step * 1.05,
                    "overlap_groups": 20},
        "serving_speedup": 1.05,
        "tokens_equal": 1,
        "contention": {"speedup": 1.1, "serialized_wall_us": 100.0,
                       "overlap_wall_us": 90.0},
    }


def _write(d, doc):
    (d / "BENCH_fig21.json").write_text(json.dumps(doc))


def test_trajectory_seed_then_check_passes(tmp_path):
    _write(tmp_path, _fig21_doc())
    store = str(tmp_path / "TRAJECTORY.json")
    added = trajectory.append_runs(str(tmp_path), store)
    assert len(added) == 1
    assert added[0]["hardware_key"] == "test-c256"
    assert trajectory.check(str(tmp_path), store) == 0
    assert trajectory.main(["--check", "--dir", str(tmp_path),
                            "--store", store]) == 0


def test_trajectory_gates_injected_20pct_regression(tmp_path):
    """The acceptance criterion: a 20% tokens/step loss must trip the
    gate (tolerance band is 10%/15%), and the process exit is non-zero
    so CI fails."""
    _write(tmp_path, _fig21_doc(tok_per_step=6.0))
    store = str(tmp_path / "TRAJECTORY.json")
    trajectory.append_runs(str(tmp_path), store)
    _write(tmp_path, _fig21_doc(tok_per_step=6.0 * 0.8, sha="feedface0000"))
    assert trajectory.check(str(tmp_path), store) >= 2   # both arms sank
    assert trajectory.main(["--check", "--dir", str(tmp_path),
                            "--store", store]) == 1


def test_trajectory_track_only_metrics_never_gate(tmp_path):
    _write(tmp_path, _fig21_doc())
    store = str(tmp_path / "TRAJECTORY.json")
    trajectory.append_runs(str(tmp_path), store)
    doc = _fig21_doc(sha="feedface0000")
    doc["contention"]["serialized_wall_us"] = 1e9   # wall absolutes drift
    _write(tmp_path, doc)
    assert trajectory.check(str(tmp_path), store) == 0


def test_trajectory_rerun_same_key_replaces_entry(tmp_path):
    _write(tmp_path, _fig21_doc())
    store = str(tmp_path / "TRAJECTORY.json")
    trajectory.append_runs(str(tmp_path), store)
    trajectory.append_runs(str(tmp_path), store)    # idempotent re-run
    runs = trajectory.load_store(store)["runs"]
    assert len(runs) == 1


def test_trajectory_missing_baseline_records_only(tmp_path):
    _write(tmp_path, _fig21_doc())
    store = str(tmp_path / "TRAJECTORY.json")
    # no store yet: nothing to compare against, but never a failure
    assert trajectory.check(str(tmp_path), store) == 0


def test_trajectory_ignores_trace_artifacts(tmp_path):
    (tmp_path / "BENCH_fig21_trace.json").write_text("{}")
    assert trajectory.bench_files(str(tmp_path)) == []


# ---------------------------------------------------------------------------
# Committed baselines stay gateable
# ---------------------------------------------------------------------------

def test_committed_bench_artifacts_cover_all_gated_metrics():
    """Every gated metric in the tables must be extractable from the
    committed BENCH files — a silently-None metric would make the CI
    gate vacuous for that figure."""
    from pathlib import Path
    root = Path(__file__).resolve().parents[1]
    for figure, fname in (("fig20_paged_serving", "BENCH_fig20.json"),
                          ("fig21_async_overlap", "BENCH_fig21.json")):
        path = root / fname
        if not path.exists():
            pytest.skip(f"{fname} not committed")
        doc = json.loads(path.read_text())
        vals = trajectory.metric_values(figure, doc)
        for m in trajectory.FIGURE_METRICS[figure]:
            if m.gate:
                assert m.name in vals, (figure, m.name)
        assert doc.get("meta", {}).get("figure") == figure
