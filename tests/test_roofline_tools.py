"""Roofline machinery unit tests: HLO collective parser, wire models,
term assembly, precision policies."""
import pytest

from repro.core import precision as pp
from repro.launch import roofline as rl

HLO_SAMPLE = """
  %all-reduce.2 = f32[16,512]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[4,8]<=[32], use_global_device_ids=true, to_apply=%add
  %all-gather.7 = bf16[8,4096,1536]{2,1,0} all-gather(%p), channel_id=2, replica_groups=[16,16]<=[256], dimensions={1}
  %reduce-scatter.1 = f32[8,256]{1,0} reduce-scatter(%x), channel_id=3, replica_groups=[1,16]<=[16], dimensions={1}, to_apply=%add
  %all-to-all.3 = f32[64,128]{1,0} all-to-all(%y), channel_id=4, replica_groups=[2,8]<=[16]
  %collective-permute.1 = bf16[4,4]{1,0} collective-permute(%z), channel_id=5, source_target_pairs={{0,1}}
  %dot.5 = f32[128,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
"""


def test_parser_finds_all_collectives():
    colls = rl.parse_collectives(HLO_SAMPLE)
    kinds = sorted(c.kind for c in colls)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]


def test_parser_shapes_dtypes_groups():
    colls = {c.kind: c for c in rl.parse_collectives(HLO_SAMPLE)}
    ar = colls["all-reduce"]
    assert ar.dtype == "f32" and ar.shape == (16, 512) and ar.group_size == 8
    ag = colls["all-gather"]
    assert ag.dtype == "bf16" and ag.shape == (8, 4096, 1536)
    assert ag.group_size == 16


def test_wire_models():
    colls = {c.kind: c for c in rl.parse_collectives(HLO_SAMPLE)}
    ar = colls["all-reduce"]          # 16*512*4 bytes, g=8
    assert ar.wire_bytes == pytest.approx(2 * 7 / 8 * 16 * 512 * 4)
    ag = colls["all-gather"]          # result is gathered: (g-1)/g * result
    assert ag.wire_bytes == pytest.approx(15 / 16 * 8 * 4096 * 1536 * 2)
    rs = colls["reduce-scatter"]      # result is scattered: (g-1) * result
    assert rs.wire_bytes == pytest.approx(15 * 8 * 256 * 4)
    cp = colls["collective-permute"]
    assert cp.wire_bytes == 4 * 4 * 2


def test_wire_bf16_caps_f32():
    colls = {c.kind: c for c in rl.parse_collectives(HLO_SAMPLE)}
    ar = colls["all-reduce"]
    assert ar.wire_bytes_bf16 == pytest.approx(ar.wire_bytes / 2)
    ag = colls["all-gather"]          # already bf16: unchanged
    assert ag.wire_bytes_bf16 == pytest.approx(ag.wire_bytes)


def test_assembly_scales_layers():
    full = rl.CellCost(flops=100.0, bytes_accessed=1000.0, wire_bytes=10.0,
                       collectives={}, wire_bytes_bf16=5.0)
    layer = rl.CellCost(flops=50.0, bytes_accessed=200.0, wire_bytes=4.0,
                        collectives={}, wire_bytes_bf16=2.0)
    roof = rl.assemble("a", "s", 256, full, layer, n_bodies=5,
                       model_flops=1e6, kind="train")
    assert roof.flops == 100 + 4 * 50
    assert roof.bytes_accessed == 1000 + 4 * 200
    assert roof.wire_bytes == 10 + 4 * 4
    assert roof.compute_s == pytest.approx(300 / rl.PEAK_FLOPS)
    assert roof.bottleneck in ("compute", "memory", "collective")
    assert roof.step_s == max(roof.compute_s, roof.memory_s,
                              roof.collective_s)


def test_decode_fraction_uses_memory_ideal():
    cost = rl.CellCost(1e9, 1e10, 1e8, {}, 1e8)
    roof = rl.assemble("a", "decode", 256, cost, None, 1,
                       model_flops=1e12, min_bytes=2.56e12, kind="decode")
    ideal = 2.56e12 / (256 * rl.HBM_BW)
    assert roof.roofline_fraction == pytest.approx(ideal / roof.step_s)


def test_model_flops_estimates():
    from repro.configs import ARCHS, SHAPES
    cfg = ARCHS["llama3-8b"]
    tr = rl.model_flops_estimate(cfg, SHAPES["train_4k"])
    assert tr == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=1e-6)
    dec = rl.model_flops_estimate(cfg, SHAPES["decode_32k"])
    assert dec == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)
    # MoE uses active params
    moe = ARCHS["llama4-scout-17b-a16e"]
    tr_moe = rl.model_flops_estimate(moe, SHAPES["train_4k"])
    assert tr_moe < 6 * moe.param_count() * 256 * 4096


def test_min_bytes_estimate_windows():
    from repro.configs import ARCHS, SHAPES
    g = ARCHS["gemma3-12b"]
    full = ARCHS["llama3-8b"]
    mg = rl.min_bytes_estimate(g, SHAPES["decode_32k"])
    mf = rl.min_bytes_estimate(full, SHAPES["decode_32k"])
    # gemma's local layers read only their 1024-token window
    assert mg < 2 * g.param_count() + 48 * 128 * 32768 * g.kv_dim * 4
    assert mf > 2 * full.param_count()


# ---------------------------------------------------------------------------
# Precision policies
# ---------------------------------------------------------------------------

def test_policies_validate():
    for p in pp.POLICIES.values():
        pp.validate(p)


def test_fp8_policy_keeps_sensitive_ops_high():
    p = pp.FP8_TRAINING
    assert p.uses_fp8()
    assert p.dtype_for("router") == "f32"
    assert p.dtype_for("ssm_recurrence") == "f32"
    assert p.dtype_for("mlp") == "fp8"


def test_policy_resolution():
    assert pp.policy_for("fp8").name == "fp8_training"
    assert pp.policy_for("fp8", serving=True).name == "fp8_serving"
    assert pp.policy_for("bf16").name == "bf16_baseline"


def test_validate_rejects_fp8_router():
    bad = pp.PrecisionPolicy("bad", {**pp.BF16_BASELINE.rules,
                                     "router": "fp8"})
    with pytest.raises(ValueError, match="must not run in FP8"):
        pp.validate(bad)


def test_unknown_op_class_raises():
    with pytest.raises(KeyError):
        pp.BF16_BASELINE.dtype_for("nonexistent")
