"""Data pipeline, optimizer, grad compression, checkpointing, fault
tolerance — substrate-level unit tests."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataCursor, Prefetcher, SyntheticLM, \
    TokenFileDataset
from repro.optim import adamw, grad_compress as gc
from repro.runtime.fault_tolerance import Heartbeat, StepMonitor, supervise


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_deterministic_and_seekable():
    d1 = SyntheticLM(1000, 16, 4, seed=7)
    d2 = SyntheticLM(1000, 16, 4, seed=7)
    b1 = [next(iter(d1)) for _ in range(3)]
    # seek directly to batch 2
    np.testing.assert_array_equal(d2.batch_at(2)["inputs"], b1[2]["inputs"])
    # labels are inputs shifted by one
    np.testing.assert_array_equal(d1.batch_at(0)["inputs"][:, 1:],
                                  d1.batch_at(0)["labels"][:, :-1])


def test_synthetic_host_sharding_disjoint():
    a = SyntheticLM(1000, 8, 8, seed=1, host_id=0, host_count=2)
    b = SyntheticLM(1000, 8, 8, seed=1, host_id=1, host_count=2)
    assert a.local_batch == 4
    assert not np.array_equal(a.batch_at(0)["inputs"], b.batch_at(0)["inputs"])


def test_token_file_dataset(tmp_path):
    path = str(tmp_path / "toks.bin")
    toks = np.arange(10_000, dtype=np.int32) % 517
    toks.tofile(path)
    ds = TokenFileDataset(path, seq_len=32, global_batch=8, vocab_size=517)
    b0 = ds.batch_at(0)
    assert b0["inputs"].shape == (8, 32)
    np.testing.assert_array_equal(b0["inputs"][0], toks[:32])
    np.testing.assert_array_equal(b0["labels"][0], toks[1:33])
    # deterministic across instances
    ds2 = TokenFileDataset(path, seq_len=32, global_batch=8, vocab_size=517)
    np.testing.assert_array_equal(ds2.batch_at(5)["inputs"],
                                  ds.batch_at(5)["inputs"])


def test_prefetcher_orders_batches():
    ds = SyntheticLM(100, 8, 2, seed=3)
    pf = Prefetcher(ds, depth=2)
    got = [next(pf) for _ in range(4)]
    pf.close()
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["inputs"],
                                      SyntheticLM(100, 8, 2, seed=3)
                                      .batch_at(i)["inputs"])


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(learning_rate=0.1, weight_decay=0.0,
                            warmup_steps=0, total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params, cfg)
    target = jnp.array([1.0, 2.0])
    for _ in range(150):
        grads = {"w": params["w"] - target}
        params, state, _ = adamw.apply(params, grads, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.2)


def test_adamw_master_weights_carry_precision():
    cfg = adamw.AdamWConfig(learning_rate=1e-4, weight_decay=0.0,
                            warmup_steps=0, total_steps=1000)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw.init(params, cfg)
    # many tiny updates that individually underflow bf16
    for _ in range(20):
        grads = {"w": jnp.full((4,), 1.0, jnp.bfloat16)}
        params, state, _ = adamw.apply(params, grads, state, cfg)
    # master moved even though each delta < bf16 ulp at 1.0
    assert float(jnp.abs(state.master["w"] - 1.0).max()) > 1e-4
    assert params["w"].dtype == jnp.bfloat16


def test_grad_clip_metric():
    cfg = adamw.AdamWConfig(grad_clip=1.0, warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros((3,))}
    state = adamw.init(params, cfg)
    _, _, m = adamw.apply(params, {"w": jnp.full((3,), 100.0)}, state, cfg)
    np.testing.assert_allclose(float(m["grad_norm"]), 100.0 * 3 ** 0.5,
                               rtol=1e-5)


def test_int8_error_feedback_reduces_bias():
    grads = {"w": jnp.linspace(-1e-3, 1e-3, 64)}
    err = gc.init_error(grads)
    acc_dq = jnp.zeros((64,))
    for _ in range(50):
        dq, err = gc.compress_int8_ef(grads, err)
        acc_dq = acc_dq + dq["w"]
    # with error feedback, the accumulated quantized grads track the truth
    np.testing.assert_allclose(np.asarray(acc_dq),
                               np.asarray(grads["w"] * 50),
                               atol=2e-3)


def test_bf16_compression_dtype():
    out = gc.compress_bf16({"w": jnp.ones((4,), jnp.float32)})
    assert out["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _state():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(10, state, extra={"data_step": 10}, blocking=True)
    assert mgr.latest_step() == 10
    restored, extra = mgr.restore(10, jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state))
    assert extra == {"data_step": 10}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_gc_keeps_last(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(), blocking=True)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_tmp_not_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / "step_99.tmp")     # simulated torn write
    assert mgr.all_steps() == []


def test_checkpoint_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(), blocking=True)
    bad = {"a": jnp.zeros((2, 3))}
    with pytest.raises(ValueError, match="structure mismatch"):
        mgr.restore(1, bad)


def test_checkpoint_restore_latest_none(tmp_path):
    assert CheckpointManager(str(tmp_path)).restore_latest(_state()) is None


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_step_monitor_flags_straggler():
    mon = StepMonitor(warmup_steps=3, k_sigma=3.0)
    for i in range(20):
        st = mon.record(i, 0.1 + 0.001 * (i % 2))
        assert not st.is_straggler
    st = mon.record(20, 0.5)                  # 5x slower
    assert st.is_straggler


def test_heartbeat_writes_file(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"), interval_s=1000)
    hb.beat(7)
    hb.close()
    import json
    with open(tmp_path / "hb.json") as f:
        data = json.load(f)
    assert data["step"] == 7


def test_supervise_restarts_until_success():
    calls = []

    def run():
        calls.append(1)
        return 0 if len(calls) >= 3 else 1
    assert supervise(run, max_restarts=5, backoff_s=0.0,
                     log=lambda *a: None) == 0
    assert len(calls) == 3


def test_supervise_exhausts_budget():
    assert supervise(lambda: 1, max_restarts=2, backoff_s=0.0,
                     log=lambda *a: None) == 1
