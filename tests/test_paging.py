"""Paged serving cache: allocator invariants, the dense-equality
exactness contract, and the paged flash-decode kernel.

The contract the whole PR leans on: paging is a memory-*layout* change,
never a numerics change — a paged greedy run must be token-for-token
identical to the dense ``ServeSession`` (solo, multi-tenant, and across
a live migration handoff). The allocator tests pin the host-side
invariants that make that safe: prefix page tables, scrub-before-reuse,
and refusal (not crash) on pool exhaustion.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.paging import PageAllocator, PagesExhausted, pages_for
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime.serve_loop import Request, ServeSession, export_nbytes

RT = RuntimeCfg(ssm_chunk=16)
MAX_LEN = 64
PAGE = 8
MP = MAX_LEN // PAGE


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _session(model, slots=4, paged=True, **kw):
    cfg, params = model
    if paged:
        kw.setdefault("page_size", PAGE)
    return ServeSession(params, cfg, batch_slots=slots, max_len=MAX_LEN,
                        rt=RT, paged=paged, **kw)


def _prompts(cfg, n, length=5, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, length).astype(np.int32)
            for _ in range(n)]


def _run_all(sess, prompts, max_new=8):
    reqs = [Request(uid=i, prompt=p.copy(), max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        sess.submit(r)
    sess.run()
    return [r.out for r in reqs]


def _pool_leaves(sess):
    """Yield each paged block's {k, v, pos} pool dict (the leaves whose
    axis-1 is the physical page pool, trash page included)."""
    for blk, leaves in sess.caches["layers"].items():
        pos = leaves.get("pos")
        if pos is not None and pos.ndim == 3 \
                and pos.shape[1] == sess.pages + 1 \
                and pos.shape[2] == sess.page_size:
            yield blk, leaves


# ---------------------------------------------------------------------------
# Allocator (host side, no model)
# ---------------------------------------------------------------------------

def test_alloc_extend_free_roundtrip():
    a = PageAllocator(n_pages=8, page_size=4, max_pages_per_slot=4,
                      n_slots=3)
    assert pages_for(0, 4) == 0 and pages_for(1, 4) == 1 \
        and pages_for(5, 4) == 2
    p0 = a.alloc_slot(0, 6)                  # 6 tokens -> 2 pages
    assert len(p0) == 2 and a.pages_in_use == 2
    grown = a.extend_slot(0, 9)              # -> 3 pages, 1 new
    assert len(grown) == 1 and a.slot_pages(0) == p0 + grown
    assert a.extend_slot(0, 9) == []         # idempotent: no new pages
    released = a.free_slot(0)
    assert sorted(released) == sorted(p0 + grown)
    assert a.pages_in_use == 0 and a.free_pages == 8
    assert a.slot_pages(0) == []
    # the table is always a logical prefix: page_map pads with -1
    a.alloc_slot(1, 4)
    pm = a.page_map()
    assert pm.shape == (3, 4) and (pm[1, 1:] == -1).all() and pm[1, 0] >= 0
    st = a.stats()
    assert st["allocs"] == 2 and st["frees"] == 1 and st["extends"] == 1
    assert st["utilization"] == 1.0          # 4 tokens in one 4-token page


def test_double_alloc_and_bad_extend_rejected():
    a = PageAllocator(4, 4, 4, 2)
    a.alloc_slot(0, 4)
    with pytest.raises(ValueError):
        a.alloc_slot(0, 4)                   # slot already holds pages
    with pytest.raises(ValueError):
        a.extend_slot(1, 4)                  # empty slot can't extend


def test_out_of_pages_is_refused_not_crashed():
    a = PageAllocator(n_pages=2, page_size=4, max_pages_per_slot=4,
                      n_slots=2)
    assert a.can_admit_tokens(8) and not a.can_admit_tokens(9)
    a.alloc_slot(0, 8)                       # pool now full
    with pytest.raises(PagesExhausted):
        a.alloc_slot(1, 1)
    assert a.stats()["oom_refusals"] == 1
    # slot 1 untouched, slot 0 unharmed, and freeing recovers the pool
    assert a.slot_pages(1) == [] and len(a.slot_pages(0)) == 2
    a.free_slot(0)
    assert a.can_admit_tokens(8)


def test_per_slot_cap_enforced():
    a = PageAllocator(16, 4, 2, 2)
    with pytest.raises(PagesExhausted):
        a.alloc_slot(0, 12)                  # 3 pages > cap 2
    assert not a.can_admit_tokens(12)


def test_free_list_is_lifo():
    a = PageAllocator(4, 4, 4, 2)
    pages = a.alloc_slot(0, 16)
    a.free_slot(0)
    again = a.alloc_slot(1, 16)
    assert again == pages                    # just-freed pages reused first


# ---------------------------------------------------------------------------
# Exactness contract: paged ≡ dense, token for token
# ---------------------------------------------------------------------------

def test_paged_solo_matches_dense(model):
    cfg, _ = model
    (p,) = _prompts(cfg, 1)
    dense = _run_all(_session(model, slots=4, paged=False), [p.copy()])
    paged = _run_all(_session(model, slots=4), [p.copy()])
    assert paged == dense


def test_paged_multi_tenant_matches_dense(model):
    cfg, _ = model
    prompts = _prompts(cfg, 6, seed=1)
    dense = _run_all(_session(model, slots=4, paged=False), prompts)
    paged = _run_all(_session(model, slots=4), prompts)
    assert paged == dense


def test_page_reuse_does_not_leak_stale_kv(model):
    """The LIFO free list hands a freed tenant's pages straight to the
    next occupant — outputs must match a fresh session exactly, which
    fails if free_slot didn't scrub the released pool rows."""
    cfg, _ = model
    pa, pb = _prompts(cfg, 2, seed=2)
    sess = _session(model, slots=1)
    _run_all(sess, [pa])
    # everything returned and the real pages are fully scrubbed (the
    # trash page, pool index `pages`, is scratch by design)
    assert sess.pager.pages_in_use == 0
    found_pool = False
    for _, leaves in _pool_leaves(sess):
        found_pool = True
        assert (np.asarray(leaves["pos"])[:, :-1] == -1).all()
        assert (np.asarray(leaves["k"], np.float32)[:, :-1] == 0).all()
        assert (np.asarray(leaves["v"], np.float32)[:, :-1] == 0).all()
    assert found_pool
    (out_b,) = _run_all(sess, [pb])
    (ref_b,) = _run_all(_session(model, slots=1), [pb.copy()])
    assert out_b == ref_b


def test_admission_refused_when_pool_exhausted(model):
    """A pool with headroom for one resident queues (not crashes) the
    second request and serves it after the first finishes — and the
    outputs still match the per-request dense oracle."""
    cfg, _ = model
    prompts = _prompts(cfg, 2, length=9, seed=3)
    # 9-token prompts need 2 pages at admit and 3 by completion
    # (9 + 8 = 17 tokens); a 3-page pool holds exactly one at a time.
    sess = _session(model, slots=2, pages=3)
    outs = _run_all(sess, prompts, max_new=8)
    assert sess.pager.stats()["oom_refusals"] == 0   # refused via can_admit
    assert sess.pager.stats()["peak_pages_in_use"] <= 3
    ref = [_run_all(_session(model, slots=2, paged=False), [p.copy()],
                    max_new=8)[0] for p in prompts]
    assert outs == ref
    # direct admit without headroom raises the typed refusal
    s2 = _session(model, slots=2, pages=1)
    with pytest.raises(PagesExhausted):
        s2.admit(Request(uid=0, prompt=prompts[0].copy(), max_new=8))


def test_mid_decode_pool_exhaustion_truncates(model):
    """A request that outgrows the pool mid-decode finishes truncated —
    never a crash — and its pages are fully released afterwards."""
    cfg, _ = model
    (p,) = _prompts(cfg, 1, seed=4)
    sess = _session(model, slots=1, pages=1)     # one page: 8 positions
    req = Request(uid=0, prompt=p.copy(), max_new=32)
    sess.submit(req)
    sess.run()
    assert req.done
    assert 0 < len(req.out) < 32                 # truncated, not served
    assert sess.pager.stats()["oom_refusals"] >= 1
    assert sess.pager.pages_in_use == 0          # slot fully released


def test_migration_handoff_mid_request_token_identical(model):
    """Export a slot mid-request, import into a second paged session,
    finish there: outputs equal the uninterrupted dense run, and the
    handoff moves pages-in-use, not slot capacity."""
    cfg, _ = model
    (p,) = _prompts(cfg, 1, seed=5)
    src = _session(model, slots=2)
    dst = _session(model, slots=2)
    req = Request(uid=7, prompt=p.copy(), max_new=12)
    src.admit(req)
    for _ in range(4):
        src.decode_once()
    assert dst.can_accept_pages(src.handoff_pages(0), src.page_size)
    export = src.export_slot(0)
    assert export.pages == src.pager.pages_for(export.pos + 1)
    assert export.page_size == PAGE
    paged_bytes = export_nbytes(export)
    dst.import_slot(export)
    while not req.done:
        dst.decode_once()
    ref = Request(uid=8, prompt=p.copy(), max_new=12)
    dsess = _session(model, slots=2, paged=False)
    dsess.admit(ref)
    while not ref.done:
        dsess.decode_once()
    assert req.out == ref.out
    # O(pages) beats O(max_len): the same handoff through dense sessions
    d_src = _session(model, slots=2, paged=False)
    d_req = Request(uid=9, prompt=p.copy(), max_new=12)
    d_src.admit(d_req)
    for _ in range(4):
        d_src.decode_once()
    dense_bytes = export_nbytes(d_src.export_slot(0))
    assert paged_bytes < dense_bytes


def test_paged_and_dense_sessions_cannot_mix_handoffs(model):
    cfg, _ = model
    (p,) = _prompts(cfg, 1)
    src = _session(model, slots=1)
    src.admit(Request(uid=0, prompt=p.copy(), max_new=8))
    export = src.export_slot(0)
    dst = _session(model, slots=1, paged=False)
    with pytest.raises(ValueError):
        dst.import_slot(export)


def test_jit_cache_key_includes_page_geometry(model):
    """Sessions differing only in page geometry must not share a jitted
    step (the traced cache layout differs)."""
    s1 = _session(model, slots=2, page_size=8)
    s2 = _session(model, slots=2, page_size=16)
    s3 = _session(model, slots=2, page_size=8, pages=4)
    assert s1.step_fn is not s2.step_fn
    assert s1.step_fn is not s3.step_fn


# ---------------------------------------------------------------------------
# Paged flash-decode kernel vs jnp reference (interpret mode)
# ---------------------------------------------------------------------------

def test_paged_kernel_matches_reference():
    import jax.numpy as jnp
    from repro.kernels.paged_attention import (
        paged_attention_reference, paged_flash_decode_pallas)
    B, h, kvh, hd, ps, mp = 3, 4, 2, 16, 8, 4
    pool = B * mp + 1
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, h, hd), jnp.float32)
    k_pages = jax.random.normal(kk, (pool, ps, kvh, hd), jnp.float32)
    v_pages = jax.random.normal(kv, (pool, ps, kvh, hd), jnp.float32)
    pm = np.full((B, mp), -1, np.int32)
    pm[0, :2] = [5, 9]                       # partially-filled table
    pm[1, :4] = [0, 1, 2, 3]                 # full table
    pm[2, :1] = [7]                          # single page, single token
    lengths = jnp.asarray([13, 32, 1], jnp.int32)
    ref = paged_attention_reference(q, k_pages, v_pages, jnp.asarray(pm),
                                    lengths)
    out = paged_flash_decode_pallas(q, k_pages, v_pages, jnp.asarray(pm),
                                    lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_backend_registered():
    import repro.kernels.paged_attention  # noqa: F401
    from repro.kernels.registry import available_backends, get_backend
    assert "pallas_paged" in available_backends()
    assert "paged" in get_backend("pallas_paged").description


def test_pagedsweep_records_feed_autotune_store(tmp_path):
    from repro.core import execution as ex
    from repro.core.autotune import AutotuneStore
    from repro.kernels.paged_attention import sweep_paged_tilings
    recs = sweep_paged_tilings(batch=2, seq=32, head_dim=16,
                               page_sizes=[8, 16], iters=1,
                               record_cache=False)
    assert len(recs) == 2
    m, n, k, prec, blocks = ex.parse_pagedsweep_name(recs[0].name)
    assert (m, n, k, prec) == (2, 32, 16, "bf16") and blocks[1] in (8, 16)
    store = AutotuneStore(str(tmp_path))
    assert store.add_records(recs) == 2
    # both geometries share the (m, k, n, prec) key; the min-latency
    # page size wins the block entry
    entry = store.blocks[(2, 16, 32, "bf16")]
    assert entry[0] in ((1, 8, 16), (1, 16, 16))
