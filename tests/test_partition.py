"""Partitioned serving runtime: device partitions, tenant routing,
fused telemetry, and telemetry-driven adaptive quotas.

The behavioral contracts of the partition layer:

* routing is deterministic — same tenants + weights → same placement;
* serving is *partition-local* — a multi-partition run produces exactly
  the tokens each partition's tenants would produce served solo, and a
  1-partition server reproduces the plain ``StreamScheduler`` run
  token-for-token;
* ``Tracer.merge`` fuses per-partition telemetry with exact counters;
* ``AdaptiveQuota`` converges: a hogging tenant's slot cap shrinks and
  the remaining tenants stay fair.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import init_params
from repro.models.layers import RuntimeCfg
from repro.runtime import telemetry
from repro.runtime.partition import (
    PLACEMENTS, DevicePartition, PartitionedServer, make_partitions,
    run_partitioned)
from repro.runtime.scheduler import (
    AdaptiveQuota, StaticQuota, StreamScheduler, make_quota, run_tenants)
from repro.runtime.serve_loop import Request, ServeSession

RT = RuntimeCfg(ssm_chunk=16)
MAX_LEN = 64


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced("llama3-8b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, tenant_idx, n=2, max_new=6, length=5):
    rng = np.random.default_rng(tenant_idx)
    return [Request(uid=tenant_idx * 100 + j,
                    prompt=rng.integers(0, cfg.vocab_size, length)
                    .astype(np.int32), max_new=max_new)
            for j in range(n)]


def _server(model, **kw):
    cfg, params = model
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("rt", RT)
    return PartitionedServer(params, cfg, **kw)


# ---------------------------------------------------------------------------
# Partitions
# ---------------------------------------------------------------------------

def test_make_partitions_disjoint():
    devs = tuple(f"dev{i}" for i in range(8))
    parts = make_partitions(3, devices=devs)
    assert [len(p.devices) for p in parts] == [3, 3, 2]
    seen = [d for p in parts for d in p.devices]
    assert len(seen) == len(set(seen)) == 8        # disjoint, complete
    assert not any(p.logical for p in parts)


def test_make_partitions_single_device_fallback():
    """CPU CI: fewer devices than partitions → logical partitions that
    share the device but are fully separate serving states."""
    parts = make_partitions(4, devices=("cpu0",))
    assert len(parts) == 4
    assert all(p.logical for p in parts)
    assert [p.index for p in parts] == [0, 1, 2, 3]
    with pytest.raises(ValueError):
        make_partitions(0)


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------

TENANTS = (("a", 2.0), ("b", 1.0), ("c", 1.0), ("d", 3.0), ("e", 1.0))


@pytest.mark.parametrize("placement", PLACEMENTS)
def test_placement_deterministic(model, placement):
    """Same tenants + weights registered in the same order land on the
    same partitions, every time (acceptance criterion)."""
    def place():
        srv = _server(model, n_partitions=3, placement=placement)
        for tid, w in TENANTS:
            srv.add_tenant(tid, weight=w)
        return dict(srv.tenant_partition)

    first = place()
    assert place() == first
    assert set(first.values()) <= {0, 1, 2}


def test_packed_fills_partitions_in_order(model):
    srv = _server(model, n_partitions=2, placement="packed", batch_slots=2)
    assert [srv.add_tenant(f"t{i}") for i in range(5)] == [0, 0, 1, 1, 0]


def test_spread_balances_by_weight(model):
    srv = _server(model, n_partitions=2, placement="spread")
    assert srv.add_tenant("heavy", weight=3.0) == 0
    assert srv.add_tenant("light1", weight=1.0) == 1
    assert srv.add_tenant("light2", weight=1.0) == 1   # 3.0 vs 1.0 -> p1
    assert srv.add_tenant("light3", weight=1.0) == 1   # 3.0 vs 2.0 -> p1


def test_load_aware_follows_measured_congestion(model):
    """With traffic only on partition 0, load_aware routes the next
    tenant to the idle partition even when registered weights tie."""
    cfg, _ = model
    srv = _server(model, n_partitions=2, placement="load_aware")
    srv.add_tenant("busy", partition=0)
    srv.add_tenant("idle_holder", partition=1)
    for req in _requests(cfg, 0, n=2, max_new=4):
        srv.submit("busy", req)
    srv.run()
    # partition 0 now carries decode EMA signal but no backlog — weights
    # tie at 1.0 each, so the index tiebreak would pick 0; give 0 backlog
    # so its measured load is visible
    for req in _requests(cfg, 1, n=1, max_new=4):
        srv.submit("busy", req)
    assert srv.add_tenant("newcomer") == 1


# ---------------------------------------------------------------------------
# Partition-local execution (token equality)
# ---------------------------------------------------------------------------

def test_single_partition_reproduces_stream_scheduler(model):
    """A 1-partition server is the old stack: same admitted order, same
    tokens, token-for-token (acceptance criterion)."""
    cfg, params = model
    wl_a = {f"t{i}": _requests(cfg, i) for i in range(3)}
    wl_b = {f"t{i}": _requests(cfg, i) for i in range(3)}

    srv = _server(model, n_partitions=1, placement="packed",
                  admission="fair_quantum", batch_slots=2)
    for tid in wl_a:
        srv.add_tenant(tid)
    for tid, reqs in wl_a.items():
        for r in reqs:
            srv.submit(tid, r)
    srv.run()

    sess = ServeSession(params, cfg, batch_slots=2, max_len=MAX_LEN, rt=RT)
    run_tenants(sess, wl_b, admission="fair_quantum")

    (sched,) = srv.schedulers
    for tid in wl_a:
        for a, b in zip(wl_a[tid], wl_b[tid]):
            assert a.done and b.done
            assert a.out == b.out, f"{tid} diverged"
    assert sched.admitted_order           # sanity: the facade admitted


def test_multi_partition_equals_solo_runs_token_for_token(model):
    """Each tenant's tokens in a 2-partition shared run match the same
    tenant served in a solo scheduler on a fresh session — partitions
    are isolation domains (acceptance criterion)."""
    cfg, params = model
    shared = {f"t{i}": _requests(cfg, i) for i in range(4)}
    srv = _server(model, n_partitions=2, placement="spread",
                  admission="fair_quantum", batch_slots=2)
    for tid in shared:
        srv.add_tenant(tid)
    for tid, reqs in shared.items():
        for r in reqs:
            srv.submit(tid, r)
    srv.run()
    assert set(srv.tenant_partition.values()) == {0, 1}

    for i in range(4):
        solo = {f"t{i}": _requests(cfg, i)}
        sess = ServeSession(params, cfg, batch_slots=2, max_len=MAX_LEN,
                            rt=RT)
        run_tenants(sess, solo, admission="fair_quantum")
        for a, b in zip(shared[f"t{i}"], solo[f"t{i}"]):
            assert a.out == b.out, f"t{i} diverged from solo run"


# ---------------------------------------------------------------------------
# Fused telemetry (Tracer.merge)
# ---------------------------------------------------------------------------

def test_tracer_merge_counts_exact_and_percentiles_fused():
    """Counts survive source-ring eviction (summed from monotonic
    counters); percentile views fuse the retained windows; partition
    tags are preserved (acceptance criterion)."""
    t0 = telemetry.Tracer(capacity=4, partition=0)
    t1 = telemetry.Tracer(capacity=64, partition=1)
    for i in range(10):                   # 6 evicted from t0's ring
        t0.record_request("alpha", wall_s=0.010, tokens=1)
    for w in (0.1, 0.2, 0.3, 0.4):
        t1.record_request("beta", wall_s=w, tokens=1)

    merged = telemetry.Tracer.merge(t0, t1)
    assert merged.counts()["request"] == 14
    assert merged.tenant_counts("request") == {"alpha": 10, "beta": 4}
    assert len(merged) == 8               # retained windows: 4 + 4
    assert merged.partition_counts("request") == {0: 4, 1: 4}

    pcts = merged.tenant_percentiles()
    assert pcts["alpha"]["p50"] == pytest.approx(0.010)
    assert pcts["beta"]["p50"] == pytest.approx(np.percentile(
        [0.1, 0.2, 0.3, 0.4], 50))
    assert pcts["beta"]["p99"] == pytest.approx(np.percentile(
        [0.1, 0.2, 0.3, 0.4], 99))
    # events replayed in timestamp order
    ts = [e.t for e in merged.events()]
    assert ts == sorted(ts)
    assert telemetry.Tracer.merge().counts() == {}


def test_partitioned_server_merged_tracer(model):
    cfg, _ = model
    srv = _server(model, n_partitions=2, placement="spread")
    for i in range(2):
        srv.add_tenant(f"t{i}")
        for r in _requests(cfg, i, n=1, max_new=4):
            srv.submit(f"t{i}", r)
    srv.run()
    merged = srv.merged_tracer()
    assert merged.tenant_counts("request") == {"t0": 1, "t1": 1}
    parts = merged.partition_counts("request")
    assert parts == {0: 1, 1: 1}
    assert "partitions:" in merged.summary()


# ---------------------------------------------------------------------------
# Adaptive quotas
# ---------------------------------------------------------------------------

def test_make_quota_specs():
    assert isinstance(make_quota(None), StaticQuota)
    assert isinstance(make_quota("static"), StaticQuota)
    assert isinstance(make_quota("adaptive"), AdaptiveQuota)
    aq = AdaptiveQuota(interval=3)
    assert make_quota(aq) is aq
    with pytest.raises(ValueError):
        make_quota("lottery")
    with pytest.raises(ValueError):
        AdaptiveQuota(interval=0)


def test_static_quota_unchanged_behavior(model):
    """The refactor's null hypothesis: a default scheduler resolves the
    same caps as before (tenant stream budget, else advisor cap)."""
    cfg, params = model
    sess = ServeSession(params, cfg, batch_slots=4, max_len=MAX_LEN, rt=RT)
    sched = StreamScheduler(sess, admission="fair_quantum")
    assert isinstance(sched.quota, StaticQuota)
    t = sched.add_tenant("t0")
    assert sched._slot_cap(t) == sched._advisor_cap()


def test_adaptive_quota_seeds_weighted_share(model):
    cfg, params = model
    sess = ServeSession(params, cfg, batch_slots=4, max_len=MAX_LEN, rt=RT)
    sched = StreamScheduler(sess, admission="fair_quantum",
                            quota="adaptive")
    assert sched.tracer is not None       # private tracer auto-created
    heavy = sched.add_tenant("heavy", weight=2.0)
    light = sched.add_tenant("light", weight=1.0)
    assert sched._slot_cap(heavy) == 3    # floor(4*2/3)=2 (+1 remainder)
    assert sched._slot_cap(light) == 1
    caps = sched.quota.caps
    assert sum(caps.values()) <= max(4, 2)


def test_adaptive_quota_shrinks_hog_and_keeps_victims_fair(model):
    """Convergence (acceptance criterion): a tenant that floods the
    partition with a deep backlog — the outlier p99/p50 turnaround tail —
    loses slot quota online, while the steady tenants stay fair among
    themselves (fairness >= 0.8). The hog's own mean turnaround is
    structurally larger (it queued 5x the work), so fairness is asserted
    over the victims the quota loop is protecting."""
    cfg, params = model
    sess = ServeSession(params, cfg, batch_slots=4, max_len=MAX_LEN, rt=RT)
    aq = AdaptiveQuota(interval=4)
    sched = StreamScheduler(sess, admission="fair_quantum", quota=aq)
    sched.add_tenant("hog")
    sched.add_tenant("v1")
    sched.add_tenant("v2")
    for r in _requests(cfg, 0, n=10, max_new=6):
        sched.submit("hog", r)
    cap0 = sched._slot_cap(sched.tenants["hog"])
    assert cap0 == 2                      # 4 slots / 3 equal tenants (+rem)

    # steady latency-sensitive victims: one short request each, every few
    # steps — their turnaround stays flat, the hog's tail stretches
    rng = np.random.default_rng(9)
    for round_ in range(5):
        sched.submit("v1", Request(
            uid=1000 + round_, max_new=3,
            prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32)))
        sched.submit("v2", Request(
            uid=2000 + round_, max_new=3,
            prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32)))
        for _ in range(6):
            sched.step()
    sched.run(max_steps=2000)

    assert aq.recalcs >= 1, "quota loop never re-derived caps"
    assert aq.shrunk.get("hog", 0) >= 1, "hog quota never shrunk"
    assert aq.caps["hog"] < cap0
    # budget conserved, not leaked: every slot the hog lost was granted
    # to someone (even a momentarily idle victim)
    assert sum(aq.caps.values()) == max(sess.batch_slots,
                                        len(sched.tenants))
    rep = sched.report()
    victim_ta = [t.mean_turnaround_steps for t in rep.tenants
                 if t.tenant_id != "hog"]
    from repro.core.concurrency import fairness
    assert fairness(victim_ta) >= 0.8, rep.summary()
    # every submitted request still completes — shrinking quotas must
    # never starve anyone out entirely
    assert all(t.completed for t in rep.tenants)


def test_partitioned_fairness_beats_single_fifo(model):
    """The fig18 headline at test scale: single-partition FIFO collapses
    cross-tenant fairness; 2 partitions with load-aware placement and
    adaptive quotas restore it at no worse step-domain throughput."""
    cfg, params = model

    def wl():
        return {f"t{i}": _requests(cfg, i, n=2, max_new=6)
                for i in range(4)}

    fifo = run_partitioned(params, cfg, wl(), n_partitions=1,
                           placement="packed", admission="fifo",
                           quota="static", batch_slots=2,
                           max_len=MAX_LEN, rt=RT)
    part = run_partitioned(params, cfg, wl(), n_partitions=2,
                           placement="load_aware",
                           admission="fair_quantum", quota="adaptive",
                           batch_slots=2, max_len=MAX_LEN, rt=RT)
    assert part.fairness >= 0.8, part.summary()
    assert fifo.fairness < part.fairness
    assert part.tokens_out == fifo.tokens_out
    assert part.tokens_out / part.steps >= fifo.tokens_out / fifo.steps
    assert part.quota == "adaptive" and fifo.quota == "static"
    d = part.to_dict()
    assert d["n_partitions"] == 2 and len(d["partitions"]) == 2


def test_shared_quota_instance_rejected_across_partitions(model):
    with pytest.raises(ValueError):
        _server(model, n_partitions=2, quota=AdaptiveQuota())
    aq = AdaptiveQuota()
    with pytest.raises(ValueError):       # same instance smuggled in a list
        _server(model, n_partitions=2, quota=[aq, aq])
    with pytest.raises(ValueError):       # wrong sequence length
        _server(model, n_partitions=3, quota=["adaptive", "static"])
    srv = _server(model, n_partitions=2,
                  quota=[AdaptiveQuota(), AdaptiveQuota()])
    assert all(isinstance(s.quota, AdaptiveQuota)
               for s in srv.schedulers)
    # repeated *specs* are fine: each partition instantiates its own
    srv2 = _server(model, n_partitions=2, quota=("adaptive", "adaptive"))
    q0, q1 = (s.quota for s in srv2.schedulers)
    assert isinstance(q0, AdaptiveQuota) and isinstance(q1, AdaptiveQuota)
    assert q0 is not q1
    with pytest.raises(ValueError):
        _server(model, n_partitions=1, placement="nearest")
