"""DeepSeek-67B — dense llama-architecture decoder.

[arXiv:2401.02954; hf deepseek-ai/deepseek-llm-67b-base] 95L d_model=8192
64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    d_ff=22016,
    vocab_size=102400,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=10_000.0,
    attn_strategy="head_tp",
    fsdp=True,
    remat="full",
)

REDUCED = ArchConfig(
    name="deepseek-67b-reduced",
    family="dense",
    num_layers=2,
    d_model=128,
    d_ff=344,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    rope_theta=10_000.0,
    attn_strategy="head_tp",
)
