"""Llama-3.1-8B — dense decoder, GQA, 128k vocab.

[arXiv:2407.21783] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=128256,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=500_000.0,
    attn_strategy="head_tp",
    fsdp=True,
    remat="full",
)

REDUCED = ArchConfig(
    name="llama3-8b-reduced",
    family="dense",
    num_layers=2,
    d_model=128,
    d_ff=448,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    rope_theta=500_000.0,
    attn_strategy="head_tp",
    remat="full",
)
