"""Configuration dataclasses for architectures, shapes, and runs.

Every assigned architecture is expressed as an :class:`ArchConfig`; input
shapes are :class:`ShapeConfig`; a (arch, shape, mesh) triple plus technique
switches forms a :class:`RunConfig`, which is what the launcher consumes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Architecture
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    """Static description of a model architecture.

    The layer stack is described as a repeating *pattern* of block kinds so
    heterogeneous stacks (gemma3 5:1 local:global, zamba2 hybrid) can be
    lowered with a single ``lax.scan`` over super-layers.
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int

    # --- attention (0 heads == attention-free) ---
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 500_000.0

    # attention pattern: "full" | "local_global"
    attn_kind: str = "full"
    window_size: int = 0             # sliding window for local layers
    local_per_global: int = 0        # e.g. 5 -> pattern [local]*5 + [global]

    # --- MoE ---
    num_experts: int = 0
    experts_top_k: int = 0
    moe_shared_expert: bool = False
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 1024       # GShard dispatch group size (tokens)

    # --- SSM / linear attention ---
    ssm_kind: str = ""               # "" | mamba2 | rwkv6
    ssm_state: int = 0               # N (mamba2 d_state)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0              # hybrid: shared attn block every N ssm layers

    # --- IO ---
    input_mode: str = "tokens"       # tokens | embeddings (stub frontend)

    # --- norm/misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # Vocab padding so the embedding/logits shard evenly on the model axis
    # (e.g. granite's 49155). Logits over padding are masked to -inf.
    vocab_pad_to: int = 256

    # --- technique switches (paper features; default paper-faithful FP8 off
    #     so bf16 is the dense baseline, mirroring the paper's dense rocBLAS
    #     baseline) ---
    precision: str = "bf16"          # bf16 | fp8
    sparsity_24: bool = False        # 2:4 packed weights in linear layers
    fp8_amax_history: int = 16

    # --- distribution policy ---
    attn_strategy: str = "head_tp"   # head_tp | seq_tp
    remat: str = "none"              # none | dots | full
    # Shard params on the data axis too (ZeRO-3/FSDP); required >= ~30B.
    fsdp: bool = False

    # ------------------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.num_heads == 0

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    # Layer pattern -------------------------------------------------------
    @property
    def superlayer_pattern(self) -> Tuple[str, ...]:
        """Block kinds inside one scanned super-layer."""
        if self.ssm_kind == "mamba2" and self.attn_every:
            # hybrid: attn_every mamba blocks then one shared attention block
            return tuple(["mamba2"] * self.attn_every + ["shared_attn"])
        if self.ssm_kind == "mamba2":
            return ("mamba2",)
        if self.ssm_kind == "rwkv6":
            return ("rwkv6",)
        if self.attn_kind == "local_global" and self.local_per_global:
            return tuple(["attn_local"] * self.local_per_global + ["attn_global"])
        if self.num_experts:
            return ("attn_moe",)
        return ("attn_dense",)

    @property
    def num_superlayers(self) -> int:
        """Scanned super-layers. Hybrid stacks may leave a tail (see below)."""
        pat = self.superlayer_pattern
        if "shared_attn" in pat:
            return self.num_layers // self.attn_every
        n, rem = divmod(self.num_layers, len(pat))
        if rem:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"superlayer size {len(pat)}")
        return n

    @property
    def hybrid_tail_layers(self) -> int:
        """Trailing SSM layers not covered by full (ssm*attn_every + shared
        attn) super-layers — e.g. zamba2's 38 = 6*6 + 2."""
        if "shared_attn" in self.superlayer_pattern:
            return self.num_layers % self.attn_every
        return 0

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND MODEL_FLOPS and memory checks)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d           # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * d      # lm head
        pat = self.superlayer_pattern
        per_pattern = 0
        for kind in pat:
            if kind in ("attn_dense", "attn_local", "attn_global"):
                per_pattern += self._attn_params() + self._mlp_params()
            elif kind == "attn_moe":
                per_pattern += self._attn_params() + self._moe_params()
            elif kind == "mamba2":
                per_pattern += self._mamba2_params()
            elif kind == "rwkv6":
                per_pattern += self._rwkv6_params()
            elif kind == "shared_attn":
                pass                      # counted once below (shared)
            per_pattern += 2 * d          # norms
        if "shared_attn" in pat:
            per_ssm = self._mamba2_params() + 2 * d
            n += self.num_layers * per_ssm                         # all ssm blocks
            n += self._attn_params() + self._mlp_params() + 2 * d  # shared block, once
        else:
            n += (self.num_layers // len(pat)) * per_pattern
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE-aware)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        all_expert = self.num_layers * self.num_experts * 3 * d * self.d_ff
        k = self.experts_top_k + (1 if self.moe_shared_expert else 0)
        active_expert = self.num_layers * k * 3 * d * self.d_ff
        return total - all_expert + active_expert

    def _attn_params(self) -> int:
        d = self.d_model
        return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

    def _mlp_params(self) -> int:
        return 3 * self.d_model * self.d_ff

    def _moe_params(self) -> int:
        d = self.d_model
        n = self.d_model * self.num_experts                 # router
        n += self.num_experts * 3 * d * self.d_ff           # expert FFNs
        if self.moe_shared_expert:
            n += 3 * d * self.d_ff
        return n

    def _mamba2_params(self) -> int:
        d, di, N = self.d_model, self.ssm_d_inner, self.ssm_state
        nh = self.ssm_nheads
        # in_proj -> (z, x, B, C, dt), conv over (x,B,C), out_proj
        n = d * (2 * di + 2 * N + nh)
        n += 4 * (di + 2 * N)            # conv1d width 4
        n += nh * 2                       # A_log, D
        n += di * d                       # out_proj
        return n

    def _rwkv6_params(self) -> int:
        d = self.d_model
        # time-mix: r,k,v,g,w projections + output
        n = 5 * d * d + d * d
        n += self.ssm_nheads * self.ssm_head_dim  # u (bonus)
        n += 6 * d                        # mix coefficients
        # channel-mix: receptance (d,d), key (d,ff), value (ff,d)
        n += d * d + d * self.d_ff + self.d_ff * d
        return n

    def with_technique(self, precision: Optional[str] = None,
                       sparsity_24: Optional[bool] = None) -> "ArchConfig":
        kw = {}
        if precision is not None:
            kw["precision"] = precision
        if sparsity_24 is not None:
            kw["sparsity_24"] = sparsity_24
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable_shapes(arch: ArchConfig) -> Tuple[ShapeConfig, ...]:
    """Shapes assigned to an architecture.

    ``long_500k`` requires sub-quadratic attention: run for SSM/hybrid/
    linear-attention archs (zamba2, rwkv6) and — as a documented extra — for
    gemma3 (5/6 sliding-window layers, seq-sharded global cache). Skipped for
    pure full-attention archs per the assignment (see DESIGN.md §4).
    """
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch.ssm_kind in ("mamba2", "rwkv6") or arch.attn_kind == "local_global":
        out.append(LONG_500K)
    return tuple(out)


# ---------------------------------------------------------------------------
# Run config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RunConfig:
    arch: ArchConfig
    shape: ShapeConfig
    multi_pod: bool = False
    # training hyperparams (examples / e2e driver)
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    grad_clip: float = 1.0
    seed: int = 0
    microbatch: int = 0              # 0 = no gradient accumulation
    grad_compress: str = "none"      # none | bf16 | int8_ef
    checkpoint_dir: str = ""
    checkpoint_every: int = 100
