"""RWKV-6 (Finch) 3B — attention-free, data-dependent decay linear attention.

[arXiv:2404.05892; hf RWKV/rwkv-6-world-3b] 32L d_model=2560 (attn-free)
d_ff=8960 vocab=65536; head_dim=64 -> 40 wkv heads.

The paper's FP8-matrix-core technique applies to the projection GEMMs only;
the wkv recurrence is not a matmul (see DESIGN.md §4 arch-applicability).
State is sharded along the value feature dim (64 -> 4/shard), which makes
the recurrence communication-free.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab_size=65536,
    num_heads=0,                  # attention-free
    ssm_kind="rwkv6",
    ssm_head_dim=64,
    ssm_expand=1,                 # wkv operates at d_model width
    ssm_chunk=128,                # pairwise-decay temp stays VMEM-sized
    attn_strategy="head_tp",      # unused (attn-free)
    remat="full",
)

REDUCED = ArchConfig(
    name="rwkv6-3b-reduced",
    family="ssm",
    num_layers=2,
    d_model=128,
    d_ff=256,
    vocab_size=512,
    num_heads=0,
    ssm_kind="rwkv6",
    ssm_head_dim=32,
    ssm_expand=1,
    ssm_chunk=32,
)
