"""Llama-4-Scout-17B-16E — MoE decoder (16 experts, top-1, shared expert).

[hf meta-llama/Llama-4-Scout-17B-16E] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 (per expert) vocab=202048, MoE 16e top-1, early fusion.

40 heads do not divide the model axis (16) -> context-parallel attention.
16 experts shard exactly onto the model axis (expert parallelism).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab_size=202048,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=500_000.0,
    num_experts=16,
    experts_top_k=1,
    moe_shared_expert=True,
    attn_strategy="seq_tp",
    fsdp=True,
    remat="full",
)

REDUCED = ArchConfig(
    name="llama4-scout-reduced",
    family="moe",
    num_layers=2,
    d_model=128,
    d_ff=256,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    rope_theta=500_000.0,
    num_experts=4,
    experts_top_k=1,
    moe_shared_expert=True,
    moe_group_size=64,
    attn_strategy="seq_tp",
)
