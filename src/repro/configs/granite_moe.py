"""Granite-3.0-3B-A800M — fine-grained MoE decoder (40 experts, top-8).

[hf ibm-granite/granite-3.0-3b-a800m-base] 32L d_model=1536 24H (GQA kv=8)
per-expert d_ff=512, vocab=49155, MoE 40e top-8.

24 heads do not divide the model axis -> context-parallel attention.
40 experts do not divide the model axis -> each expert's d_ff (512) is
sharded instead (512/16 = 32 per shard). vocab 49155 is padded to 49408
for even embedding sharding (logits over padding masked).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    d_ff=512,
    vocab_size=49155,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    rope_theta=10_000.0,
    num_experts=40,
    experts_top_k=8,
    attn_strategy="seq_tp",
    remat="full",
)

REDUCED = ArchConfig(
    name="granite-moe-reduced",
    family="moe",
    num_layers=2,
    d_model=128,
    d_ff=64,
    vocab_size=515,               # deliberately non-multiple: exercises padding
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    rope_theta=10_000.0,
    num_experts=8,
    experts_top_k=2,
    moe_group_size=64,
    attn_strategy="seq_tp",
)
