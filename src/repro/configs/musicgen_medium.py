"""MusicGen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf facebook/musicgen-medium] 48L d_model=1536 24H
(GQA kv=24 == MHA) d_ff=6144 vocab=2048. Audio frontend is a stub:
``input_mode="embeddings"`` — input_specs() provides precomputed frame
embeddings (backbone-only per assignment).

24 heads do not divide the model axis (16) -> context-parallel attention
(``attn_strategy="seq_tp"``).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    d_ff=6144,
    vocab_size=2048,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    rope_theta=10_000.0,
    input_mode="embeddings",
    attn_strategy="seq_tp",
    remat="full",
)

REDUCED = ArchConfig(
    name="musicgen-medium-reduced",
    family="audio",
    num_layers=2,
    d_model=128,
    d_ff=256,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    rope_theta=10_000.0,
    input_mode="embeddings",
    attn_strategy="seq_tp",
    remat="full",
)
