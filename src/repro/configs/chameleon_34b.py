"""Chameleon-34B — early-fusion mixed-modal decoder over VQ image tokens.

[arXiv:2405.09818] 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
The VQ-VAE image tokenizer frontend is a stub (``input_mode="embeddings"``):
input_specs() provides precomputed patch/token embeddings per the
backbone-only assignment.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    d_ff=22016,
    vocab_size=65536,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=10_000.0,
    input_mode="embeddings",
    attn_strategy="head_tp",
    fsdp=True,
    remat="full",
)

REDUCED = ArchConfig(
    name="chameleon-34b-reduced",
    family="vlm",
    num_layers=2,
    d_model=128,
    d_ff=344,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    rope_theta=10_000.0,
    input_mode="embeddings",
    attn_strategy="head_tp",
)
