"""Gemma-3-12B — dense decoder with 5:1 local:global attention, 256k vocab.

[hf google/gemma-3-12b-pt] 48L d_model=3840 16H (GQA kv=8) head_dim=256
d_ff=15360 vocab=262144; sliding window 1024 on local layers, pattern
5 local : 1 global.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    d_ff=15360,
    vocab_size=262144,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    rope_theta=1_000_000.0,
    attn_kind="local_global",
    window_size=1024,
    local_per_global=5,
    attn_strategy="head_tp",
    fsdp=True,
    remat="full",
)

REDUCED = ArchConfig(
    name="gemma3-12b-reduced",
    family="dense",
    num_layers=6,                 # one 5:1 super-layer
    d_model=128,
    d_ff=256,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    rope_theta=1_000_000.0,
    attn_kind="local_global",
    window_size=64,
    local_per_global=5,
    attn_strategy="head_tp",
    remat="full",
)
