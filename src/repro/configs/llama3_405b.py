"""Llama-3.1-405B — dense decoder, GQA, 128k vocab.

[arXiv:2407.21783] 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    d_ff=53248,
    vocab_size=128256,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=500_000.0,
    attn_strategy="head_tp",
    fsdp=True,
    remat="full",
)

REDUCED = ArchConfig(
    name="llama3-405b-reduced",
    family="dense",
    num_layers=2,
    d_model=128,
    d_ff=416,
    vocab_size=512,
    num_heads=8,
    num_kv_heads=2,
    head_dim=16,
    rope_theta=500_000.0,
    attn_strategy="head_tp",
)
