"""Architecture/shape registry.

``get_arch(name)`` returns the full published config; ``get_reduced(name)``
returns the CPU-smoke-test variant of the same family. ``ARCH_NAMES`` lists
the 10 assigned architectures (+ the paper's own §8 transformer case study).
"""
from __future__ import annotations

from repro.configs.base import (
    ArchConfig,
    RunConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    applicable_shapes,
)

from repro.configs import (
    musicgen_medium,
    zamba2_1p2b,
    deepseek_67b,
    llama3_405b,
    llama3_8b,
    gemma3_12b,
    llama4_scout,
    granite_moe,
    rwkv6_3b,
    chameleon_34b,
)

# The paper's §8.1 transformer-style FP8 case-study kernel: a small dense
# decoder used by benchmarks/fig14_transformer.py and examples.
PAPER_TRANSFORMER = ArchConfig(
    name="paper-transformer",
    family="dense",
    num_layers=4,
    d_model=512,
    d_ff=2048,
    vocab_size=32000,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    precision="fp8",
    attn_strategy="head_tp",
)

_MODULES = {
    "musicgen-medium": musicgen_medium,
    "zamba2-1.2b": zamba2_1p2b,
    "deepseek-67b": deepseek_67b,
    "llama3-405b": llama3_405b,
    "llama3-8b": llama3_8b,
    "gemma3-12b": gemma3_12b,
    "llama4-scout-17b-a16e": llama4_scout,
    "granite-moe-3b-a800m": granite_moe,
    "rwkv6-3b": rwkv6_3b,
    "chameleon-34b": chameleon_34b,
}

ARCHS = {name: mod.CONFIG for name, mod in _MODULES.items()}
REDUCED = {name: mod.REDUCED for name, mod in _MODULES.items()}
ARCHS["paper-transformer"] = PAPER_TRANSFORMER
REDUCED["paper-transformer"] = PAPER_TRANSFORMER

ARCH_NAMES = tuple(_MODULES.keys())


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}") from None


def get_reduced(name: str) -> ArchConfig:
    try:
        return REDUCED[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REDUCED)}") from None


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}") from None


def all_cells(include_extra: bool = True):
    """Yield every assigned (arch, shape) dry-run cell."""
    for name in ARCH_NAMES:
        arch = ARCHS[name]
        for shape in applicable_shapes(arch):
            yield arch, shape


__all__ = [
    "ArchConfig", "RunConfig", "ShapeConfig", "SHAPES", "ARCHS", "REDUCED",
    "ARCH_NAMES", "PAPER_TRANSFORMER", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "get_arch", "get_reduced", "get_shape", "applicable_shapes",
    "all_cells",
]
