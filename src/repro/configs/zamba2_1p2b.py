"""Zamba2-1.2B — Mamba2 backbone + shared attention block (hybrid).

[arXiv:2411.15242; hf Zyphra/Zamba2-1.2B] 38L d_model=2048, shared attn
32H (kv=32), d_ff=8192, vocab=32000, ssm_state=64.

Modeled as 38 Mamba2 blocks with a parameter-shared attention+MLP block
invoked after every 6 Mamba2 blocks (6 invocations; 38 = 6*6 + 2 tail
blocks). See DESIGN.md §6 for the simplification notes.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    d_ff=8192,
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    ssm_kind="mamba2",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    attn_strategy="head_tp",
    remat="full",
)

REDUCED = ArchConfig(
    name="zamba2-1.2b-reduced",
    family="hybrid",
    num_layers=5,                 # 2*2 + 1 tail
    d_model=128,
    d_ff=256,
    vocab_size=512,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    ssm_kind="mamba2",
    ssm_state=16,
    ssm_head_dim=32,
    ssm_expand=2,
    ssm_chunk=32,
    attn_every=2,
    attn_strategy="head_tp",
)
