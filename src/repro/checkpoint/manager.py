"""Checkpointing: atomic, asynchronous, elastically re-shardable.

Layout per step:
  <dir>/step_<n>.tmp/          — written first
      meta.json                — step, data cursor, pytree structure
      arr_<i>.npy              — one file per leaf (numpy, host-gathered)
  <dir>/step_<n>/              — atomic rename once fully written

Restore re-lays-out every leaf onto the *target* mesh/shardings
(``device_put`` with the new NamedSharding), so a checkpoint written from a
512-chip run restores onto 256 chips and vice versa — elastic scaling.
Saves run on a background thread (training never blocks on disk); the
manager keeps the last ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


# numpy can't round-trip ml_dtypes (bfloat16, fp8) through save/load casts —
# store them as raw byte views plus a dtype tag in meta.json.
_BYTE_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


_ML_DTYPES = {"bfloat16", "float8_e4m3fn", "float8_e5m2", "float8_e4m3",
              "float8_e4m3fnuz", "float8_e5m2fnuz", "float8_e4m3b11_fnuz",
              "int4", "uint4", "float4_e2m1fn", "float8_e8m0fnu"}


def _to_savable(a: np.ndarray):
    if a.dtype.name in _ML_DTYPES:
        return a.view(_BYTE_VIEW[a.dtype.itemsize]), a.dtype.name
    return a, None


def _from_saved(raw: np.ndarray, dtype_tag: Optional[str]):
    if dtype_tag is None:
        return raw
    return raw.view(np.dtype(getattr(ml_dtypes, dtype_tag)))


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             blocking: bool = False):
        """Snapshot ``state`` at ``step``. Device arrays are fetched to host
        synchronously (cheap vs. step time), disk IO happens on a thread."""
        self.wait()                     # one in-flight save at a time
        leaves, treedef = _flatten(state)
        host_leaves = []
        dtype_tags = []
        for l in leaves:
            a, tag = _to_savable(np.asarray(l))
            host_leaves.append(a)
            dtype_tags.append(tag)
        meta = {
            "step": int(step),
            "n_leaves": len(host_leaves),
            "dtype_tags": dtype_tags,
            "treedef": str(treedef),
            "extra": extra or {},
            "time": time.time(),
        }

        def work():
            try:
                tmp = os.path.join(self.dir, f"step_{step}.tmp")
                final = os.path.join(self.dir, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                for i, a in enumerate(host_leaves):
                    np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
                with open(os.path.join(tmp, "meta.json"), "w") as f:
                    json.dump(meta, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)   # atomic commit
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any,
                shardings: Optional[Any] = None):
        """Load ``step`` into the structure of ``target`` (values or
        ShapeDtypeStructs). With ``shardings`` (pytree of NamedSharding,
        same structure), leaves are placed onto the *current* mesh — this is
        the elastic-rescale path."""
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        leaves, treedef = _flatten(target)
        if meta["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {meta['n_leaves']} leaves, target has "
                f"{len(leaves)} — structure mismatch")
        shard_leaves = (_flatten(shardings)[0] if shardings is not None
                        else [None] * len(leaves))
        tags = meta.get("dtype_tags") or [None] * len(leaves)
        out = []
        for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
            a = _from_saved(np.load(os.path.join(path, f"arr_{i}.npy")),
                            tags[i])
            if tuple(a.shape) != tuple(ref.shape):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {a.shape} != target "
                    f"{ref.shape}")
            a = a.astype(ref.dtype)
            out.append(jax.device_put(a, shd) if shd is not None
                       else jax.device_put(a))
        return jax.tree_util.tree_unflatten(treedef, out), meta["extra"]

    def restore_latest(self, target: Any, shardings: Optional[Any] = None):
        step = self.latest_step()
        if step is None:
            return None
        state, extra = self.restore(step, target, shardings)
        return step, state, extra
