"""AdamW with FP32 master weights (bf16 model params) + cosine schedule.

Mixed-precision training contract (FP8-LM / standard TPU recipe):
  model params bf16 → grads bf16/f32 → update in f32 against master copies
  → params recast to bf16. Optimizer state shards exactly like its param
  (ZeRO follows the param specs; see runtime/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    mu: Any                  # f32 pytree
    nu: Any                  # f32 pytree
    master: Any              # f32 pytree (master weights)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0
    # bf16 moments halve optimizer HBM — the distributed-optimization knob
    # for the big archs (llama3-405b fits 512 chips with this on).
    moments_dtype: Any = jnp.float32


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)


def init(params: Any, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moments_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
    )


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def apply(params: Any, grads: Any, state: AdamWState,
          cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu, master):
        g = g.astype(jnp.float32) * clip
        mu1 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu1 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mu1 / b1c
        vhat = nu1 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                              # decay matrices only
            delta = delta + cfg.weight_decay * master
        new_master = master - lr * delta
        return (new_master.astype(p.dtype), mu1.astype(mu.dtype),
                nu1.astype(nu.dtype), new_master)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu, state.master)
    # unzip the 4-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_master = jax.tree.map(lambda t: t[3], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_state = AdamWState(step=step, mu=new_mu, nu=new_nu, master=new_master)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def state_shape(params_shape: Any, cfg: AdamWConfig) -> AdamWState:
    return jax.eval_shape(lambda p: init(p, cfg), params_shape)
