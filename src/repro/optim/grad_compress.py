"""Gradient compression for the slow cross-pod links (DESIGN.md §3.2).

At 1000+ nodes the ``pod`` axis rides data-center interconnect, not ICI.
Two standard tricks, both pjit-compatible (they transform the gradient
pytree *before* the all-reduce that GSPMD emits from the sharding specs):

* ``bf16``     — cast grads to bf16 for the reduction (2× wire bytes).
* ``int8_ef``  — per-tensor symmetric int8 quantization with **error
  feedback**: the quantization residual is carried in the train state and
  added back before the next step's quantization, which keeps SGD unbiased
  in the long run (Seide et al.; 1-bit Adam lineage).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compress_bf16(grads: Any) -> Any:
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def _quant_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_int8_ef(grads: Any, error: Optional[Any]):
    """Returns (quantized_grads_dequantized, new_error).

    The dequantized value is what enters the optimizer; the residual
    (g - dq) is the carried error-feedback state.
    """
    if error is None:
        error = jax.tree.map(jnp.zeros_like, grads)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quant_int8(g32)
        dq = q.astype(jnp.float32) * scale
        return dq.astype(g.dtype), (g32 - dq).astype(jnp.float32)

    out = jax.tree.map(one, grads, error)
    dq = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return dq, new_err


def init_error(params_like: Any) -> Any:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params_like)
