"""Per-architecture sharding policies over the fixed production mesh.

Mesh axes (launch/mesh.py): single-pod ``(data=16, model=16)``, multi-pod
``(pod=2, data=16, model=16)``. Policies (DESIGN.md §3.1):

* batch           → ("pod","data")
* TP (Megatron)   → weight output/input dims on "model" (column/row)
* FSDP (ZeRO-3)   → large weight dims additionally on "data" when cfg.fsdp
* EP              → expert dim on "model" when E % 16 == 0, else per-expert
                    d_ff on "model" (granite)
* decode KV cache → (batch→data, seq→model) "flash-decoding" sharding
* SSM states      → heads (mamba2) / value-dim (rwkv6) on "model"

Every rule only ever shards dims that divide the axis size — checked at
spec-construction time so a bad rule fails loudly before lowering.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % axis_size(mesh, axes) == 0


def _maybe(dim: int, mesh: Mesh, axes):
    """Use ``axes`` for this dim only if it divides evenly."""
    return axes if (axes and _fits(dim, mesh, axes)) else None


# ---------------------------------------------------------------------------
# Parameter shardings
# ---------------------------------------------------------------------------

def _leaf_rule(path: str, shape: Tuple[int, ...], cfg: ArchConfig,
               mesh: Mesh, policy: str = "tp_fsdp") -> P:
    """PartitionSpec for one *unstacked* parameter leaf.

    policy "tp_fsdp" (default): Megatron TP on "model" + optional ZeRO on
    "data". policy "fsdp_only" (§Perf): both axes are storage-sharding; no
    tensor parallelism — batch shards 256-way, weights gather per layer
    (ZeRO-3). Right-sizes small-model training where TP collectives dominate.
    """
    fsdp = "data" if (cfg.fsdp or policy == "fsdp_only") else None
    name = path.split("/")[-1]

    def spec(*axes):
        fixed = tuple(_maybe(shape[i], mesh, ax)
                      for i, ax in enumerate(axes))
        return P(*fixed)

    if name == "embed":                       # (Vp, d)
        return spec("model", fsdp)
    if name == "head":                        # (d, Vp)
        return spec(fsdp, "model")
    if name in ("w_q", "w_k", "w_v", "w_gate", "w_up", "w_ck",
                "w_z", "w_x", "w_B", "w_C", "w_dt",
                "w_r", "w_g", "w_w", "w_cr"):
        if "moe" in path and len(shape) == 3:              # (E, d, f) experts
            if cfg.num_experts and _fits(shape[0], mesh, "model"):
                return spec("model", fsdp, None)           # EP
            return spec(None, fsdp, "model")               # shard per-expert ff
        return spec(fsdp, "model")            # column parallel
    if name in ("w_o", "w_down", "w_cv", "out_proj"):
        if "moe" in path and len(shape) == 3:              # (E, f, d) experts
            if cfg.num_experts and _fits(shape[0], mesh, "model"):
                return spec("model", None, fsdp)
            return spec(None, "model", fsdp)
        return spec("model", fsdp)             # row parallel
    if name == "router":                        # (d, E) — f32, replicated
        return P()
    if name == "conv_w":                        # (4, conv_dim)
        return spec(None, "model")
    # norms, biases, mixing coeffs, A_log, D, u, ... — replicated
    return P()


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(cfg: ArchConfig, mesh: Mesh, params_tree,
                policy: str = "tp_fsdp") -> Any:
    """PartitionSpec pytree mirroring ``params_tree`` (values or shapes)."""

    def rule(path, leaf):
        p = _path_str(path)
        shape = tuple(leaf.shape)
        # scanned stacks carry a leading layer dim
        stacked = p.startswith("layers/") or p.startswith("tail/")
        core_shape = shape[1:] if stacked else shape
        s = _leaf_rule(p, core_shape, cfg, mesh, policy)
        if stacked:
            s = P(None, *s)
        return s

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_tree) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, mesh, params_tree))


# ---------------------------------------------------------------------------
# Activation / batch shardings
# ---------------------------------------------------------------------------

def input_spec(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> P:
    """Spec for the token/embedding input batch."""
    ba = batch_axes(mesh)
    b = shape.global_batch
    baxes = ba if b % axis_size(mesh, ba) == 0 else None
    if cfg.input_mode == "embeddings" and not shape.is_decode:
        return P(baxes, None, None)
    return P(baxes, None)


def logits_spec(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> P:
    ba = batch_axes(mesh)
    b = shape.global_batch
    baxes = ba if b % axis_size(mesh, ba) == 0 else None
    if shape.is_decode:
        return P(baxes, _maybe(cfg.padded_vocab, mesh, "model"))
    return P(baxes, None, _maybe(cfg.padded_vocab, mesh, "model"))


# ---------------------------------------------------------------------------
# Decode cache shardings
# ---------------------------------------------------------------------------

def cache_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                cache_tree) -> Any:
    """Specs for the decode cache pytree (stacked on n_super/n_tail)."""
    ba = batch_axes(mesh)
    b = shape.global_batch
    baxes = ba if b % axis_size(mesh, ba) == 0 else None
    # when batch can't shard (long_500k b=1), put cache seq on data+model
    seq_axes = "model" if baxes else ("data", "model")

    def rule(path, leaf):
        p = _path_str(path)
        name = p.split("/")[-1]
        shp = tuple(leaf.shape)[1:]          # drop layer-stack dim
        if name in ("k", "v"):               # (B, S, kv, hd)
            sx = _maybe(shp[1], mesh, seq_axes)
            return P(None, baxes, sx, None, None)
        if name == "pos":                    # (B, S)
            return P(None, baxes, _maybe(shp[1], mesh, seq_axes))
        if name == "h":                      # mamba2 (B, nh, hp, N)
            return P(None, baxes, _maybe(shp[1], mesh, "model"), None, None)
        if name == "conv":                   # (B, 3, conv_dim)
            return P(None, baxes, None, _maybe(shp[2], mesh, "model"))
        if name == "S":                      # rwkv6 (B, nh, hd, hd)
            return P(None, baxes, None, None, _maybe(shp[3], mesh, "model"))
        if name in ("prev_tm", "prev_cm"):   # (B, 1, d)
            return P(None, baxes, None, None)
        return P(None)
    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def cache_shardings(cfg, shape, mesh, cache_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(cfg, shape, mesh, cache_tree))


# ---------------------------------------------------------------------------
# Activation constraint hook (RuntimeCfg.shard_fn)
# ---------------------------------------------------------------------------

def make_shard_fn(cfg: ArchConfig, mesh: Mesh, shape: ShapeConfig,
                  seq_shard_acts: bool = True, decode_2d_tp: bool = False,
                  policy: str = "tp_fsdp"):
    """Returns shard_fn(tag, x) applying with_sharding_constraint by tag.

    ``seq_shard_acts`` shards the residual stream's seq dim on "model"
    between layers (Megatron-SP): activation stacks shrink 16× — required
    to fit the 16 GiB/chip HBM budget for the train cells.

    ``decode_2d_tp`` (§Perf): decode activations replicate the batch and
    shard d on "data" instead — every matmul contracts against its locally
    resident 2-D weight shard and psums small activations, replacing the
    per-layer FSDP weight all-gathers (the decode collective bottleneck).
    """
    ba = batch_axes(mesh)
    model_free = True                        # "model" usable for non-batch dims
    if policy == "fsdp_only":
        ba = ba + ("model",)                 # batch over every axis
        model_free = False
        seq_shard_acts = False               # no model axis left for seq
    b = shape.global_batch
    baxes = ba if b % axis_size(mesh, ba) == 0 else None
    seq_model = cfg.attn_strategy == "seq_tp"

    def fn(tag: str, x):
        if tag == "act_btd":                 # residual stream (B, S, d)
            if shape.is_decode and decode_2d_tp:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(
                        mesh, P(None, None, _maybe(x.shape[2], mesh, "data"))))
            sx = None
            if (seq_shard_acts and not shape.is_decode
                    and x.shape[1] % axis_size(mesh, "model") == 0):
                sx = "model"
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(baxes, sx, None)))
        if tag == "attn_q":                  # (B, S, h, hd)
            if not model_free:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(baxes, None, None, None)))
            if seq_model and x.shape[1] % axis_size(mesh, "model") == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(baxes, "model", None, None)))
            if x.shape[2] % axis_size(mesh, "model") == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(baxes, None, "model", None)))
            return x
        if tag == "decode_q":                # (B, 1, h, hd) single-token q
            if decode_2d_tp:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(None, None, None, None)))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(baxes, None, None, None)))
        if tag == "rwkv_v":                  # (B, S, nh, hd) — value-dim
            vx = "model" if model_free else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(baxes, None, None, vx)))
        if tag == "moe_tokens":              # (G, gs, d) — token groups
            all_ax = ba if not model_free else (
                (ba + ("model",)) if baxes else ("model",))
            gax = _maybe(x.shape[0], mesh, all_ax)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(gax, None, None)))
        if tag == "moe_dispatch":            # (G, E, C, d) — expert layout
            if model_free and x.shape[1] % axis_size(mesh, "model") == 0:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, P(baxes, "model", None, None)))
            all_ax = ba if not model_free else (
                (ba + ("model",)) if baxes else ("model",))
            gax = _maybe(x.shape[0], mesh, all_ax)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(gax, None, None, None)))
        return x
    return fn
