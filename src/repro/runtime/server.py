"""The serving control plane: one ``ServingRuntime`` behind one spec.

Three overlapping serving entry points accreted across PRs — the
:class:`~repro.runtime.serve_loop.ServeSession` slot API, the
:class:`~repro.runtime.scheduler.StreamScheduler` tenant loop, and the
:class:`~repro.runtime.partition.PartitionedServer` sub-mesh router. The
paper's core finding is that the right execution decision is *context
dependent* (FP8 above the occupancy knee §5, bounded concurrency §6, 2:4
under memory-bound multi-tenancy §7), and the Infinity-Fabric placement
study plus AsyncSparse (PAPERS.md) both argue the serving layer needs a
control plane that can SPECIALIZE partitions and MOVE tenants — not a
static router with one ambient policy. This module is that control plane:

* :class:`ServingSpec` — a declarative, JSON-serializable description of
  the whole runtime: partitions (each with its own
  :class:`~repro.core.execution.ExecutionPolicy`, admission and quota
  policy), tenant placement, slot geometry, and the live-migration
  policy. One spec, one runtime; the legacy classes are internal
  components behind it.
* :class:`ServingRuntime` — the single facade: ``add_tenant`` /
  ``submit`` / ``step`` / ``drain`` / ``report``. Partitions step in
  LOCKSTEP (one global step domain), so per-request step accounting —
  and therefore fairness/turnaround — stays exact even when a request
  crosses partitions mid-flight.
* **Live tenant migration** — the ``load_aware`` re-route path: when a
  partition's decode-EMA-weighted outstanding work diverges past
  ``MigrationSpec.threshold`` × the least-loaded partition, one tenant is
  drained (frozen on the source: in-flight requests keep decoding, no
  new admissions) and moved: queued requests transfer immediately,
  in-flight requests hand their per-slot KV/SSM cache state to the
  target partition as slots free up
  (:meth:`~repro.runtime.serve_loop.ServeSession.export_slot` /
  ``import_slot``). Greedy decode is bit-exact across the move; the
  per-partition tracers record ``migrate`` events (start / handoff /
  done) so the fused accounting keeps full provenance.

Live handoff requires the two partitions to run *execution-compatible*
policies (same resolved policy spec): a request's arithmetic cannot
change mid-stream. Queued (not yet admitted) requests may migrate across
heterogeneous policies freely — they simply execute under the target's
policy.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core import concurrency as cc
from repro.core import execution as ex
from repro.core.speculative import SpecDecodeSpec
from repro.runtime import telemetry
from repro.runtime.controller import ControllerSpec, SLOController
from repro.runtime.scheduler import (
    ADMISSION_POLICIES, QuotaPolicy, SLO, SchedulerReport, StreamScheduler,
    Tenant, TenantReport, build_tenant_report, request_cost)
from repro.runtime.serve_loop import Request, ServeSession, export_nbytes

PLACEMENTS = ("packed", "spread", "load_aware")


# ---------------------------------------------------------------------------
# Device partitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DevicePartition:
    """One spatial partition: a disjoint device subset (possibly shared
    with other partitions only in the single-device logical fallback)."""
    index: int
    devices: tuple = ()
    logical: bool = False            # True: single-device fallback

    @property
    def label(self) -> str:
        kind = "logical" if self.logical else "devices"
        return f"partition{self.index}({kind}:{len(self.devices)})"


def make_partitions(n: int, devices: Optional[Sequence] = None
                    ) -> List[DevicePartition]:
    """Split the attached devices into ``n`` disjoint partitions.

    With at least ``n`` devices each partition gets ``len(devices)//n`` of
    them (remainder devices go to the leading partitions, mirroring
    ``run_spatial``'s subset semantics). With fewer — the CPU CI case —
    every partition is *logical*: it references the same device set but
    the serving state (session, scheduler, tracer) is fully per-partition,
    which is what the behavioral contracts test."""
    if n <= 0:
        raise ValueError("need at least one partition")
    if devices is None:
        import jax
        try:
            devices = tuple(jax.devices())
        except Exception:  # noqa: BLE001 — no backend: logical partitions
            devices = ()
    devices = tuple(devices)
    if len(devices) < n:
        return [DevicePartition(index=i, devices=devices, logical=True)
                for i in range(n)]
    per, extra = divmod(len(devices), n)
    parts, at = [], 0
    for i in range(n):
        take = per + (1 if i < extra else 0)
        parts.append(DevicePartition(index=i,
                                     devices=devices[at:at + take]))
        at += take
    return parts


# ---------------------------------------------------------------------------
# The declarative spec
# ---------------------------------------------------------------------------

def _policy_str(policy) -> Optional[str]:
    if policy is None or isinstance(policy, str):
        return policy
    if isinstance(policy, ex.ExecutionPolicy):
        return policy.full_spec()
    raise TypeError(f"policy {policy!r} is not None/str/ExecutionPolicy")


def _spec_dict(speculative) -> Optional[Dict[str, Any]]:
    spec = SpecDecodeSpec.from_any(speculative)
    return spec.to_dict() if spec is not None else None


def _controller_dict(controller) -> Optional[Dict[str, Any]]:
    spec = ControllerSpec.from_any(controller)
    return spec.to_dict() if spec is not None else None


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """One partition's declarative config. ``policy`` is an execution-
    policy spec string (``"fp8:sparse24:jnp"``), ``"auto"`` (resolve via
    the occupancy advisor at session setup), an
    :class:`~repro.core.execution.ExecutionPolicy` instance
    (programmatic use), or ``None`` — inherit the runtime-wide default.
    ``batch_slots`` overrides the spec-wide slot count for this
    partition."""
    policy: Any = None
    admission: str = "fair_quantum"
    quota: Optional[str] = None      # None | "static" | "adaptive"
    batch_slots: Optional[int] = None
    # Paged-cache overrides (None = inherit the spec-wide setting). NOTE:
    # migration can only hand slots between partitions with the SAME cache
    # layout (paged-ness and page_size).
    paged: Optional[bool] = None
    page_size: Optional[int] = None
    pages: Optional[int] = None
    # Speculative decoding override (core/speculative.SpecDecodeSpec as an
    # int k / dict / instance; None = inherit the spec-wide setting).
    # Deliberately EXCLUDED from policy_key(): the committed cache is
    # bit-identical with or without speculation, so live migration between
    # partitions with different speculative settings stays legal — there
    # is no draft state to carry, the target simply re-drafts.
    speculative: Any = None

    def __post_init__(self):
        if self.admission not in ADMISSION_POLICIES:
            raise ValueError(f"admission {self.admission!r} not in "
                             f"{ADMISSION_POLICIES}")
        if self.quota not in (None, "static", "adaptive"):
            raise ValueError(f"quota {self.quota!r} not in "
                             "(None, 'static', 'adaptive')")
        if self.batch_slots is not None and self.batch_slots <= 0:
            raise ValueError("batch_slots must be positive")
        if self.page_size is not None and self.page_size <= 0:
            raise ValueError("page_size must be positive")
        if self.pages is not None and self.pages <= 0:
            raise ValueError("pages must be positive")
        SpecDecodeSpec.from_any(self.speculative)   # validate now

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["policy"] = _policy_str(self.policy)
        d["speculative"] = _spec_dict(self.speculative)
        return d


@dataclasses.dataclass(frozen=True)
class MigrationSpec:
    """The live-migration policy (the ``load_aware`` re-route path).

    Every ``interval`` steps (and at least ``cooldown`` steps after the
    previous migration) the runtime compares per-partition loads — the
    decode-EMA-weighted outstanding work — and when the busiest exceeds
    ``threshold`` × the least-loaded, one tenant is migrated. At most
    ``max_migrations`` over the runtime's lifetime (an oscillation
    backstop)."""
    enabled: bool = False
    interval: int = 8
    threshold: float = 2.0
    cooldown: int = 16
    max_migrations: int = 8

    def __post_init__(self):
        if self.interval <= 0 or self.cooldown < 0:
            raise ValueError("interval must be positive, cooldown >= 0")
        if self.threshold <= 1.0:
            raise ValueError("threshold must exceed 1.0 (a ratio)")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """A declaratively pre-registered tenant (optional — tenants can also
    be added at runtime via :meth:`ServingRuntime.add_tenant`).

    ``slo`` is an optional service-level objective — an
    :class:`~repro.runtime.scheduler.SLO`, a spec string
    (``"latency:8"``, ``"latency:0.05@wall_s"``, ``"throughput:2.5"``,
    ``"batch:0.9"``), or a dict — whose attainment ratio the reports and
    the metrics plane surface per tenant."""
    id: str
    weight: float = 1.0
    partition: Optional[int] = None  # None: router-placed
    slo: Any = None                  # None | str | dict | SLO

    def __post_init__(self):
        object.__setattr__(self, "slo", SLO.parse(self.slo))

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["slo"] = self.slo.spec() if self.slo is not None else None
        return d


@dataclasses.dataclass(frozen=True)
class ServingSpec:
    """The whole serving runtime, declaratively. JSON-serializable
    (``launch/serve.py --spec``); the legacy flag cluster builds one of
    these."""
    partitions: Tuple[PartitionSpec, ...] = (PartitionSpec(),)
    placement: str = "load_aware"
    batch_slots: int = 4
    max_len: int = 128
    temperature: float = 0.0
    seed: int = 0
    policy: Any = None               # runtime-wide default partition policy
    migration: MigrationSpec = dataclasses.field(
        default_factory=MigrationSpec)
    tenants: Tuple[TenantSpec, ...] = ()
    # Paged serving cache (core/paging.py): per-slot page tables over a
    # shared pool instead of dense (slots × max_len) buffers. ``pages``
    # None sizes the pool to dense-equivalent capacity.
    paged: bool = False
    page_size: int = 16
    pages: Optional[int] = None
    # Speculative multi-token decoding (core/speculative.SpecDecodeSpec as
    # an int k / dict / instance; None = off). Greedy-only — a spec with
    # temperature > 0 and speculation refuses at construction. Partitions
    # override via PartitionSpec.speculative.
    speculative: Any = None
    # Lane overlap: when True (and >1 partition), the runtime co-dispatches
    # partitions the OverlapPlanner pairs from measured decode latencies
    # instead of stepping them through a serial Python loop. Token streams
    # are identical either way; only wall-clock overlap changes. Partitions
    # whose policy says ``no_overlap`` stay serial individually.
    overlap: bool = True
    # Metrics plane (runtime/metrics.py): when True the runtime builds a
    # MetricsRegistry and attaches a MetricsSink to every partition
    # tracer; the registry is reachable as ``runtime.metrics`` and every
    # ``report()`` folds SLO attainment / fairness / occupancy gauges in.
    metrics: bool = False
    # SLO closed loop (runtime/controller.ControllerSpec as None / bool /
    # dict / instance). When set, the runtime runs an SLOController every
    # ``interval`` global steps that freezes batch-class tenants and
    # boosts slot caps while a latency-class tenant misses its SLO.
    # None (the default) is byte-identical to the pre-controller runtime.
    controller: Any = None

    def __post_init__(self):
        if not self.partitions:
            raise ValueError("spec needs at least one partition")
        if self.placement not in PLACEMENTS:
            raise ValueError(f"placement {self.placement!r} not in "
                             f"{PLACEMENTS}")
        if self.batch_slots <= 0 or self.max_len <= 1:
            raise ValueError("batch_slots must be positive, max_len > 1")
        if self.page_size <= 0:
            raise ValueError("page_size must be positive")
        if self.pages is not None and self.pages <= 0:
            raise ValueError("pages must be positive")
        for p in (self,) + self.partitions:
            on = self.paged if p is self or p.paged is None else p.paged
            ps = p.page_size if p.page_size is not None else self.page_size
            if on and self.max_len % ps:
                raise ValueError(f"max_len={self.max_len} must be a "
                                 f"multiple of page_size={ps}")
            sv = self.speculative if p is self or p.speculative is None \
                else p.speculative
            if SpecDecodeSpec.from_any(sv) is not None \
                    and self.temperature > 0:
                raise ValueError(
                    "speculative decoding is greedy-only: a spec with "
                    f"temperature={self.temperature} cannot enable "
                    "speculation (drop the speculative field or set "
                    "temperature=0)")
        ControllerSpec.from_any(self.controller)   # validate now
        ids = [t.id for t in self.tenants]
        if len(set(ids)) != len(ids):
            raise ValueError("duplicate tenant ids in spec")
        for t in self.tenants:
            if t.partition is not None \
                    and not 0 <= t.partition < len(self.partitions):
                raise ValueError(f"tenant {t.id!r} pinned to partition "
                                 f"{t.partition} of {len(self.partitions)}")

    @property
    def n_partitions(self) -> int:
        return len(self.partitions)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "partitions": [p.to_dict() for p in self.partitions],
            "placement": self.placement,
            "batch_slots": self.batch_slots,
            "max_len": self.max_len,
            "temperature": self.temperature,
            "seed": self.seed,
            "policy": _policy_str(self.policy),
            "migration": self.migration.to_dict(),
            "tenants": [t.to_dict() for t in self.tenants],
            "paged": self.paged,
            "page_size": self.page_size,
            "pages": self.pages,
            "speculative": _spec_dict(self.speculative),
            "overlap": self.overlap,
            "metrics": self.metrics,
            "controller": _controller_dict(self.controller),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ServingSpec":
        d = dict(d)
        parts = d.get("partitions", 1)
        if isinstance(parts, int):           # shorthand: N default partitions
            parts = [{} for _ in range(parts)]
        d["partitions"] = tuple(
            p if isinstance(p, PartitionSpec) else PartitionSpec(**p)
            for p in parts)
        mig = d.get("migration", MigrationSpec())
        if isinstance(mig, dict):
            mig = MigrationSpec(**mig)
        d["migration"] = mig
        d["tenants"] = tuple(
            t if isinstance(t, TenantSpec) else TenantSpec(**t)
            for t in d.get("tenants", ()))
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ServingSpec fields: {sorted(unknown)}")
        return cls(**d)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ServingSpec":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "ServingSpec":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MigrationRecord:
    """One tenant move, start to drain completion."""
    tenant: str
    src: int
    dst: int
    start_step: int
    reason: str = "manual"
    queued_moved: int = 0
    slots_handed_off: int = 0
    done_step: int = -1              # -1: still draining

    @property
    def done(self) -> bool:
        return self.done_step >= 0

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class PartitionedReport:
    """One fused view over all partitions.

    ``fairness``/``cv`` are the paper indices over *every* tenant with
    demand — a tenant that submitted requests but never completed any
    (starved) contributes its elapsed wait as a turnaround lower bound
    instead of silently vanishing from the denominator, and a registered
    tenant that never submitted still appears in ``tenants`` (zeros).
    ``steps`` is the runtime's global lockstep step count, ``tokens_out``
    the sum over partitions."""
    placement: str
    admission: str
    quota: str
    n_partitions: int
    n_tenants: int
    steps: int
    wall_s: float
    tokens_out: int
    fairness: float
    cv: float
    tenant_partition: Dict[str, int]
    partitions: List[SchedulerReport]
    tenants: List[TenantReport] = dataclasses.field(default_factory=list)
    migrations: int = 0
    policies: List[str] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        lines = [
            f"[partitioned] {self.n_partitions} partitions "
            f"({self.placement}), {self.admission}/{self.quota}: "
            f"{self.n_tenants} tenants, {self.steps} steps, "
            f"{self.tokens_out} tokens in {self.wall_s:.2f}s | "
            f"fairness={self.fairness:.3f} cv={self.cv:.3f}"]
        if self.migrations:
            lines.append(f"  migrations: {self.migrations}")
        if any(self.policies):
            lines.append("  policies: " + " ".join(
                f"p{i}:{p or 'ambient'}"
                for i, p in enumerate(self.policies)))
        for t in self.tenants:
            extra = f" (migrated x{t.migrations})" if t.migrations else ""
            if t.slo:
                att = "n/a" if t.slo_attainment is None \
                    else f"{t.slo_attainment:.2f}"
                extra += f" slo[{t.slo}]={att}"
            lines.append(
                f"  {t.tenant_id}@p{t.partition}: {t.completed}/"
                f"{t.submitted} done, {t.tokens_out} tok, "
                f"turnaround={t.mean_turnaround_steps:.1f} steps{extra}")
        for rep in self.partitions:
            for line in rep.summary().splitlines():
                lines.append("  " + line)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------

class ServingRuntime:
    """Every partition, one facade, one step domain.

    One :class:`ServeSession` + :class:`StreamScheduler` + partition-
    tagged :class:`~repro.runtime.telemetry.Tracer` per partition, all
    built from one :class:`ServingSpec`. Partitions step in lockstep —
    ``step()`` advances every scheduler exactly once — so request step
    accounting lives in a single global domain and stays exact across
    live migrations.

    Every partition's session is built from the same params/config/seed,
    so under greedy decoding a tenant's token stream is independent of
    *which* partition serves it and of who shares the node — including
    across a live migration between execution-compatible partitions
    (tested token-for-token).

    ``policy=`` / ``quota=`` are legacy programmatic overrides (uniform
    policy object, quota instance or per-partition sequence) used by the
    deprecated facades; new callers put policies in the spec."""

    def __init__(self, params, cfg, spec: Union[None, ServingSpec, Dict]
                 = None, *, rt=None, policy=None,
                 quota: Union[None, str, QuotaPolicy, Sequence] = None,
                 partitions: Optional[Sequence[DevicePartition]] = None,
                 tracer_capacity: int = 4096, session_kw=None):
        if spec is None:
            spec = ServingSpec()
        elif isinstance(spec, dict):
            spec = ServingSpec.from_dict(spec)
        self.spec = spec
        self.cfg = cfg
        self.placement = spec.placement
        self.batch_slots = spec.batch_slots
        self.partitions = list(partitions) if partitions is not None \
            else make_partitions(spec.n_partitions)
        if len(self.partitions) != spec.n_partitions:
            raise ValueError(
                f"{len(self.partitions)} device partitions for "
                f"{spec.n_partitions} partition specs")
        self._validate_quota_override(quota)

        resolved = [self._resolve_policy(ps.policy, policy or spec.policy)
                    for ps in spec.partitions]
        # prune+pack the shared weights ONCE for every sparse24 partition;
        # each session's own pack pass then finds only PackedWeight leaves
        # (no-op walk) instead of re-packing the full model per partition
        packed_params = None
        if any(isinstance(p, ex.ExecutionPolicy) and p.sparsity == "sparse24"
               for p in resolved):
            packed_params = ex.pack_model_params(params)

        self.tracers: List[telemetry.Tracer] = []
        self.sessions: List[ServeSession] = []
        self.schedulers: List[StreamScheduler] = []
        self.tenant_partition: Dict[str, int] = {}
        self._tenant_order: List[str] = []
        self.step_count = 0
        self.migrations: List[MigrationRecord] = []
        self._draining: Dict[str, MigrationRecord] = {}
        self._migrated_counts: Dict[str, int] = {}
        self._last_migration_step = -(10 ** 9)

        kw = dict(session_kw or {})
        if rt is not None:
            kw["rt"] = rt
        for i, (part, pspec) in enumerate(zip(self.partitions,
                                              spec.partitions)):
            pol = resolved[i]
            use_params = packed_params if (
                isinstance(pol, ex.ExecutionPolicy)
                and pol.sparsity == "sparse24") else params
            tr = telemetry.Tracer(capacity=tracer_capacity,
                                  partition=part.index)
            p_paged = spec.paged if pspec.paged is None else pspec.paged
            p_psize = pspec.page_size if pspec.page_size is not None \
                else spec.page_size
            p_pages = pspec.pages if pspec.pages is not None else spec.pages
            p_spec = spec.speculative if pspec.speculative is None \
                else pspec.speculative
            sess = ServeSession(
                self._place_params(use_params, part), cfg,
                batch_slots=pspec.batch_slots or spec.batch_slots,
                max_len=spec.max_len, temperature=spec.temperature,
                seed=spec.seed, policy=pol, telemetry=tr,
                paged=p_paged, page_size=p_psize, pages=p_pages,
                speculative=p_spec, **kw)
            sched = StreamScheduler(
                sess, admission=pspec.admission, tracer=tr,
                quota=self._quota_for(quota, pspec, i))
            self.tracers.append(tr)
            self.sessions.append(sess)
            self.schedulers.append(sched)
        # one dispatch lane per partition — the ACE-queue analogue the
        # overlap step routes through — plus the planner that pairs them
        # from measured decode EMAs (core/execution.OverlapPlanner)
        self.lanes = [cc.ExecutionLane(f"lane{i}", index=i)
                      for i in range(len(self.sessions))]
        self.planner = ex.OverlapPlanner()
        self._next_overlap_group = 0
        # Metrics plane: one registry + one sink over every partition
        # tracer (events carry partition tags, so one sink suffices).
        self.metrics = None
        self.metrics_sink = None
        if spec.metrics:
            from repro.runtime.metrics import MetricsRegistry, MetricsSink
            self.metrics = MetricsRegistry()
            self.metrics_sink = MetricsSink(self.metrics).attach(
                *self.tracers)
        # SLO closed loop (runtime/controller.py): acts on attainment
        # every ``interval`` steps. None → byte-identical legacy behavior.
        cspec = ControllerSpec.from_any(spec.controller)
        self.controller = (SLOController(cspec)
                           if cspec is not None and cspec.enabled else None)
        for tspec in spec.tenants:
            self.add_tenant(tspec.id, weight=tspec.weight,
                            partition=tspec.partition, slo=tspec.slo)

    # -- construction helpers -----------------------------------------------
    @staticmethod
    def _resolve_policy(policy, default):
        pol = policy if policy is not None else default
        if pol is None or pol == "auto" \
                or isinstance(pol, ex.ExecutionPolicy):
            return pol
        if isinstance(pol, str):
            return ex.parse_policy(pol)
        raise TypeError(f"policy {pol!r} is not None/'auto'/spec-string/"
                        "ExecutionPolicy")

    def _validate_quota_override(self, quota) -> None:
        n = len(self.partitions)
        if isinstance(quota, (list, tuple)):
            if len(quota) != n:
                raise ValueError(f"quota sequence has {len(quota)} entries "
                                 f"for {n} partitions")
            # string/None specs are instantiated fresh per partition and
            # may repeat; only *instances* carry per-scheduler state
            insts = [q for q in quota if isinstance(q, QuotaPolicy)]
            if len(set(map(id, insts))) != len(insts):
                raise ValueError(
                    "the quota sequence repeats a QuotaPolicy instance "
                    "across partitions; online policies keep per-scheduler "
                    "state — pass one instance per partition")
        elif isinstance(quota, QuotaPolicy) and n > 1:
            raise ValueError(
                "a single QuotaPolicy instance cannot be shared across "
                "partitions (it keeps per-scheduler state); pass a string "
                "spec or one instance per partition")

    @staticmethod
    def _quota_for(quota, pspec: PartitionSpec, index: int):
        """Per-partition quota: the legacy override wins (sequence
        indexed, uniform spec repeated), else the partition spec's."""
        if isinstance(quota, (list, tuple)):
            return quota[index]
        if quota is not None:
            return quota
        return pspec.quota

    @staticmethod
    def _place_params(params, part: DevicePartition):
        """Pin the model replica to the partition's lead device. Logical
        partitions (single-device fallback) share the original params —
        duplicating them would only waste the one device's memory."""
        if part.logical or not part.devices:
            return params
        import jax
        return jax.device_put(params, part.devices[0])

    def policy_key(self, i: int) -> str:
        """The partition's *resolved* execution-policy identity — live
        handoff is allowed only between partitions with equal keys."""
        pol = self.sessions[i].policy
        return pol.full_spec() if isinstance(pol, ex.ExecutionPolicy) else ""

    # -- routing ------------------------------------------------------------
    @property
    def n_partitions(self) -> int:
        return len(self.partitions)

    def _load(self, i: int) -> float:
        """Observed load of partition ``i``: registered tenant weight plus
        the tracer's measured decode signal (mean decode wall × outstanding
        work). Zero-traffic partitions score by weight alone. (Placement-
        time signal; the migration loop uses :meth:`_partition_work`.)"""
        sched = self.schedulers[i]
        weight = sum(t.weight for t in sched.tenants.values())
        backlog = sched.pending() + sched.session.n_active
        return weight + self.tracers[i].mean_wall("decode") * backlog

    def _route(self, weight: float) -> int:
        if self.placement == "packed":
            # first partition whose registered tenancy has not yet filled
            # its slot budget; once every budget is full, overflow goes to
            # the least-populated partition (ties to the lowest index)
            for i, sched in enumerate(self.schedulers):
                if len(sched.tenants) < self.sessions[i].batch_slots:
                    return i
            return min(range(self.n_partitions),
                       key=lambda i: (len(self.schedulers[i].tenants), i))
        if self.placement == "spread":
            return min(range(self.n_partitions),
                       key=lambda i: (sum(t.weight for t in
                                          self.schedulers[i]
                                          .tenants.values()), i))
        # load_aware: least measured load, ties by index
        return min(range(self.n_partitions),
                   key=lambda i: (self._load(i), i))

    def add_tenant(self, tenant_id: str, *, weight: float = 1.0,
                   policy=None, partition: Optional[int] = None,
                   slo=None) -> int:
        """Register a tenant on a partition (router-chosen unless
        ``partition`` pins one). Unlike the PR 4 router, registration is
        no longer forever: the migration loop may re-route the tenant
        later. ``slo`` is an optional SLO class (spec string / dict /
        :class:`~repro.runtime.scheduler.SLO`). Returns the partition
        index."""
        if tenant_id in self.tenant_partition:
            raise ValueError(f"tenant {tenant_id!r} already registered")
        idx = self._route(weight) if partition is None else partition
        self.schedulers[idx].add_tenant(tenant_id, weight=weight,
                                        policy=policy, slo=slo)
        self.tenant_partition[tenant_id] = idx
        self._tenant_order.append(tenant_id)
        self.tracers[idx].record("route", tenant=tenant_id,
                                 meta={"weight": weight,
                                       "placement": self.placement})
        return idx

    # -- the facade ----------------------------------------------------------
    def submit(self, tenant_id: str, req: Request) -> None:
        """Queue a request on the tenant's CURRENT partition (follows the
        tenant across migrations)."""
        self.schedulers[self.tenant_partition[tenant_id]].submit(
            tenant_id, req)

    def pending(self) -> int:
        return sum(s.pending() for s in self.schedulers)

    @property
    def n_active(self) -> int:
        return sum(s.session.n_active for s in self.schedulers)

    def step(self) -> List[Request]:
        """One lockstep round: EVERY partition advances one scheduler
        step (idle partitions tick too — one global step domain is what
        keeps turnaround accounting exact across migrations), then the
        migration loop hands off draining tenants and re-checks partition
        loads. Returns all requests completed this round.

        With ``spec.overlap`` (and >1 partition) the round goes through
        :meth:`_step_lanes`: planner-paired partitions dispatch through
        their lanes before any join, so heterogeneous partitions genuinely
        execute concurrently. Per-partition state transitions are
        identical either way — only wall-clock overlap differs."""
        if self.spec.overlap and self.n_partitions > 1:
            done = self._step_lanes()
        else:
            done = []
            for sched in self.schedulers:
                done.extend(sched.step())
        self.step_count += 1
        self._advance_migrations()
        if self.spec.migration.enabled:
            self._maybe_migrate()
        if self.controller is not None:
            self.controller.on_step(self)
        return done

    def _overlap_candidates(self) -> List[ex.OverlapCandidate]:
        """One candidate per partition: its policy's sparsity and overlap
        gate, plus the measured decode-latency EMA for its dominant decode
        shape (the key ``join_decode`` records under). A partition without
        a measurement stays serial this round — measure first, overlap
        second."""
        cands = []
        for i, sess in enumerate(self.sessions):
            pol = sess.policy if isinstance(sess.policy, ex.ExecutionPolicy) \
                else None
            shape = (sess.batch_slots, sess.cfg.d_model, sess.cfg.d_ff,
                     sess.cfg.precision)
            cands.append(self.planner.candidate(
                i, sparsity=pol.sparsity if pol is not None else "dense",
                shape=shape, tracer=self.tracers[i],
                allowed=pol.overlap if pol is not None else True))
        return cands

    def _step_lanes(self) -> List[Request]:
        """One planner-scheduled round: every paired partition dispatches
        through its lane before *any* of them joins — the widest overlap
        window the plan allows, so one partition's host work (admission,
        prefill dispatch, token accounting) hides under another's in-flight
        decode. Serial partitions then step synchronously. Each group's
        pairing decision is recorded as an ``overlap`` event on every
        member's tracer so the choice is attributable after the fact."""
        plan = self.planner.plan(self._overlap_candidates())
        done: List[Request] = []
        tickets = []
        for group in plan.groups:
            gid = self._next_overlap_group
            self._next_overlap_group += 1
            for i in group:
                tickets.append((i, group, gid, self.schedulers[i]
                                .dispatch_step(self.lanes[i],
                                               overlap_group=gid)))
        for i, group, gid, ticket in tickets:
            done.extend(self.schedulers[i].join_step(ticket))
            self.tracers[i].record(
                "overlap", lane=self.lanes[i].name, overlap_group=gid,
                step=self.step_count,
                meta={"group": [int(g) for g in group]})
        for i in plan.serial:
            done.extend(self.schedulers[i].step())
        return done

    def drain(self, max_steps: int = 100_000) -> List[Request]:
        """Run until every queue is empty, every slot is free, and every
        migration has completed (or ``max_steps``). Returns every
        completed request."""
        steps = 0
        while (self.pending() or self.n_active or self._draining) \
                and steps < max_steps:
            self.step()
            steps += 1
        return [r for sched in self.schedulers
                for t in sched.tenants.values() for r in t.completed]

    # -- live migration -------------------------------------------------------
    def _partition_work(self, i: int) -> float:
        """Deterministic outstanding work on partition ``i`` in token
        positions: queued request costs plus the remaining decode budget
        of every active slot."""
        w = float(sum(request_cost(r) for t in
                      self.schedulers[i].tenants.values() for r in t.queue))
        for r in self.sessions[i].slots:
            if r is not None:
                w += max(0, r.max_new - len(r.out))
        return w

    def _tenant_work(self, i: int, tenant_id: str) -> float:
        t = self.schedulers[i].tenants[tenant_id]
        w = float(sum(request_cost(r) for r in t.queue))
        for r in self.sessions[i].slots:
            if r is not None and r.tenant == tenant_id:
                w += max(0, r.max_new - len(r.out))
        return w

    def _loads(self) -> List[float]:
        """Per-partition migration signal: outstanding work weighted by
        the measured decode-wall EMA. The EMA factor applies only once
        every partition has a measurement (comparisons must stay in one
        domain); until then the signal is pure step-domain work — which
        also keeps the re-route decision deterministic in tests."""
        works = [self._partition_work(i) for i in range(self.n_partitions)]
        emas = [self.tracers[i].mean_wall("decode")
                for i in range(self.n_partitions)]
        if all(e > 0 for e in emas):
            return [w * e for w, e in zip(works, emas)]
        return works

    def _maybe_migrate(self) -> None:
        mig = self.spec.migration
        if self._draining or self.n_partitions < 2:
            return
        if len(self.migrations) >= mig.max_migrations:
            return
        if self.step_count % mig.interval:
            return
        if self.step_count - self._last_migration_step < mig.cooldown:
            return
        loads = self._loads()
        src = max(range(self.n_partitions), key=lambda i: (loads[i], -i))
        if loads[src] <= 0:
            return
        works = [self._partition_work(i) for i in range(self.n_partitions)]
        for dst in sorted(range(self.n_partitions),
                          key=lambda i: (loads[i], i)):
            if dst == src:
                continue
            if loads[src] < mig.threshold * max(loads[dst], 1e-9):
                break                 # ascending: no further dst can pass
            victim = self._pick_victim(src, dst, works)
            if victim is not None:
                self.migrate(victim, dst, reason="load_aware")
                return

    def _pick_victim(self, src: int, dst: int,
                     works: List[float]) -> Optional[str]:
        """The tenant whose move best equalizes the two partitions'
        outstanding work — and strictly improves it (no oscillation).
        Tenants with in-flight requests are eligible only when the two
        partitions run execution-compatible policies."""
        compat = self.policy_key(src) == self.policy_key(dst)
        cur = abs(works[src] - works[dst])
        best, best_score = None, None
        for tid in self.schedulers[src]._order:
            t = self.schedulers[src].tenants[tid]
            if t.frozen or tid in self._draining:
                continue
            if t.active and not compat:
                continue
            w = self._tenant_work(src, tid)
            if w <= 0:
                continue
            score = abs((works[src] - w) - (works[dst] + w))
            if score >= cur:
                continue
            if best_score is None or score < best_score:
                best, best_score = tid, score
        return best

    def migrate(self, tenant_id: str, dst: Optional[int] = None, *,
                reason: str = "manual") -> MigrationRecord:
        """Start a live migration of ``tenant_id`` to partition ``dst``
        (default: the least-loaded other partition).

        The tenant is frozen on its source partition (no new admissions),
        its queued requests transfer immediately, new submissions route to
        the target at once, and each in-flight request hands its per-slot
        cache state over as the target frees a slot — or simply finishes
        on the source if that happens first. The returned record's
        ``done_step`` is set once the source is fully drained and the
        tenant's accounting has been folded onto the target."""
        if tenant_id in self._draining:
            raise ValueError(f"tenant {tenant_id!r} is already migrating")
        src = self.tenant_partition[tenant_id]
        if dst is None:
            loads = self._loads()
            dst = min((i for i in range(self.n_partitions) if i != src),
                      key=lambda i: (loads[i], i))
        if dst == src:
            raise ValueError(f"tenant {tenant_id!r} is already on "
                             f"partition {dst}")
        if not 0 <= dst < self.n_partitions:
            raise ValueError(f"no partition {dst}")
        src_sched, dst_sched = self.schedulers[src], self.schedulers[dst]
        src_t = src_sched.tenants[tenant_id]
        if tenant_id in dst_sched.tenants:
            raise ValueError(f"tenant {tenant_id!r} already has state on "
                             f"partition {dst}")
        if src_t.active and self.policy_key(src) != self.policy_key(dst):
            raise ValueError(
                f"tenant {tenant_id!r} has {src_t.active} in-flight "
                f"request(s) and partitions {src}->{dst} run different "
                f"execution policies ({self.policy_key(src) or 'ambient'} "
                f"vs {self.policy_key(dst) or 'ambient'}); a request's "
                "arithmetic cannot change mid-stream — drain it first or "
                "pick a policy-compatible target")

        src_sched.freeze(tenant_id)
        dst_t = dst_sched.add_tenant(tenant_id, weight=src_t.weight,
                                     policy=src_t.policy, slo=src_t.slo)
        # fair_quantum join rule: resume at no less than the target's
        # current virtual-time floor so the newcomer cannot monopolize
        # admissions, but keep its own served-work history
        others = [t.vtime for t in dst_sched.tenants.values()
                  if t.tenant_id != tenant_id]
        dst_t.vtime = max(src_t.vtime, min(others, default=0.0))

        moved = list(src_t.queue)
        src_t.queue.clear()
        dst_t.queue.extend(moved)
        dst_t.submitted += len(moved)
        src_t.submitted -= len(moved)
        if moved:
            first = min(r.submit_step for r in moved)
            dst_t.first_submit_step = first if dst_t.first_submit_step < 0 \
                else min(dst_t.first_submit_step, first)
        self.tenant_partition[tenant_id] = dst

        rec = MigrationRecord(tenant=tenant_id, src=src, dst=dst,
                              start_step=self.step_count, reason=reason,
                              queued_moved=len(moved))
        self.migrations.append(rec)
        self._draining[tenant_id] = rec
        self._last_migration_step = self.step_count
        for tr in (self.tracers[src], self.tracers[dst]):
            tr.record_migrate(tenant_id, src=src, dst=dst, phase="start",
                              step=self.step_count, reason=reason,
                              queued=len(moved))
        self._advance_migration(rec)     # hand off what fits right now
        return rec

    def _advance_migrations(self) -> None:
        for rec in list(self._draining.values()):
            self._advance_migration(rec)

    def _advance_migration(self, rec: MigrationRecord) -> None:
        tid, src, dst = rec.tenant, rec.src, rec.dst
        src_sched, dst_sched = self.schedulers[src], self.schedulers[dst]
        src_sess, dst_sess = self.sessions[src], self.sessions[dst]
        src_t, dst_t = src_sched.tenants[tid], dst_sched.tenants[tid]
        for slot, req in enumerate(src_sess.slots):
            if req is None or req.tenant != tid:
                continue
            # admission-by-headroom: on paged targets this checks free
            # PAGES for the slot's pages-in-use, not just a free slot
            if not dst_sess.can_accept_pages(src_sess.handoff_pages(slot),
                                             src_sess.page_size):
                break                 # keep decoding on src; retry next step
            export = src_sess.export_slot(slot)
            dst_slot = dst_sess.import_slot(export)
            src_t.active -= 1
            dst_t.active += 1
            rec.slots_handed_off += 1
            for tr in (self.tracers[src], self.tracers[dst]):
                tr.record_migrate(tid, src=src, dst=dst, phase="handoff",
                                  step=self.step_count, uid=req.uid,
                                  src_slot=slot, dst_slot=dst_slot,
                                  pos=export.pos, pages=export.pages,
                                  handoff_bytes=export_nbytes(export))
        if src_t.queue or src_t.active:
            return
        # source fully drained: fold the tenant's history onto the target
        # (chronologically: source completions happened first) and detach
        dst_t.completed[:0] = src_t.completed
        dst_t.tokens_out += src_t.tokens_out
        dst_t.submitted += src_t.submitted
        dst_t.service_steps += src_t.service_steps
        dst_t.spec_steps += src_t.spec_steps
        dst_t.spec_drafted += src_t.spec_drafted
        dst_t.spec_accepted += src_t.spec_accepted
        if src_t.first_submit_step >= 0:
            dst_t.first_submit_step = src_t.first_submit_step \
                if dst_t.first_submit_step < 0 \
                else min(dst_t.first_submit_step, src_t.first_submit_step)
        if src_sess.adaptive_k is not None:
            # the departed tenant must stop constraining the source's
            # batch-wide adaptive speculation depth
            src_sess.adaptive_k.forget(tid)
        src_sched.remove_tenant(tid)
        rec.done_step = self.step_count
        del self._draining[tid]
        self._migrated_counts[tid] = self._migrated_counts.get(tid, 0) + 1
        for tr in (self.tracers[src], self.tracers[dst]):
            tr.record_migrate(tid, src=src, dst=dst, phase="done",
                              step=self.step_count,
                              handoffs=rec.slots_handed_off)

    # -- fused telemetry ----------------------------------------------------
    def merged_tracer(self) -> telemetry.Tracer:
        """One fused event view over all partitions
        (:meth:`telemetry.Tracer.merge`; partition tags preserved)."""
        return telemetry.Tracer.merge(*self.tracers)

    def _tenant_groups(self) -> Dict[str, List[Tuple[int, Tenant]]]:
        groups: Dict[str, List[Tuple[int, Tenant]]] = {}
        for i, sched in enumerate(self.schedulers):
            for tid, t in sched.tenants.items():
                groups.setdefault(tid, []).append((i, t))
        return groups

    def report(self) -> PartitionedReport:
        reps = [s.report() for s in self.schedulers]
        groups = self._tenant_groups()
        rows: List[TenantReport] = []
        turnarounds: List[float] = []
        for tid in self._tenant_order:
            row, contrib = build_tenant_report(
                tid, [t for _, t in groups.get(tid, [])], self.step_count,
                partition=self.tenant_partition.get(tid, -1),
                migrations=self._migrated_counts.get(tid, 0))
            rows.append(row)
            if contrib is not None:
                turnarounds.append(contrib)
        rep = PartitionedReport(
            placement=self.placement,
            admission="/".join(sorted({s.admission
                                       for s in self.schedulers})),
            quota="/".join(sorted({s.quota.name for s in self.schedulers})),
            n_partitions=self.n_partitions,
            n_tenants=len(self._tenant_order),
            steps=self.step_count,
            wall_s=max((rep.wall_s for rep in reps), default=0.0),
            tokens_out=sum(rep.tokens_out for rep in reps),
            fairness=cc.fairness(turnarounds),
            cv=cc.cv(turnarounds),
            tenant_partition=dict(self.tenant_partition),
            partitions=reps,
            tenants=rows,
            migrations=sum(1 for m in self.migrations if m.done),
            policies=[self.policy_key(i)
                      for i in range(self.n_partitions)])
        if self.metrics is not None:
            from repro.runtime.metrics import observe_runtime
            observe_runtime(self.metrics, self, rep)
        return rep


def run_serving(params, cfg, spec: Union[ServingSpec, Dict],
                workloads: Dict[str, Sequence[Request]], *,
                weights: Optional[Dict[str, float]] = None,
                max_steps: int = 100_000,
                **runtime_kw) -> PartitionedReport:
    """One-shot helper: build the runtime from a spec, register + submit
    every tenant's workload, drain, return the fused report."""
    runtime = ServingRuntime(params, cfg, spec, **runtime_kw)
    for tid in workloads:
        if tid not in runtime.tenant_partition:
            runtime.add_tenant(tid, weight=(weights or {}).get(tid, 1.0))
    for tid, reqs in workloads.items():
        for req in reqs:
            runtime.submit(tid, req)
    runtime.drain(max_steps=max_steps)
    return runtime.report()
