"""Production traffic simulator: seed-deterministic workloads + traces.

The serving stack (PRs 2-9) grew admission classes, quotas, migration,
overlap, speculative depth, and SLO attainment — but every one of those
mechanisms was exercised by hand-built toy workloads. This module is the
workload plane: a generator that turns a compact :class:`WorkloadSpec`
into a concrete arrival sequence, and a :class:`WorkloadTrace`
record/replay harness so that any generated (or captured) workload is
replayable *bit-for-bit* through ``ServingRuntime.submit``.

Design rules:

* **Millions of users is a rate parameter, not a tenant count.** The
  generator samples an *aggregate* arrival process (requests per
  scheduler step); the user population only ever appears as that rate.
  Tenants are the runtime's logical isolation domains (N small), and
  per-arrival tenant attribution follows a truncated Zipf popularity
  law over tenant ranks — rank 0 is the head tenant, the tail shares
  the remainder, which is how real multi-tenant traffic concentrates.
* **Arrival processes are modulated Poisson.** ``poisson`` is
  homogeneous; ``bursty`` alternates ON/OFF phases (geometric phase
  lengths, rate x burst_factor vs rate / burst_factor); ``diurnal``
  modulates the rate sinusoidally with a fixed period — a compressed
  day. All three draw from one ``numpy`` Generator in a documented
  order, so a (spec, seed) pair always yields the same trace.
* **Traces are self-contained.** Every event stores its prompt tokens
  and output budget inline. Replay never re-samples anything, so a
  saved JSON trace reproduces the exact same submit sequence even if
  the generator's sampling order changes in a future PR.
* **Lengths are mixtures.** Prompt and output lengths draw from a
  short uniform range with an optional long-range mixture component
  (``long_frac``) — the bimodal short-interactive / long-batch shape
  that makes slot-occupancy decisions interesting.

``run_trace`` drives a trace through anything with the scheduler facade
(``add_tenant`` / ``submit`` / ``step``; ``ServingRuntime`` and
``StreamScheduler`` both qualify) in the global lockstep step domain:
arrivals for step s are submitted before step s executes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.runtime.serve_loop import Request

ARRIVALS = ("poisson", "bursty", "diurnal")
TRACE_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class LengthDist:
    """Uniform [lo, hi] length draw with an optional long-range mixture:
    with probability ``long_frac`` the draw comes from
    [long_lo, long_hi] instead — short interactive turns beside long
    batch generations in one stream."""
    lo: int
    hi: int
    long_lo: int = 0
    long_hi: int = 0
    long_frac: float = 0.0

    def __post_init__(self):
        if self.lo < 1 or self.hi < self.lo:
            raise ValueError(f"LengthDist needs 1 <= lo <= hi, got "
                             f"[{self.lo}, {self.hi}]")
        if not 0.0 <= self.long_frac <= 1.0:
            raise ValueError(f"long_frac must be in [0, 1], got "
                             f"{self.long_frac}")
        if self.long_frac > 0.0 and (self.long_lo < 1
                                     or self.long_hi < self.long_lo):
            raise ValueError(f"LengthDist long range needs 1 <= long_lo "
                             f"<= long_hi, got [{self.long_lo}, "
                             f"{self.long_hi}]")

    def sample(self, rng: np.random.Generator) -> int:
        # Draw order is part of the determinism contract: one uniform
        # for the mixture gate (only when a long component exists), then
        # one integer for the length.
        if self.long_frac > 0.0 and rng.random() < self.long_frac:
            return int(rng.integers(self.long_lo, self.long_hi + 1))
        return int(rng.integers(self.lo, self.hi + 1))

    def to_dict(self) -> Dict[str, Any]:
        d = {"lo": self.lo, "hi": self.hi}
        if self.long_frac > 0.0:
            d.update(long_lo=self.long_lo, long_hi=self.long_hi,
                     long_frac=self.long_frac)
        return d

    @classmethod
    def from_any(cls, v: Union["LengthDist", int, Sequence[int], Dict]
                 ) -> "LengthDist":
        """int → fixed length; (lo, hi) → uniform; dict → kwargs."""
        if isinstance(v, LengthDist):
            return v
        if isinstance(v, int):
            return cls(lo=v, hi=v)
        if isinstance(v, dict):
            return cls(**v)
        if isinstance(v, (tuple, list)) and len(v) == 2:
            return cls(lo=int(v[0]), hi=int(v[1]))
        raise TypeError(f"LengthDist spec {v!r} is not "
                        "LengthDist/int/(lo, hi)/dict")


def zipf_weights(n: int, s: float) -> np.ndarray:
    """Truncated-Zipf popularity over ranks 0..n-1: p(i) ∝ (i+1)^-s.
    s=0 is uniform; s≈1 is classic web-traffic skew."""
    if n < 1:
        raise ValueError("zipf_weights needs n >= 1")
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-float(s))
    return w / w.sum()


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Declarative workload: (spec, seed) → one deterministic trace.

    ``rate`` is aggregate mean arrivals per scheduler step — the only
    place the size of the user population appears. ``slos`` /
    ``weights``, when given, are per-tenant-rank (length ``tenants``)
    and ride along into ``run_trace`` registration.
    """
    tenants: int = 4
    zipf_s: float = 1.1              # tenant popularity skew (0: uniform)
    arrival: str = "poisson"
    rate: float = 1.0                # mean arrivals / scheduler step
    burst_factor: float = 4.0        # bursty: ON-phase rate multiplier
    burst_len: int = 8               # bursty: mean phase length (steps)
    period: int = 64                 # diurnal: steps per cycle
    amplitude: float = 0.8           # diurnal: rate swing fraction
    steps: int = 64                  # arrival horizon (scheduler steps)
    prompt_len: Any = (4, 8)         # LengthDist.from_any forms
    max_new: Any = (4, 8)
    # Per-rank max_new overrides (None: the global dist). Interactive
    # tenants answer short while batch tenants generate long — the shape
    # that makes slot occupancy contended.
    max_new_overrides: Tuple[Any, ...] = ()
    vocab: int = 256                 # prompt token id range
    slos: Tuple[Optional[str], ...] = ()    # per-rank SLO spec strings
    weights: Tuple[float, ...] = ()         # per-rank scheduler weights
    seed: int = 0

    def __post_init__(self):
        if self.tenants < 1:
            raise ValueError("WorkloadSpec needs tenants >= 1")
        if self.arrival not in ARRIVALS:
            raise ValueError(f"arrival {self.arrival!r} not in {ARRIVALS}")
        if self.rate <= 0:
            raise ValueError("rate must be positive")
        if self.burst_factor < 1.0:
            raise ValueError("burst_factor must be >= 1")
        if self.burst_len < 1:
            raise ValueError("burst_len must be >= 1")
        if self.period < 2:
            raise ValueError("period must be >= 2")
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1)")
        if self.steps < 1:
            raise ValueError("steps must be >= 1")
        if self.vocab < 2:
            raise ValueError("vocab must be >= 2")
        object.__setattr__(self, "prompt_len",
                           LengthDist.from_any(self.prompt_len))
        object.__setattr__(self, "max_new",
                           LengthDist.from_any(self.max_new))
        object.__setattr__(
            self, "max_new_overrides",
            tuple(None if v is None else LengthDist.from_any(v)
                  for v in self.max_new_overrides))
        if self.max_new_overrides \
                and len(self.max_new_overrides) != self.tenants:
            raise ValueError(
                f"max_new_overrides has {len(self.max_new_overrides)} "
                f"entries for {self.tenants} tenants")
        object.__setattr__(self, "slos", tuple(self.slos))
        object.__setattr__(self, "weights",
                           tuple(float(w) for w in self.weights))
        if self.slos and len(self.slos) != self.tenants:
            raise ValueError(f"slos has {len(self.slos)} entries for "
                             f"{self.tenants} tenants")
        if self.weights and len(self.weights) != self.tenants:
            raise ValueError(f"weights has {len(self.weights)} entries "
                             f"for {self.tenants} tenants")

    def tenant_ids(self) -> List[str]:
        return [f"tenant{i}" for i in range(self.tenants)]

    def slo_for(self, rank: int) -> Optional[str]:
        return self.slos[rank] if self.slos else None

    def weight_for(self, rank: int) -> float:
        return self.weights[rank] if self.weights else 1.0

    def max_new_for(self, rank: int) -> LengthDist:
        if self.max_new_overrides \
                and self.max_new_overrides[rank] is not None:
            return self.max_new_overrides[rank]
        return self.max_new

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["prompt_len"] = self.prompt_len.to_dict()
        d["max_new"] = self.max_new.to_dict()
        d["max_new_overrides"] = [None if v is None else v.to_dict()
                                  for v in self.max_new_overrides]
        d["slos"] = list(self.slos)
        d["weights"] = list(self.weights)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "WorkloadSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown WorkloadSpec fields: "
                             f"{sorted(unknown)}")
        d = dict(d)
        if "slos" in d:
            d["slos"] = tuple(d["slos"])
        if "weights" in d:
            d["weights"] = tuple(d["weights"])
        if "max_new_overrides" in d:
            d["max_new_overrides"] = tuple(d["max_new_overrides"])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class WorkloadEvent:
    """One arrival, fully materialized: replay needs no generator."""
    step: int
    tenant: str
    uid: int
    prompt: Tuple[int, ...]
    max_new: int

    def to_request(self) -> Request:
        # A FRESH Request per call: the runtime mutates Request in
        # place, so replays must never share instances.
        return Request(uid=self.uid,
                       prompt=np.asarray(self.prompt, dtype=np.int32),
                       max_new=self.max_new)

    def to_dict(self) -> Dict[str, Any]:
        return {"step": self.step, "tenant": self.tenant,
                "uid": self.uid, "prompt": list(self.prompt),
                "max_new": self.max_new}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "WorkloadEvent":
        return cls(step=int(d["step"]), tenant=str(d["tenant"]),
                   uid=int(d["uid"]),
                   prompt=tuple(int(x) for x in d["prompt"]),
                   max_new=int(d["max_new"]))


@dataclasses.dataclass
class WorkloadTrace:
    """An ordered arrival sequence + the spec that produced it (None
    for captured/hand-built traces). JSON round-trips exactly."""
    events: List[WorkloadEvent]
    spec: Optional[WorkloadSpec] = None

    @property
    def steps(self) -> int:
        """Arrival horizon: the spec's if present, else last event + 1."""
        if self.spec is not None:
            return self.spec.steps
        return max((e.step for e in self.events), default=-1) + 1

    def by_step(self) -> Dict[int, List[WorkloadEvent]]:
        out: Dict[int, List[WorkloadEvent]] = {}
        for e in self.events:
            out.setdefault(e.step, []).append(e)
        return out

    def tenant_ids(self) -> List[str]:
        if self.spec is not None:
            return self.spec.tenant_ids()
        seen: List[str] = []
        for e in self.events:
            if e.tenant not in seen:
                seen.append(e.tenant)
        return seen

    def arrivals_per_tenant(self) -> Dict[str, int]:
        out = {tid: 0 for tid in self.tenant_ids()}
        for e in self.events:
            out[e.tenant] = out.get(e.tenant, 0) + 1
        return out

    def to_dict(self) -> Dict[str, Any]:
        return {"schema": TRACE_SCHEMA,
                "spec": self.spec.to_dict() if self.spec else None,
                "events": [e.to_dict() for e in self.events]}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "WorkloadTrace":
        if d.get("schema") != TRACE_SCHEMA:
            raise ValueError(f"trace schema {d.get('schema')!r} != "
                             f"{TRACE_SCHEMA}")
        spec = (WorkloadSpec.from_dict(d["spec"])
                if d.get("spec") is not None else None)
        return cls(events=[WorkloadEvent.from_dict(e)
                           for e in d["events"]], spec=spec)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "WorkloadTrace":
        return cls.from_dict(json.loads(s))

    def save(self, path: Union[str, Path]) -> None:
        Path(path).write_text(self.to_json())

    @classmethod
    def load(cls, path: Union[str, Path]) -> "WorkloadTrace":
        return cls.from_json(Path(path).read_text())


def _rates(spec: WorkloadSpec, rng: np.random.Generator) -> List[float]:
    """Per-step arrival rates. Bursty phase lengths draw from ``rng``
    FIRST (before any per-arrival sampling) so the modulation sequence
    is deterministic and independent of how many arrivals land."""
    if spec.arrival == "poisson":
        return [spec.rate] * spec.steps
    if spec.arrival == "diurnal":
        return [spec.rate * (1.0 + spec.amplitude
                             * math.sin(2.0 * math.pi * s / spec.period))
                for s in range(spec.steps)]
    # bursty: ON/OFF alternation, geometric phase lengths, mean
    # preserved-ish around rate (ON multiplies, OFF divides).
    rates: List[float] = []
    on = True
    remaining = 0
    while len(rates) < spec.steps:
        if remaining == 0:
            on = not on
            remaining = int(rng.geometric(1.0 / spec.burst_len))
        rates.append(spec.rate * spec.burst_factor if on
                     else spec.rate / spec.burst_factor)
        remaining -= 1
    return rates


def generate(spec: WorkloadSpec) -> "WorkloadTrace":
    """(spec, spec.seed) → deterministic trace. Sampling order per step:
    arrival count, then per arrival: tenant rank, prompt length, output
    length, prompt tokens."""
    rng = np.random.default_rng(spec.seed)
    probs = zipf_weights(spec.tenants, spec.zipf_s)
    tids = spec.tenant_ids()
    rates = _rates(spec, rng)
    events: List[WorkloadEvent] = []
    uid = 0
    for step in range(spec.steps):
        n = int(rng.poisson(rates[step]))
        for _ in range(n):
            rank = int(rng.choice(spec.tenants, p=probs))
            plen = spec.prompt_len.sample(rng)
            mnew = spec.max_new_for(rank).sample(rng)
            prompt = rng.integers(0, spec.vocab, plen)
            events.append(WorkloadEvent(
                step=step, tenant=tids[rank], uid=uid,
                prompt=tuple(int(t) for t in prompt), max_new=mnew))
            uid += 1
    return WorkloadTrace(events=events, spec=spec)


def run_trace(runtime, trace: WorkloadTrace, *, register: bool = True,
              drain: bool = True, max_steps: int = 100_000,
              on_step=None) -> List[Request]:
    """Drive a trace through a scheduler facade (``ServingRuntime`` or
    ``StreamScheduler``) in lockstep: arrivals stamped for step s are
    submitted before step s runs, so ``submit_step`` matches the trace.
    Returns the completed requests (ALL of them when ``drain``)."""
    if register:
        ranks = {tid: i for i, tid in enumerate(trace.tenant_ids())}
        registered = getattr(runtime, "tenant_partition", None)
        if registered is None:                       # StreamScheduler
            registered = runtime.tenants
        spec = trace.spec
        for tid, rank in ranks.items():
            if tid in registered:
                continue
            kw: Dict[str, Any] = {}
            if spec is not None:
                kw["weight"] = spec.weight_for(rank)
                kw["slo"] = spec.slo_for(rank)
            runtime.add_tenant(tid, **kw)
    by_step = trace.by_step()
    done: List[Request] = []
    for step in range(trace.steps):
        for ev in by_step.get(step, ()):
            runtime.submit(ev.tenant, ev.to_request())
        done.extend(runtime.step())
        if on_step is not None:
            on_step(runtime, step)
    if drain:
        # drain()/run() return the FULL completion list (including the
        # requests finished during the arrival phase above).
        if hasattr(runtime, "drain"):
            return runtime.drain(max_steps)
        return runtime.run(max_steps)
    return done


def tokens_by_uid(completed: Sequence[Request]) -> Dict[int, List[int]]:
    """uid → committed tokens, the equality unit for replay/controller
    exactness asserts."""
    return {r.uid: list(r.out) for r in completed}


def token_checksum(completed: Sequence[Request]) -> str:
    """Order-independent digest of every committed token stream — the
    loadgen CLI prints it so CI can compare a generate-run against a
    replay-run without shipping token dumps around."""
    h = hashlib.sha256()
    for r in sorted(completed, key=lambda r: r.uid):
        h.update(f"{r.uid}:{','.join(map(str, r.out))};".encode())
    return h.hexdigest()[:16]
