"""Fairness-aware multi-tenant serving scheduler (paper §6/§9.2 at the
application layer).

The paper's concurrency pillar shows that *aggregate* speedup under
concurrent streams masks per-stream fairness collapse (Fig 5: 0.016–0.138
at 8 streams), and §9.2 turns that into scheduling guidance. This module
reproduces the result — and the fix — at the serving layer instead of raw
matmuls: N tenant queues share one model through a
:class:`~repro.runtime.serve_loop.ServeSession`, and a pluggable admission
policy decides whose request takes the next free slot.

Admission policies
------------------
* ``fifo``         — global arrival order. The shared-queue throughput
  extreme: first tenants monopolize the slots, per-tenant fairness
  collapses exactly as the paper's shared-ACE-queue runs do.
* ``round_robin``  — cycle tenants with backlog; equal turns regardless of
  request cost.
* ``fair_quantum`` — credit-based (stride/deficit hybrid): each tenant
  accrues virtual time as ``served_work / weight`` and the lowest virtual
  time with backlog wins the slot, so heavier requests cost
  proportionally more of a tenant's turn. Per-tenant slot quotas come
  from the tenant's :class:`~repro.core.execution.ExecutionPolicy` stream
  budget (PR 1) with the :class:`~repro.core.concurrency.OccupancyAdvisor`
  cap as the default — the §9.2 "≤4 streams for latency-sensitive" rule
  as an admission constraint.

Quota resolution is a pluggable :class:`QuotaPolicy`:

* :class:`StaticQuota` — the stream-budget/advisor constants above.
* :class:`AdaptiveQuota` — re-derives per-tenant slot caps online every N
  steps from ``Tracer.tenant_percentiles()``: a tenant whose p99/p50
  turnaround ratio is an outlier (deep backlog bursting through the
  shared slots) gets its cap shrunk toward 1 and the freed share is
  granted to the best-behaved backlogged tenants, with the aggregate
  grant bounded by the partition's slot budget.

Telemetry: per-tenant fairness / CV / overlap efficiency and p50/p99
request latency, all through :mod:`repro.core.concurrency` so the serving
report reads like the paper's stream characterization. Step-domain
metrics (turnaround in decode steps) are deterministic; wall-clock
latencies ride along for real deployments.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import concurrency as cc
from repro.core import execution as ex
from repro.runtime.serve_loop import Request, ServeSession

ADMISSION_POLICIES = ("fifo", "round_robin", "fair_quantum")
QUOTA_POLICIES = ("static", "adaptive")

# Arrival stamps are PROCESS-GLOBAL (not per-scheduler): a live migration
# moves queued requests between schedulers, and fifo's min-by-arrival
# tiebreak is only meaningful if every request's stamp comes from one
# ordered domain. Deterministic for a fixed submission sequence.
_ARRIVALS = itertools.count()


def request_cost(req: Request) -> int:
    """Admission cost of a request in token-positions: prefill work plus
    the decode budget it may hold a slot for."""
    return len(req.prompt) + req.max_new


# ---------------------------------------------------------------------------
# SLO classes (latency-bound / throughput-bound / batch tenants)
# ---------------------------------------------------------------------------

SLO_KINDS = ("latency", "throughput", "batch")


@dataclasses.dataclass(frozen=True)
class SLO:
    """A tenant's service-level objective, in one of three classes:

    * ``latency`` — ``target`` is a per-request turnaround bound;
      attainment is the fraction of completed requests that met it. The
      domain is ``metric``: ``"turnaround_steps"`` (deterministic
      scheduler steps — reproducible run-to-run, the default) or
      ``"wall_s"`` (wall-clock seconds, for real deployments). A tenant
      with demand but zero completions is *starved*: attainment 0.0, not
      undefined — starvation must read as the worst miss.
    * ``throughput`` — ``target`` is a delivered-rate floor in tokens
      per global scheduler step; attainment is
      ``min(1, observed / target)``.
    * ``batch`` — best-effort completion: ``target`` is the required
      completion ratio (default 1.0); attainment is
      ``min(1, (completed / submitted) / target)``.

    A tenant with no demand has no attainment (``None``) — idle is not a
    miss. Spec strings parse as ``kind:target[@metric]``
    (``"latency:8"``, ``"latency:0.05@wall_s"``, ``"throughput:2.5"``,
    ``"batch"``)."""
    kind: str
    target: float = 0.0
    metric: str = "turnaround_steps"

    def __post_init__(self):
        if self.kind not in SLO_KINDS:
            raise ValueError(f"SLO kind {self.kind!r} not in {SLO_KINDS}")
        if self.kind == "batch" and self.target == 0.0:
            object.__setattr__(self, "target", 1.0)
        if self.target <= 0:
            raise ValueError(f"SLO target must be positive, got "
                             f"{self.target}")
        if self.metric not in ("turnaround_steps", "wall_s"):
            raise ValueError(f"SLO metric {self.metric!r} not in "
                             "('turnaround_steps', 'wall_s')")

    def spec(self) -> str:
        s = f"{self.kind}:{self.target:g}"
        if self.kind == "latency" and self.metric != "turnaround_steps":
            s += f"@{self.metric}"
        return s

    @classmethod
    def parse(cls, spec: Union[None, str, Dict, "SLO"]) -> Optional["SLO"]:
        """``None`` / spec-string / dict / instance → ``Optional[SLO]``."""
        if spec is None or isinstance(spec, SLO):
            return spec
        if isinstance(spec, dict):
            return cls(**spec)
        if not isinstance(spec, str):
            raise TypeError(f"SLO spec {spec!r} is not None/str/dict/SLO")
        body, _, metric = spec.partition("@")
        kind, _, target = body.partition(":")
        kw: Dict[str, Any] = {"kind": kind.strip()}
        if target.strip():
            kw["target"] = float(target)
        elif kind.strip() != "batch":
            raise ValueError(f"SLO {spec!r}: {kind!r} needs a target "
                             "(\"kind:target\")")
        if metric.strip():
            kw["metric"] = metric.strip()
        return cls(**kw)

    def attainment(self, *, samples: Sequence[float] = (),
                   tokens_out: int = 0, steps: int = 0,
                   completed: int = 0, submitted: int = 0
                   ) -> Optional[float]:
        """Attainment ratio in [0, 1] from a tenant's observed record;
        ``samples`` is the per-request latency population in this SLO's
        ``metric`` domain (only consulted by the ``latency`` class).
        ``None`` with no demand."""
        if submitted <= 0:
            return None
        if self.kind == "latency":
            if completed <= 0:
                return 0.0           # starved: demand, nothing finished
            if not samples:
                return 0.0
            met = sum(1 for s in samples if s <= self.target)
            return met / len(samples)
        if self.kind == "throughput":
            rate = tokens_out / steps if steps > 0 else 0.0
            return min(1.0, rate / self.target)
        return min(1.0, (completed / submitted) / self.target)


def attainment_from_tracer(tracer, tenant_id: str, slo: Optional[SLO],
                           steps: int) -> Optional[float]:
    """SLO attainment from telemetry alone (the metrics plane's path —
    reports use the exact scheduler records instead): latency samples
    come from ``Tracer.tenant_latencies`` (the same window
    ``tenant_percentiles`` summarizes), demand/tokens from the monotonic
    per-tenant counters, so the ratio survives ring eviction."""
    if slo is None:
        return None
    completed = tracer.tenant_counts("request").get(tenant_id, 0)
    admitted = tracer.tenant_counts("admit").get(tenant_id, 0)
    samples = tracer.tenant_latencies(slo.metric).get(tenant_id, [])
    tokens = sum(ev.meta.get("tokens", 0)
                 for ev in tracer.events("request")
                 if ev.tenant == tenant_id)
    return slo.attainment(samples=samples, tokens_out=tokens, steps=steps,
                          completed=completed,
                          submitted=max(admitted, completed))


@dataclasses.dataclass
class Tenant:
    """One tenant's queue + accounting."""
    tenant_id: str
    weight: float = 1.0
    policy: Optional[ex.ExecutionPolicy] = None
    slo: Optional[SLO] = None
    queue: List[Request] = dataclasses.field(default_factory=list)
    completed: List[Request] = dataclasses.field(default_factory=list)
    submitted: int = 0
    tokens_out: int = 0
    active: int = 0                  # slots currently held
    service_steps: int = 0           # decode steps holding >= 1 slot
    vtime: float = 0.0               # fair_quantum: served_work / weight
    frozen: bool = False             # draining: no new admissions
    first_submit_step: int = -1      # earliest demand (starvation lower
    #                                  bound when nothing ever completes)
    spec_steps: int = 0              # speculative decode steps holding a slot
    spec_drafted: int = 0            # draft tokens proposed across those steps
    spec_accepted: int = 0           # drafts the bf16 verify accepted

    def slot_cap(self, default: int) -> int:
        """Concurrent-slot quota: the tenant policy's stream budget if it
        carries one, else the advisor default."""
        if self.policy is not None and self.policy.streams > 0:
            return self.policy.streams
        return default


@dataclasses.dataclass
class TenantReport:
    tenant_id: str
    completed: int
    tokens_out: int
    service_steps: int
    mean_turnaround_steps: float     # submit -> finish, scheduler steps
    mean_queue_wait_steps: float     # submit -> admit, scheduler steps
    p50_latency_s: float
    p99_latency_s: float
    submitted: int = 0               # demand (0: registered but idle)
    partition: int = -1              # serving partition (-1: unpartitioned)
    migrations: int = 0              # times this tenant was live-migrated
    slo: str = ""                    # SLO spec string ("": no SLO)
    slo_attainment: Optional[float] = None   # None: no SLO or no demand
    spec_steps: int = 0              # speculative decode steps
    spec_drafted: int = 0            # draft tokens proposed
    spec_accepted: int = 0           # drafts accepted by the verify
    acceptance_rate: Optional[float] = None  # accepted/drafted (None: no
    #                                          drafts proposed)
    effective_tokens_per_step: Optional[float] = None  # committed tokens
    #                                  per speculative step (>= 1.0; None
    #                                  without speculative steps)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def build_tenant_report(tid: str, records: Sequence[Tenant],
                        step_count: int, *, partition: int = -1,
                        migrations: int = 0
                        ) -> Tuple[TenantReport, Optional[float]]:
    """One fused :class:`TenantReport` over a tenant's records (a tenant
    mid-migration briefly has one per partition) plus its fairness-
    denominator contribution: the mean completed turnaround; the elapsed
    wait as a lower bound when STARVED (demand but nothing finished —
    starvation must drag fairness down, not vanish from it); ``None``
    with no demand. The single accounting rule shared by the scheduler
    and runtime reports, so the fused view cannot drift from the
    per-partition views it embeds."""
    completed = [r for t in records for r in t.completed]
    submitted = sum(t.submitted for t in records)
    ta = [float(r.finish_step - r.submit_step) for r in completed]
    waits = [float(r.admit_step - r.submit_step) for r in completed]
    lat = cc.latency_percentiles([r.latency_s for r in completed])
    mean_ta = float(np.mean(ta)) if ta else 0.0
    slo = next((t.slo for t in records if t.slo is not None), None)
    slo_att = None
    if slo is not None:
        samples = ta if slo.metric == "turnaround_steps" \
            else [r.latency_s for r in completed]
        slo_att = slo.attainment(
            samples=samples,
            tokens_out=sum(t.tokens_out for t in records),
            steps=step_count, completed=len(completed),
            submitted=submitted)
    spec_steps = sum(t.spec_steps for t in records)
    spec_drafted = sum(t.spec_drafted for t in records)
    spec_accepted = sum(t.spec_accepted for t in records)
    row = TenantReport(
        tenant_id=tid,
        completed=len(completed),
        tokens_out=sum(t.tokens_out for t in records),
        service_steps=sum(t.service_steps for t in records),
        mean_turnaround_steps=mean_ta,
        mean_queue_wait_steps=float(np.mean(waits)) if waits else 0.0,
        p50_latency_s=lat["p50"],
        p99_latency_s=lat["p99"],
        submitted=submitted,
        partition=partition,
        migrations=migrations,
        slo=slo.spec() if slo is not None else "",
        slo_attainment=slo_att,
        spec_steps=spec_steps,
        spec_drafted=spec_drafted,
        spec_accepted=spec_accepted,
        acceptance_rate=(spec_accepted / spec_drafted
                         if spec_drafted else None),
        effective_tokens_per_step=((spec_accepted + spec_steps) / spec_steps
                                   if spec_steps else None))
    if ta:
        contribution: Optional[float] = mean_ta
    elif submitted:
        first = min((t.first_submit_step for t in records
                     if t.first_submit_step >= 0), default=0)
        contribution = float(step_count - first)
    else:
        contribution = None
    return row, contribution


@dataclasses.dataclass
class SchedulerReport:
    """Paper-style per-tenant concurrency metrics for one serving run.

    ``fairness``/``cv`` are computed over per-tenant mean turnaround (in
    deterministic scheduler steps); ``overlap_efficiency`` compares the
    sum of per-tenant busy steps against the actual step count (1.0 when
    tenants fully share the decode batch, 0.0 when they serialize).
    """
    admission: str
    quota: str
    n_tenants: int
    steps: int
    wall_s: float
    tokens_out: int
    fairness: float
    fairness_min_max: float
    cv: float
    overlap_efficiency: float
    tenants: List[TenantReport]

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        lines = [
            f"[sched] {self.admission}/{self.quota}: {self.n_tenants} "
            f"tenants, "
            f"{self.steps} steps, {self.tokens_out} tokens in "
            f"{self.wall_s:.2f}s | fairness={self.fairness:.3f} "
            f"cv={self.cv:.3f} overlap_eff={self.overlap_efficiency:.3f}"]
        for t in self.tenants:
            line = (
                f"  {t.tenant_id}: {t.completed} done, {t.tokens_out} tok, "
                f"turnaround={t.mean_turnaround_steps:.1f} steps, "
                f"wait={t.mean_queue_wait_steps:.1f} steps, "
                f"p50={t.p50_latency_s * 1e3:.1f}ms "
                f"p99={t.p99_latency_s * 1e3:.1f}ms")
            if t.slo:
                att = "n/a" if t.slo_attainment is None \
                    else f"{t.slo_attainment:.2f}"
                line += f" slo[{t.slo}]={att}"
            lines.append(line)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Quota policies (pluggable per-tenant slot-cap resolution)
# ---------------------------------------------------------------------------

class QuotaPolicy:
    """How many concurrent slots each tenant may hold.

    ``slot_cap`` is consulted on every admission attempt; ``on_step`` runs
    once per scheduler step *before* admission, which is where an online
    policy re-derives its caps."""

    name = "quota"

    def slot_cap(self, sched: "StreamScheduler", tenant: Tenant) -> int:
        raise NotImplementedError

    def on_step(self, sched: "StreamScheduler") -> None:
        pass


class StaticQuota(QuotaPolicy):
    """The original resolution: the tenant policy's stream budget if it
    carries one, else the advisor's §9.2 cap for this tenancy level."""

    name = "static"

    def slot_cap(self, sched: "StreamScheduler", tenant: Tenant) -> int:
        return tenant.slot_cap(sched._advisor_cap())


class AdaptiveQuota(QuotaPolicy):
    """Telemetry-driven slot caps (the ROADMAP "drive fair_quantum quotas
    online from ``Tracer.tenant_percentiles()``" item).

    Caps seed at each tenant's weighted share of the partition's slot
    budget (every tenant keeps a floor of 1). Every ``interval`` steps the
    scheduler's tracer is consulted: per tenant, the p99/p50 ratio of
    request turnaround (deterministic step domain by default) is compared
    against the tenant median — a ratio beyond ``outlier_factor`` × median
    marks a *hogging* tenant (a deep backlog whose tail is bursting
    through the shared slots), its cap shrinks by 1 (floor 1), and the
    freed share is granted to the best-behaved backlogged tenant. The
    aggregate grant never exceeds ``max(batch_slots, n_tenants)`` — the
    partition's budget with the per-tenant floor — so online re-derivation
    can redistribute but never oversubscribe.

    Second signal — occupancy (``fill_floor``): when the tracer's mean
    observed grid-tile fill (:meth:`~repro.runtime.telemetry.Tracer.
    mean_fill`) drops below ``fill_floor``, the *aggregate* budget shrinks
    by one slot per interval (floor: one slot per tenant) and recovers one
    slot per interval once fill is back above the floor — the §5/§6
    finding that a collapsed grid cannot pay for wide concurrency, folded
    into admission. ``None`` (default) disables the signal: absolute fill
    is only meaningful against a calibrated core count, so deployments
    opt in with the measured floor (``launch/profile.py`` artifacts)."""

    name = "adaptive"

    def __init__(self, interval: int = 8, outlier_factor: float = 1.5,
                 metric: str = "turnaround_steps", min_samples: int = 2,
                 fill_floor: Optional[float] = None, n_cores: int = 256):
        if interval <= 0:
            raise ValueError("interval must be positive")
        self.interval = interval
        self.outlier_factor = outlier_factor
        self.metric = metric
        self.min_samples = min_samples
        self.fill_floor = fill_floor
        self.n_cores = n_cores
        self.caps: Dict[str, int] = {}
        self.recalcs = 0
        self.shrunk: Dict[str, int] = {}   # tenant -> total cap reductions
        self.occupancy_shrinks = 0         # budget cuts from fill collapse
        self._fill_budget: Optional[int] = None   # None: signal never fired
        self._seeded_for: frozenset = frozenset()

    # -- seeding ------------------------------------------------------------
    def budget(self, sched: "StreamScheduler") -> int:
        full = max(sched.session.batch_slots, len(sched.tenants))
        if self._fill_budget is None:
            return full
        # occupancy-collapsed budget: never below one slot per tenant
        return max(max(1, len(sched.tenants)), min(full, self._fill_budget))

    def _seed(self, sched: "StreamScheduler") -> None:
        tenants = [sched.tenants[tid] for tid in sched._order]
        total_w = sum(t.weight for t in tenants) or 1.0
        budget = self.budget(sched)
        caps = {t.tenant_id: max(1, int(budget * t.weight / total_w))
                for t in tenants}
        # distribute any remaining share deterministically: heaviest
        # first, registration order breaking ties
        remaining = budget - sum(caps.values())
        for t in sorted(tenants, key=lambda t: (-t.weight,
                                                sched._order.index(
                                                    t.tenant_id))):
            if remaining <= 0:
                break
            caps[t.tenant_id] += 1
            remaining -= 1
        self.caps = caps
        self._seeded_for = frozenset(caps)

    def slot_cap(self, sched: "StreamScheduler", tenant: Tenant) -> int:
        if frozenset(sched.tenants) != self._seeded_for:
            self._seed(sched)
        return self.caps[tenant.tenant_id]

    # -- the occupancy signal ------------------------------------------------
    def _occupancy_step(self, sched: "StreamScheduler", tracer) -> None:
        """Shrink/recover the aggregate budget from the measured grid
        fill, then trim caps to fit (largest caps first, registration
        order breaking ties)."""
        fill = tracer.mean_fill(self.n_cores)
        if fill is None:
            return
        full = max(sched.session.batch_slots, len(sched.tenants))
        floor = max(1, len(sched.tenants))
        changed = False
        if fill < self.fill_floor:
            cur = full if self._fill_budget is None else self._fill_budget
            nxt = max(floor, cur - 1)
            if nxt < cur:
                self._fill_budget = nxt
                self.occupancy_shrinks += 1
                changed = True
        elif self._fill_budget is not None:
            self._fill_budget += 1
            changed = True
            if self._fill_budget >= full:
                self._fill_budget = None          # fully recovered
        if not changed:
            return
        budget = self.budget(sched)
        while sum(self.caps.values()) > budget:
            tid = max(self.caps, key=lambda t: (self.caps[t],
                                                -sched._order.index(t)))
            if self.caps[tid] <= 1:
                break
            self.caps[tid] -= 1
        # recovery must REGROW the trimmed caps, not just the budget —
        # smallest caps first (the reverse of the trim), registration
        # order breaking ties, up to the recovered budget
        while sum(self.caps.values()) < budget:
            tid = min(self.caps, key=lambda t: (self.caps[t],
                                                sched._order.index(t)))
            self.caps[tid] += 1
        tracer.record("quota", step=sched.step_count,
                      meta={"signal": "occupancy", "fill": fill,
                            "budget": budget, "caps": dict(self.caps)})

    # -- the online loop ----------------------------------------------------
    def on_step(self, sched: "StreamScheduler") -> None:
        if sched.step_count == 0 or sched.step_count % self.interval:
            return
        if frozenset(sched.tenants) != self._seeded_for:
            self._seed(sched)
        tracer = sched.tracer
        if tracer is None:
            return
        if self.fill_floor is not None and self.caps:
            self._occupancy_step(sched, tracer)
        lats = tracer.tenant_latencies(self.metric)
        ratios: Dict[str, float] = {}
        for tid, ls in lats.items():
            if tid not in self.caps or len(ls) < self.min_samples:
                continue
            p = cc.latency_percentiles(ls)
            if p["p50"] > 0:
                ratios[tid] = p["p99"] / p["p50"]
        if len(ratios) < 2:
            return                       # nothing to compare against
        median = float(np.median(list(ratios.values())))
        outliers = [tid for tid, r in ratios.items()
                    if r > self.outlier_factor * max(1.0, median)]
        if not outliers:
            return
        self.recalcs += 1
        freed = 0
        for tid in outliers:
            if self.caps[tid] > 1:
                self.caps[tid] -= 1
                self.shrunk[tid] = self.shrunk.get(tid, 0) + 1
                freed += 1
        if not freed:
            return
        # grant the freed share to the best-behaved tenants (backlogged
        # first, then idle — the budget must be conserved, not leak when
        # every victim's queue is momentarily empty), lowest tail ratio
        # first, registration order breaking ties, aggregate at/below the
        # budget
        budget = self.budget(sched)
        grantees = sorted(
            (tid for tid in sched._order if tid not in outliers),
            key=lambda tid: (not sched.tenants[tid].queue,
                             ratios.get(tid, float("inf")),
                             sched._order.index(tid)))
        for tid in grantees:
            if freed <= 0 or sum(self.caps.values()) >= budget:
                break
            if self.caps[tid] < budget:
                self.caps[tid] += 1
                freed -= 1
        tracer.record("quota", step=sched.step_count,
                      meta={"caps": dict(self.caps),
                            "outliers": list(outliers),
                            "median_ratio": median})


def make_quota(quota: Union[None, str, QuotaPolicy]) -> QuotaPolicy:
    """``None``/``"static"``/``"adaptive"``/instance → a QuotaPolicy."""
    if quota is None or quota == "static":
        return StaticQuota()
    if quota == "adaptive":
        return AdaptiveQuota()
    if isinstance(quota, QuotaPolicy):
        return quota
    raise ValueError(f"quota {quota!r} not in {QUOTA_POLICIES} and not a "
                     "QuotaPolicy instance")


class StreamScheduler:
    """Run N tenant queues against one :class:`ServeSession`.

    The scheduler owns admission (the session's own FIFO queue stays
    unused): each step it fills free slots according to the admission
    policy, then advances every active slot one decode step via
    ``session.decode_once()``.
    """

    def __init__(self, session: ServeSession, *,
                 admission: str = "fair_quantum",
                 advisor: Optional[cc.OccupancyAdvisor] = None,
                 tracer=None, quota: Union[None, str, QuotaPolicy] = None):
        if admission not in ADMISSION_POLICIES:
            raise ValueError(f"admission {admission!r} not in "
                             f"{ADMISSION_POLICIES}")
        self.session = session
        self.admission = admission
        self.quota = make_quota(quota)
        if isinstance(self.quota, AdaptiveQuota) and tracer is None:
            # the adaptive loop needs the per-tenant percentiles: reuse
            # the session's tracer when it already has one (taking it
            # over below would otherwise silently starve it), else build
            # a private one
            tracer = session.tracer
            if tracer is None:
                from repro.runtime import telemetry
                tracer = telemetry.Tracer()
        # Default quota advisor: the calibrated one when autotune.install()
        # has loaded a measured artifact, else the §9.2-constant advisor.
        self.advisor = advisor or ex.get_default_advisor()
        # tracer (repro.runtime.telemetry.Tracer, duck-typed): receives
        # one "admit" event per slot grant and one "request" event per
        # completion, keyed by tenant — the observed per-tenant p99 that
        # fair_quantum quotas can consume instead of static budgets.
        # The session's serving-op events (prefill/decode) follow the
        # scheduler driving it: a scheduler with a tracer takes them over
        # (so a reused session's events don't keep flowing to a previous
        # run's tracer).
        self.tracer = tracer
        if tracer is not None:
            session.tracer = tracer
        self.tenants: Dict[str, Tenant] = {}
        self._order: List[str] = []      # registration order (rr pointer)
        self._rr_next = 0
        self.step_count = 0
        self.admitted_order: List[str] = []   # tenant id per admission
        # Per-tenant slot-cap overrides (tenant_id -> cap). Wins over the
        # QuotaPolicy: the SLO controller boosts a missing latency-class
        # tenant to the full budget for the enforcement episode.
        self.cap_overrides: Dict[str, int] = {}
        self._default_cap: Optional[int] = None
        self._t0: Optional[float] = None
        self._wall_s = 0.0

    # -- tenants / submission ----------------------------------------------
    def add_tenant(self, tenant_id: str, *, weight: float = 1.0,
                   policy: Optional[ex.ExecutionPolicy] = None,
                   slo: Union[None, str, Dict, SLO] = None) -> Tenant:
        if tenant_id in self.tenants:
            raise ValueError(f"tenant {tenant_id!r} already registered")
        if weight <= 0:
            raise ValueError("tenant weight must be positive")
        t = Tenant(tenant_id=tenant_id, weight=weight, policy=policy,
                   slo=SLO.parse(slo))
        self.tenants[tenant_id] = t
        self._order.append(tenant_id)
        self._default_cap = None         # advisor cap depends on tenancy
        if self.tracer is not None:
            # a registered-but-idle tenant must still be enumerable from
            # telemetry (it has no admit/request events of its own)
            self.tracer.record("register", tenant=tenant_id,
                               step=self.step_count,
                               meta={"weight": weight,
                                     "slo": t.slo.spec() if t.slo else ""})
        return t

    def freeze(self, tenant_id: str) -> None:
        """Stop admitting ``tenant_id`` (drain mode: in-flight requests
        keep decoding, queued/new requests wait). The serving runtime
        freezes a tenant on its source partition while migrating it."""
        self.tenants[tenant_id].frozen = True

    def thaw(self, tenant_id: str) -> None:
        self.tenants[tenant_id].frozen = False

    def remove_tenant(self, tenant_id: str) -> Tenant:
        """Detach a fully drained tenant (no queue, no active slots) and
        return its record — the migration path folds it into the target
        partition's record. Raises if the tenant still has work here."""
        t = self.tenants[tenant_id]
        if t.queue or t.active:
            raise ValueError(
                f"tenant {tenant_id!r} still has {len(t.queue)} queued / "
                f"{t.active} active requests on this scheduler")
        del self.tenants[tenant_id]
        self._order.remove(tenant_id)
        self._default_cap = None
        if self._order:
            self._rr_next %= len(self._order)
        else:
            self._rr_next = 0
        return t

    def submit(self, tenant_id: str, req: Request):
        t = self.tenants[tenant_id]
        req.tenant = tenant_id
        req.submit_t = time.perf_counter()
        req.submit_step = self.step_count
        req._arrival = next(_ARRIVALS)   # global deterministic fifo tiebreak
        t.submitted += 1
        if t.first_submit_step < 0:
            t.first_submit_step = self.step_count
        t.queue.append(req)

    def pending(self) -> int:
        return sum(len(t.queue) for t in self.tenants.values())

    def _advisor_cap(self) -> int:
        if self._default_cap is None:
            # §9.2 default quota: the advisor's stream cap for a
            # latency-sensitive workload with this many co-tenants.
            cfg = self.session.cfg
            advice = self.advisor.advise(cc.WorkloadProfile(
                precision=cfg.precision,
                grid_tiles=ex.grid_tiles(self.session.batch_slots, cfg.d_ff),
                latency_sensitive=True,
                concurrent_tenants=max(1, len(self.tenants))))
            self._default_cap = max(1, advice.max_streams)
        return self._default_cap

    def _slot_cap(self, t: Tenant) -> int:
        override = self.cap_overrides.get(t.tenant_id)
        if override is not None:
            return max(1, override)
        return self.quota.slot_cap(self, t)

    # -- admission policies -------------------------------------------------
    def _admissible(self) -> List[Tenant]:
        return [self.tenants[tid] for tid in self._order
                if self.tenants[tid].queue
                and not self.tenants[tid].frozen
                and self.tenants[tid].active
                < self._slot_cap(self.tenants[tid])]

    def _pick(self) -> Optional[Tenant]:
        cands = self._admissible()
        if not cands:
            return None
        if self.admission == "fifo":
            return min(cands, key=lambda t: t.queue[0]._arrival)
        if self.admission == "round_robin":
            n = len(self._order)
            for off in range(n):
                tid = self._order[(self._rr_next + off) % n]
                t = self.tenants[tid]
                if t in cands:
                    self._rr_next = (self._order.index(tid) + 1) % n
                    return t
            return None
        # fair_quantum: lowest virtual time wins; ties resolved by
        # registration order (stable because _admissible preserves it).
        return min(cands, key=lambda t: t.vtime)

    def _admit_free_slots(self):
        # Admission gates on the session's full headroom check — on paged
        # sessions that is free-*page* headroom for the candidate's prompt,
        # not just a free slot (dense can_admit ≡ has_free_slot).
        while self.session.has_free_slot():
            t = self._pick()
            if t is None:
                break
            if not self.session.can_admit(t.queue[0]):
                break
            req = t.queue.pop(0)
            self.session.admit(req)
            req.admit_step = self.step_count
            self.admitted_order.append(t.tenant_id)
            if self.tracer is not None:
                self.tracer.record("admit", tenant=t.tenant_id,
                                   step=self.step_count,
                                   meta={"uid": req.uid,
                                         "cost": request_cost(req)})
            if self.admission == "fair_quantum":
                t.vtime += request_cost(req) / t.weight
            if req.done:                 # completed at admission (max_new=1)
                self._finish(t, req)
            else:
                t.active += 1

    def _finish(self, t: Tenant, req: Request):
        req.finish_step = self.step_count
        t.completed.append(req)
        t.tokens_out += len(req.out)
        if self.tracer is not None:
            self.tracer.record_request(
                t.tenant_id, wall_s=req.latency_s, tokens=len(req.out),
                turnaround_steps=req.finish_step - req.submit_step,
                step=self.step_count, uid=req.uid)

    # -- driving ------------------------------------------------------------
    def dispatch_step(self, lane: Optional[cc.ExecutionLane] = None, *,
                      overlap_group: int = -1):
        """Dispatch half of one scheduler step: quota refresh + admission
        (host work, including any prefill), then the decode enqueued
        through ``lane``. Returns the session's
        :class:`~repro.runtime.serve_loop.DecodeTicket`; pass it to
        :meth:`join_step` exactly once. The split lets the serving runtime
        co-dispatch heterogeneous partitions before joining any of them."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        self.quota.on_step(self)
        self._admit_free_slots()
        return self.session.dispatch_decode(lane,
                                            overlap_group=overlap_group)

    def join_step(self, ticket) -> List[Request]:
        """Join half of one scheduler step: block on the ticket, then the
        same per-tenant accounting as the synchronous path."""
        done = self.session.join_decode(ticket)
        self.step_count += 1
        for t in self.tenants.values():
            if t.active:
                t.service_steps += 1
        drain = getattr(self.session, "drain_spec_deltas", None)
        if drain is not None:
            for tenant, drafted, accepted in drain():
                t = self.tenants.get(tenant)
                if t is None:
                    continue
                t.spec_steps += 1
                t.spec_drafted += drafted
                t.spec_accepted += accepted
        for req in done:
            t = self.tenants[req.tenant]
            t.active -= 1
            self._finish(t, req)
            if (t.active == 0 and not t.queue
                    and getattr(self.session, "adaptive_k", None)
                    is not None):
                # a drained tenant must stop constraining the batch-wide
                # adaptive speculation depth
                self.session.adaptive_k.forget(t.tenant_id)
        self._wall_s = time.perf_counter() - self._t0
        return done

    def step(self) -> List[Request]:
        """Fill free slots per the admission policy, then one decode step.
        Returns the requests that completed this step."""
        return self.join_step(self.dispatch_step())

    def run(self, max_steps: int = 100_000) -> List[Request]:
        """Drive until every queue is drained and every slot is free."""
        while (self.pending() or self.session.n_active) \
                and self.step_count < max_steps:
            self.step()
        return [r for t in self.tenants.values() for r in t.completed]

    # -- telemetry ----------------------------------------------------------
    def report(self) -> SchedulerReport:
        per_tenant: List[TenantReport] = []
        turnarounds: List[float] = []
        for tid in self._order:
            row, contrib = build_tenant_report(
                tid, [self.tenants[tid]], self.step_count)
            per_tenant.append(row)
            if contrib is not None:
                turnarounds.append(contrib)
        busy = sum(t.service_steps for t in self.tenants.values())
        return SchedulerReport(
            admission=self.admission,
            quota=self.quota.name,
            n_tenants=len(self.tenants),
            steps=self.step_count,
            wall_s=self._wall_s,
            tokens_out=sum(t.tokens_out for t in self.tenants.values()),
            fairness=cc.fairness(turnarounds),
            fairness_min_max=cc.fairness_min_max(turnarounds),
            cv=cc.cv(turnarounds),
            overlap_efficiency=cc.overlap_efficiency(
                float(busy), float(self.step_count), len(self.tenants)),
            tenants=per_tenant)


def run_tenants(session: ServeSession, workloads: Dict[str, Sequence[Request]],
                *, admission: str = "fair_quantum",
                weights: Optional[Dict[str, float]] = None,
                policies: Optional[Dict[str, ex.ExecutionPolicy]] = None,
                max_steps: int = 100_000, tracer=None,
                quota: Union[None, str, QuotaPolicy] = None
                ) -> SchedulerReport:
    """One-shot helper: register tenants, submit their workloads up front,
    run to completion, return the report (benchmarks and the launcher)."""
    sched = StreamScheduler(session, admission=admission, tracer=tracer,
                            quota=quota)
    for tid in workloads:
        sched.add_tenant(tid, weight=(weights or {}).get(tid, 1.0),
                         policy=(policies or {}).get(tid))
    for tid, reqs in workloads.items():
        for req in reqs:
            sched.submit(tid, req)
    sched.run(max_steps=max_steps)
    return sched.report()
