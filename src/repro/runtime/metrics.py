"""Labeled metrics registry + the Tracer→metrics bridge.

The observability plane's *metric* surface (the event surface is
:mod:`repro.runtime.telemetry`, the visual surface is
:mod:`repro.runtime.traceview`). The paper's running argument is that
MI300A performance is only predictable when occupancy, concurrency, and
sparsity effects are continuously *measured* — this module turns the
Tracer's event stream into the continuously-scrapable form dashboards
and CI gates consume:

* :class:`Counter` / :class:`Gauge` / :class:`Histogram` — labeled
  instruments. Histograms use explicit bucket bounds (cumulative
  Prometheus semantics: each bucket counts observations ≤ its bound,
  ``+Inf`` implicit).
* :class:`MetricsRegistry` — get-or-create instrument registry with
  ``snapshot()`` (JSON-safe dict) and ``to_prometheus()`` (text
  exposition format) so one registry serves both the ``--metrics-out``
  artifact and a scrape endpoint.
* :class:`MetricsSink` — subscribes to one or more Tracers
  (:meth:`~repro.runtime.telemetry.Tracer.add_sink`) and folds every
  event into the standard instrument set: decode/prefill latency
  histograms, per-tenant token/request counters, pages-in-use and
  fragmentation gauges, migration counters, overlap-efficiency gauges,
  and ring-eviction (dropped) counters. Counters are driven by the same
  per-event stream as the Tracer's monotonic counts, so the two stay
  exact together past ring eviction.
* :func:`observe_runtime` — fold a ``ServingRuntime`` report's derived
  signals (per-tenant SLO attainment, fairness, per-partition occupancy
  fill and backlog) into gauges; the live dashboard
  (:mod:`repro.launch.top`) calls it each refresh.

Wiring: ``ServingSpec(metrics=True)`` builds a registry + sink attached
to every partition tracer; ``launch/serve.py --metrics-out`` writes the
snapshot (or Prometheus text for ``.prom``/``.txt`` paths) at exit.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import concurrency as cc

PREFIX = "repro_"

# Default latency buckets (seconds): serving decode/prefill steps on CPU
# CI land around 1-100ms; real-hardware steps land in the small-ms range.
LATENCY_BUCKETS_S = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                     0.1, 0.25, 0.5, 1.0, 2.5)
# Turnaround buckets (deterministic scheduler steps).
STEP_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)
SPEC_COMMIT_BUCKETS = (1.0, 2.0, 3.0, 4.0, 6.0, 8.0)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


def _fmt(v: float) -> str:
    """Prometheus sample rendering: integers without a trailing ``.0`` so
    golden-text tests stay readable."""
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


class _Metric:
    """Shared labeled-series bookkeeping. Thread-safe: serving loops and
    lane joins record concurrently."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        if not name or not name.replace("_", "a").isalnum():
            raise ValueError(f"metric name {name!r} must be [a-z0-9_]+")
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: Dict[LabelKey, Any] = {}

    def labels(self) -> List[LabelKey]:
        with self._lock:
            return sorted(self._series)

    def _expose(self) -> List[Tuple[str, str, float]]:
        """(suffix, label-string, value) rows for the text exposition."""
        with self._lock:
            return [("", _label_str(k), v)
                    for k, v in sorted(self._series.items())]

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {(_label_str(k) or "total"): v
                    for k, v in sorted(self._series.items())}


class Counter(_Metric):
    """Monotonically increasing labeled counter."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(_label_key(labels), 0.0))


class Gauge(_Metric):
    """Labeled gauge (set/inc/dec)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._series[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels) -> Optional[float]:
        with self._lock:
            v = self._series.get(_label_key(labels))
            return None if v is None else float(v)


class Histogram(_Metric):
    """Labeled histogram over explicit bucket upper bounds.

    Prometheus cumulative-bucket semantics: ``bucket_counts[i]`` counts
    observations ≤ ``buckets[i]`` and the implicit ``+Inf`` bucket equals
    ``count``. ``snapshot()`` additionally derives non-cumulative per-bin
    counts for human consumption."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = LATENCY_BUCKETS_S):
        super().__init__(name, help)
        bs = [float(b) for b in buckets]
        if not bs or sorted(bs) != bs or len(set(bs)) != len(bs):
            raise ValueError("buckets must be non-empty, sorted, unique")
        self.buckets = tuple(bs)

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = {"bucket_counts": [0] * len(self.buckets),
                     "count": 0, "sum": 0.0}
                self._series[key] = s
            for i, bound in enumerate(self.buckets):
                if value <= bound:
                    s["bucket_counts"][i] += 1
            s["count"] += 1
            s["sum"] += float(value)

    def value(self, **labels) -> Optional[Dict[str, Any]]:
        with self._lock:
            s = self._series.get(_label_key(labels))
            return None if s is None else {
                "bucket_counts": list(s["bucket_counts"]),
                "count": s["count"], "sum": s["sum"]}

    def _expose(self) -> List[Tuple[str, str, float]]:
        rows: List[Tuple[str, str, float]] = []
        with self._lock:
            for key, s in sorted(self._series.items()):
                for bound, n in zip(self.buckets, s["bucket_counts"]):
                    lab = dict(key) | {"le": _fmt(bound)}
                    rows.append(("_bucket", _label_str(_label_key(lab)),
                                 float(n)))
                lab = dict(key) | {"le": "+Inf"}
                rows.append(("_bucket", _label_str(_label_key(lab)),
                             float(s["count"])))
                rows.append(("_sum", _label_str(key), s["sum"]))
                rows.append(("_count", _label_str(key), float(s["count"])))
        return rows

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            out = {}
            for key, s in sorted(self._series.items()):
                cum = s["bucket_counts"]
                out[_label_str(key) or "total"] = {
                    "buckets": list(self.buckets),
                    "bucket_counts": list(cum),
                    "per_bin": [c - p for c, p in zip(cum, [0] + cum[:-1])]
                    + [s["count"] - (cum[-1] if cum else 0)],
                    "count": s["count"],
                    "sum": round(s["sum"], 9),
                    "mean": round(s["sum"] / s["count"], 9)
                    if s["count"] else 0.0,
                }
            return out


class MetricsRegistry:
    """Get-or-create registry of named instruments.

    Re-requesting a name returns the existing instrument (and raises if
    the kind differs) so producers across modules share series without
    plumbing instrument handles around."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}, "
                        f"requested {cls.kind}")
                return m
            m = cls(name, help, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = LATENCY_BUCKETS_S) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    # -- exposition ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-safe view of every instrument: the ``--metrics-out``
        artifact and the dashboard's data source."""
        with self._lock:
            metrics = dict(self._metrics)
        return {name: {"kind": m.kind, "help": m.help,
                       "series": m.snapshot()}
                for name, m in sorted(metrics.items())}

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (one HELP/TYPE header per
        metric, deterministic series order)."""
        with self._lock:
            metrics = dict(self._metrics)
        lines: List[str] = []
        for name, m in sorted(metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for suffix, labels, value in m._expose():
                lines.append(f"{name}{suffix}{labels} {_fmt(value)}")
        return "\n".join(lines) + "\n"

    def save(self, path: str) -> str:
        text = self.to_prometheus() if path.endswith((".prom", ".txt")) \
            else self.to_json() + "\n"
        with open(path, "w") as f:
            f.write(text)
        return path


# ---------------------------------------------------------------------------
# The Tracer -> metrics bridge
# ---------------------------------------------------------------------------

class MetricsSink:
    """Subscribes to Tracer ``_ingest`` (via ``Tracer.add_sink``) and
    populates the standard serving instrument set.

    Every event increments ``repro_events_total{kind=...}`` — driven by
    the same stream as the Tracer's monotonic per-kind counters, so the
    two agree exactly even after ring eviction (the accounting contract
    ``tests/test_observability.py`` pins). Dropped (ring-evicted) events
    land in ``repro_events_dropped_total{kind=...}`` through the
    ``on_drop`` hook.

    ``migrate`` events are recorded on *both* endpoints' tracers for
    provenance; the sink counts each phase once (on the source
    partition's tracer) so migration counters don't double."""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        r = self.registry
        self.events = r.counter(PREFIX + "events_total",
                                "telemetry events by kind")
        self.dropped = r.counter(PREFIX + "events_dropped_total",
                                 "tracer ring evictions by kind")
        self.decode_lat = r.histogram(
            PREFIX + "decode_latency_seconds",
            "decode-step wall time", buckets=LATENCY_BUCKETS_S)
        self.prefill_lat = r.histogram(
            PREFIX + "prefill_latency_seconds",
            "prefill (admission) wall time", buckets=LATENCY_BUCKETS_S)
        self.turnaround = r.histogram(
            PREFIX + "request_turnaround_steps",
            "request submit->finish in scheduler steps",
            buckets=STEP_BUCKETS)
        self.requests = r.counter(PREFIX + "requests_total",
                                  "completed requests per tenant")
        self.tokens = r.counter(PREFIX + "tenant_tokens_total",
                                "generated tokens per tenant")
        self.admissions = r.counter(PREFIX + "admissions_total",
                                    "slot grants per tenant")
        self.migrations = r.counter(PREFIX + "migrations_total",
                                    "migration lifecycle events by phase")
        self.handoff_bytes = r.counter(
            PREFIX + "migration_handoff_bytes_total",
            "bytes moved by live slot handoffs")
        self.pages_in_use = r.gauge(PREFIX + "pages_in_use",
                                    "allocator pages currently allocated")
        self.page_util = r.gauge(PREFIX + "page_utilization",
                                 "written positions / allocated capacity")
        self.page_frag = r.gauge(PREFIX + "page_fragmentation",
                                 "1 - utilization of allocated pages")
        self.page_oom = r.counter(PREFIX + "page_oom_total",
                                  "pool-exhaustion refusals")
        self.overlap_groups = r.counter(PREFIX + "overlap_groups_total",
                                        "planner co-dispatch pairings")
        self.overlap_eff = r.gauge(
            PREFIX + "overlap_efficiency",
            "latest per-group overlap efficiency (sum/max walls)")
        self.overlap_speedup = r.gauge(
            PREFIX + "overlap_speedup",
            "latest per-group serial/concurrent wall ratio")
        self.spec_drafted = r.counter(
            PREFIX + "spec_drafted_total",
            "draft tokens proposed per tenant (speculative decode)")
        self.spec_accepted = r.counter(
            PREFIX + "spec_accepted_total",
            "draft tokens the bf16 verify accepted per tenant")
        self.spec_committed = r.histogram(
            PREFIX + "spec_committed_tokens",
            "tokens committed per speculative step per tenant",
            buckets=SPEC_COMMIT_BUCKETS)
        self.controller_actions = r.counter(
            PREFIX + "controller_actions_total",
            "SLO-controller actions (freeze/thaw/boost/unboost) by kind")
        self._group_walls: Dict[int, List[float]] = {}
        self._glock = threading.Lock()

    # -- subscription -------------------------------------------------------
    def attach(self, *tracers) -> "MetricsSink":
        for tr in tracers:
            tr.add_sink(self)
        return self

    # -- the event fold -----------------------------------------------------
    def on_drop(self, kind: str) -> None:
        self.dropped.inc(kind=kind)

    def on_event(self, ev) -> None:
        part = str(ev.partition)
        self.events.inc(kind=ev.kind)
        if ev.kind == "decode" and ev.wall_s > 0:
            self.decode_lat.observe(ev.wall_s, partition=part)
        elif ev.kind == "prefill" and ev.wall_s > 0:
            self.prefill_lat.observe(ev.wall_s, partition=part)
        elif ev.kind == "request":
            tenant = ev.tenant or "?"
            self.requests.inc(tenant=tenant)
            self.tokens.inc(int(ev.meta.get("tokens", 0)), tenant=tenant)
            ta = ev.meta.get("turnaround_steps", -1)
            if ta is not None and ta >= 0:
                self.turnaround.observe(float(ta), tenant=tenant)
        elif ev.kind == "admit":
            self.admissions.inc(tenant=ev.tenant or "?")
        elif ev.kind == "migrate":
            # recorded on both endpoint tracers: count once, at the source
            if ev.partition == ev.meta.get("src"):
                phase = ev.meta.get("phase", "?")
                self.migrations.inc(phase=phase,
                                    src=str(ev.meta.get("src")),
                                    dst=str(ev.meta.get("dst")))
                if phase == "handoff":
                    self.handoff_bytes.inc(
                        int(ev.meta.get("handoff_bytes", 0)))
        elif ev.kind == "spec":
            tenant = ev.tenant or "?"
            self.spec_drafted.inc(int(ev.meta.get("drafted", 0)),
                                  tenant=tenant)
            self.spec_accepted.inc(int(ev.meta.get("accepted", 0)),
                                   tenant=tenant)
            committed = ev.meta.get("committed")
            if committed:
                self.spec_committed.observe(float(committed), tenant=tenant)
        elif ev.kind == "controller":
            self.controller_actions.inc(
                action=str(ev.meta.get("action", "?")),
                tenant=ev.tenant or "?")
        elif ev.kind == "paging":
            if ev.meta.get("phase") == "page_oom":
                self.page_oom.inc(partition=part)
            if "pages_in_use" in ev.meta:
                self.pages_in_use.set(ev.meta["pages_in_use"],
                                      partition=part)
            if "utilization" in ev.meta:
                self.page_util.set(ev.meta["utilization"], partition=part)
            if "fragmentation" in ev.meta:
                self.page_frag.set(ev.meta["fragmentation"],
                                   partition=part)
        if ev.overlap_group >= 0 and ev.wall_s > 0:
            with self._glock:
                walls = self._group_walls.setdefault(ev.overlap_group, [])
                walls.append(ev.wall_s)
                if len(walls) == 2:
                    self.overlap_groups.inc()
                if len(walls) >= 2:
                    serial, conc = float(sum(walls)), float(max(walls))
                    self.overlap_eff.set(cc.overlap_efficiency(
                        serial, conc, len(walls)))
                    self.overlap_speedup.set(
                        serial / conc if conc > 0 else 0.0)


# ---------------------------------------------------------------------------
# Report-derived gauges (SLO attainment, fairness, occupancy)
# ---------------------------------------------------------------------------

def observe_runtime(registry: MetricsRegistry, runtime,
                    report=None) -> Dict[str, Any]:
    """Fold a ``ServingRuntime``'s current report into gauges: per-tenant
    SLO attainment (from ``Tracer.tenant_percentiles``-backed report
    rows), cross-partition fairness, tokens/steps, and per-partition
    occupancy fill + backlog. Returns the report's dict for callers that
    render both (the dashboard)."""
    rep = report if report is not None else runtime.report()
    g_att = registry.gauge(PREFIX + "slo_attainment",
                           "per-tenant SLO attainment ratio [0,1]")
    g_fair = registry.gauge(PREFIX + "tenant_fairness",
                            "cross-partition turnaround fairness index")
    g_tok = registry.gauge(PREFIX + "tokens_out",
                           "total generated tokens")
    g_steps = registry.gauge(PREFIX + "scheduler_steps",
                             "global lockstep step count")
    g_fill = registry.gauge(PREFIX + "occupancy_fill",
                            "mean observed grid-tile fill (x cores)")
    g_backlog = registry.gauge(PREFIX + "backlog_requests",
                               "queued + in-flight requests")
    g_acc = registry.gauge(PREFIX + "spec_acceptance_rate",
                           "per-tenant draft acceptance ratio [0,1]")
    g_eff = registry.gauge(PREFIX + "spec_effective_tokens_per_step",
                           "per-tenant committed tokens per speculative "
                           "step")
    g_fair.set(rep.fairness)
    g_tok.set(rep.tokens_out)
    g_steps.set(rep.steps)
    for row in rep.tenants:
        if row.slo_attainment is not None:
            g_att.set(row.slo_attainment, tenant=row.tenant_id,
                      slo=row.slo or "none")
        if row.acceptance_rate is not None:
            g_acc.set(row.acceptance_rate, tenant=row.tenant_id)
        if row.effective_tokens_per_step is not None:
            g_eff.set(row.effective_tokens_per_step, tenant=row.tenant_id)
    n_cores = cc.detect_core_count()
    for i, tr in enumerate(runtime.tracers):
        fill = tr.mean_fill(n_cores)
        if fill is not None:
            g_fill.set(fill, partition=str(i))
        sched = runtime.schedulers[i]
        g_backlog.set(sched.pending() + sched.session.n_active,
                      partition=str(i))
    return rep.to_dict()
