"""DEPRECATED facade — the partitioned serving entry point of PR 4.

The control plane moved to :mod:`repro.runtime.server`: construct a
:class:`~repro.runtime.server.ServingRuntime` from a declarative
:class:`~repro.runtime.server.ServingSpec` instead (per-partition
execution policies, admission/quota, placement, live tenant migration —
see docs/serving_api.md for the migration guide). This module keeps the
old names importable for one release:

* :class:`DevicePartition` / :func:`make_partitions` /
  :class:`PartitionedReport` — re-exported from ``runtime.server``
  (unchanged semantics).
* :class:`PartitionedServer` — a thin shim over ``ServingRuntime`` with
  the legacy constructor signature and the ``run()`` verb (now
  ``drain()``). Emits a :class:`DeprecationWarning`.
* :func:`run_partitioned` — delegates to
  :func:`~repro.runtime.server.run_serving`.

Behavioral note: the runtime steps partitions in LOCKSTEP (every
partition ticks every round — the documented model the old facade only
approximated), which keeps request step accounting in one global domain
so fairness/turnaround stay exact across live migrations.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional, Sequence, Union

from repro.runtime.server import (           # noqa: F401 — re-exports
    PLACEMENTS, DevicePartition, MigrationRecord, PartitionedReport,
    PartitionSpec, ServingRuntime, ServingSpec, make_partitions,
    run_serving)
from repro.runtime.scheduler import QuotaPolicy
from repro.runtime.serve_loop import Request


def _warn(old: str) -> None:
    warnings.warn(
        f"{old} is deprecated: build a ServingRuntime from a ServingSpec "
        "(repro.runtime.server); see docs/serving_api.md for the "
        "migration guide",
        DeprecationWarning, stacklevel=3)


class PartitionedServer:
    """Deprecated shim: the PR 4 facade over the new control plane.

    All attributes (``schedulers``/``sessions``/``tracers``/
    ``tenant_partition``/``report``/``merged_tracer``/…) delegate to the
    wrapped :class:`~repro.runtime.server.ServingRuntime`; ``run`` maps to
    ``drain``."""

    def __init__(self, params, cfg, *, n_partitions: int = 1,
                 batch_slots: int = 4, max_len: int = 128, rt=None,
                 placement: str = "spread",
                 admission: str = "fair_quantum",
                 quota: Union[None, str, QuotaPolicy, Sequence] = None,
                 temperature: float = 0.0, seed: int = 0, policy=None,
                 partitions: Optional[Sequence[DevicePartition]] = None,
                 tracer_capacity: int = 4096, session_kw=None):
        _warn("PartitionedServer")
        n = n_partitions if partitions is None else len(partitions)
        spec = ServingSpec(
            partitions=tuple(PartitionSpec(admission=admission)
                             for _ in range(max(1, n))),
            placement=placement, batch_slots=batch_slots, max_len=max_len,
            temperature=temperature, seed=seed)
        self._runtime = ServingRuntime(
            params, cfg, spec, rt=rt, policy=policy, quota=quota,
            partitions=partitions, tracer_capacity=tracer_capacity,
            session_kw=session_kw)

    @property
    def runtime(self) -> ServingRuntime:
        return self._runtime

    def run(self, max_steps: int = 100_000):
        return self._runtime.drain(max_steps=max_steps)

    def __getattr__(self, name):
        return getattr(self._runtime, name)


def run_partitioned(params, cfg, workloads: Dict[str, Sequence[Request]],
                    *, n_partitions: int = 1,
                    placement: str = "spread",
                    admission: str = "fair_quantum",
                    quota: Union[None, str] = None,
                    weights: Optional[Dict[str, float]] = None,
                    max_steps: int = 100_000, batch_slots: int = 4,
                    max_len: int = 128, rt=None, **server_kw
                    ) -> PartitionedReport:
    """Deprecated one-shot helper — use
    :func:`~repro.runtime.server.run_serving` with a ServingSpec."""
    _warn("run_partitioned")
    spec = ServingSpec(
        partitions=tuple(PartitionSpec(admission=admission)
                         for _ in range(max(1, n_partitions))),
        placement=placement, batch_slots=batch_slots, max_len=max_len,
        seed=server_kw.pop("seed", 0),
        temperature=server_kw.pop("temperature", 0.0))
    return run_serving(params, cfg, spec, workloads, weights=weights,
                       max_steps=max_steps, rt=rt, quota=quota, **server_kw)
