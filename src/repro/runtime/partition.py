"""Partitioned serving runtime: spatial sub-mesh partitions + tenant router.

The paper's §6/§9.2 guidance — and AMD's Instinct partitioning modes
(CPX/NPS, see the partitioning-guide study in PAPERS.md) — is that a
large accelerator node should *place* latency-sensitive streams onto
disjoint sub-device partitions instead of funneling everything through
one shared queue: partition-local execution is the difference between
predictable and collapsed tail latency. This module is that placement
layer for the serving stack:

* :class:`DevicePartition` — one disjoint device subset, derived from
  ``jax.devices()``. On a single-device container (CPU CI) the partitions
  are *logical*: they share the physical device but keep fully separate
  sessions/schedulers/tracers, so every behavioral property (routing,
  quotas, fused telemetry) runs under tier-1 tests.
* :class:`PartitionedServer` — owns one
  :class:`~repro.runtime.serve_loop.ServeSession` +
  :class:`~repro.runtime.scheduler.StreamScheduler` + partition-tagged
  :class:`~repro.runtime.telemetry.Tracer` per partition, routes tenants
  to partitions via a pluggable placement policy, and exposes the same
  ``submit / step / run / report`` facade as a single scheduler — existing
  callers move over by constructing this instead.

Placement policies (tenant → partition, pinned at registration):

* ``packed``     — fill partition 0 up to its slot budget, then 1, …
  (maximizes batch occupancy per partition; the throughput extreme).
* ``spread``     — least-loaded by registered tenant weight, ties by
  partition index (maximizes isolation; the latency extreme).
* ``load_aware`` — least *measured* load: registered weight plus each
  partition tracer's decode-wall EMA signal, so placement follows
  observed congestion rather than static counts. With no traffic yet it
  degrades to ``spread`` — placement stays deterministic for a fixed
  registration sequence.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

from repro.core import concurrency as cc
from repro.core import execution as ex
from repro.runtime import telemetry
from repro.runtime.scheduler import (
    QuotaPolicy, SchedulerReport, StreamScheduler)
from repro.runtime.serve_loop import Request, ServeSession

PLACEMENTS = ("packed", "spread", "load_aware")


# ---------------------------------------------------------------------------
# Device partitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DevicePartition:
    """One spatial partition: a disjoint device subset (possibly shared
    with other partitions only in the single-device logical fallback)."""
    index: int
    devices: tuple = ()
    logical: bool = False            # True: single-device fallback

    @property
    def label(self) -> str:
        kind = "logical" if self.logical else "devices"
        return f"partition{self.index}({kind}:{len(self.devices)})"


def make_partitions(n: int, devices: Optional[Sequence] = None
                    ) -> List[DevicePartition]:
    """Split the attached devices into ``n`` disjoint partitions.

    With at least ``n`` devices each partition gets ``len(devices)//n`` of
    them (remainder devices go to the leading partitions, mirroring
    ``run_spatial``'s subset semantics). With fewer — the CPU CI case —
    every partition is *logical*: it references the same device set but
    the serving state (session, scheduler, tracer) is fully per-partition,
    which is what the behavioral contracts test."""
    if n <= 0:
        raise ValueError("need at least one partition")
    if devices is None:
        import jax
        try:
            devices = tuple(jax.devices())
        except Exception:  # noqa: BLE001 — no backend: logical partitions
            devices = ()
    devices = tuple(devices)
    if len(devices) < n:
        return [DevicePartition(index=i, devices=devices, logical=True)
                for i in range(n)]
    per, extra = divmod(len(devices), n)
    parts, at = [], 0
    for i in range(n):
        take = per + (1 if i < extra else 0)
        parts.append(DevicePartition(index=i,
                                     devices=devices[at:at + take]))
        at += take
    return parts


# ---------------------------------------------------------------------------
# Fused report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PartitionedReport:
    """One fused view over all partitions.

    ``fairness``/``cv`` are the paper indices over *every* tenant's mean
    turnaround (step domain), regardless of which partition served it —
    cross-partition fairness is exactly what partitioning is supposed to
    buy. ``steps`` is the max over partitions (they step in lockstep from
    ``run``), ``tokens_out`` the sum."""
    placement: str
    admission: str
    quota: str
    n_partitions: int
    n_tenants: int
    steps: int
    wall_s: float
    tokens_out: int
    fairness: float
    cv: float
    tenant_partition: Dict[str, int]
    partitions: List[SchedulerReport]

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        lines = [
            f"[partitioned] {self.n_partitions} partitions "
            f"({self.placement}), {self.admission}/{self.quota}: "
            f"{self.n_tenants} tenants, {self.steps} steps, "
            f"{self.tokens_out} tokens in {self.wall_s:.2f}s | "
            f"fairness={self.fairness:.3f} cv={self.cv:.3f}"]
        for rep in self.partitions:
            for line in rep.summary().splitlines():
                lines.append("  " + line)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# The partitioned server
# ---------------------------------------------------------------------------

class PartitionedServer:
    """Many schedulers on one node, one facade.

    One :class:`ServeSession` + :class:`StreamScheduler` + partition-tagged
    :class:`Tracer` per :class:`DevicePartition`; tenants are routed to a
    partition at :meth:`add_tenant` time by the placement policy and stay
    pinned (their requests follow them). ``submit``/``step``/``run``/
    ``report`` mirror the single-scheduler API, so callers that drove a
    ``StreamScheduler`` directly keep working against this facade.

    Every partition's session is built from the same params/config/seed,
    so a tenant's token stream is independent of *which* partition serves
    it and of who shares the node — the multi-partition run equals the
    per-partition solo runs token-for-token (tested)."""

    def __init__(self, params, cfg, *, n_partitions: int = 1,
                 batch_slots: int = 4, max_len: int = 128, rt=None,
                 placement: str = "spread",
                 admission: str = "fair_quantum",
                 quota: Union[None, str, QuotaPolicy, Sequence] = None,
                 temperature: float = 0.0, seed: int = 0, policy=None,
                 partitions: Optional[Sequence[DevicePartition]] = None,
                 tracer_capacity: int = 4096, session_kw=None):
        if placement not in PLACEMENTS:
            raise ValueError(f"placement {placement!r} not in {PLACEMENTS}")
        self.placement = placement
        self.admission = admission
        self.partitions = list(partitions) if partitions is not None \
            else make_partitions(n_partitions)
        self.batch_slots = batch_slots
        if isinstance(quota, (list, tuple)):
            if len(quota) != len(self.partitions):
                raise ValueError(
                    f"quota sequence has {len(quota)} entries for "
                    f"{len(self.partitions)} partitions")
            # string/None specs are instantiated fresh per partition and
            # may repeat; only *instances* carry per-scheduler state
            insts = [q for q in quota if isinstance(q, QuotaPolicy)]
            if len(set(map(id, insts))) != len(insts):
                raise ValueError(
                    "the quota sequence repeats a QuotaPolicy instance "
                    "across partitions; online policies keep "
                    "per-scheduler state — pass one instance per "
                    "partition")
        if isinstance(policy, ex.ExecutionPolicy) \
                and policy.sparsity == "sparse24":
            # prune+pack the shared weights ONCE here; each session's own
            # pack pass then finds only PackedWeight leaves (no-op walk)
            # instead of re-packing the full model per partition
            params = ex.pack_model_params(params)
        self.tracers: List[telemetry.Tracer] = []
        self.sessions: List[ServeSession] = []
        self.schedulers: List[StreamScheduler] = []
        self.tenant_partition: Dict[str, int] = {}
        kw = dict(session_kw or {})
        if rt is not None:
            kw["rt"] = rt
        for part in self.partitions:
            tr = telemetry.Tracer(capacity=tracer_capacity,
                                  partition=part.index)
            sess = ServeSession(self._place_params(params, part), cfg,
                                batch_slots=batch_slots, max_len=max_len,
                                temperature=temperature, seed=seed,
                                policy=policy, telemetry=tr, **kw)
            sched = StreamScheduler(sess, admission=admission, tracer=tr,
                                    quota=self._quota_for(quota, part.index))
            self.tracers.append(tr)
            self.sessions.append(sess)
            self.schedulers.append(sched)

    @staticmethod
    def _place_params(params, part: DevicePartition):
        """Pin the model replica to the partition's lead device. Logical
        partitions (single-device fallback) share the original params —
        duplicating them would only waste the one device's memory."""
        if part.logical or not part.devices:
            return params
        import jax
        return jax.device_put(params, part.devices[0])

    @staticmethod
    def _quota_for(quota, index: int):
        """Quota spec per partition: a sequence is indexed, a string/None
        is instantiated *fresh* per partition (online policies keep
        per-partition state and must not be shared)."""
        if isinstance(quota, (list, tuple)):
            return quota[index]
        if isinstance(quota, QuotaPolicy):
            if index > 0:
                raise ValueError(
                    "a single QuotaPolicy instance cannot be shared across "
                    "partitions (it keeps per-scheduler state); pass a "
                    "string spec or one instance per partition")
            return quota
        return quota

    # -- routing ------------------------------------------------------------
    @property
    def n_partitions(self) -> int:
        return len(self.partitions)

    def _load(self, i: int) -> float:
        """Observed load of partition ``i``: registered tenant weight plus
        the tracer's measured decode signal (mean decode wall × outstanding
        work). Zero-traffic partitions score by weight alone."""
        sched = self.schedulers[i]
        weight = sum(t.weight for t in sched.tenants.values())
        backlog = sched.pending() + sched.session.n_active
        return weight + self.tracers[i].mean_wall("decode") * backlog

    def _route(self, weight: float) -> int:
        if self.placement == "packed":
            # first partition whose registered tenancy has not yet filled
            # its slot budget; once every budget is full, overflow goes to
            # the least-populated partition (ties to the lowest index)
            for i, sched in enumerate(self.schedulers):
                if len(sched.tenants) < self.sessions[i].batch_slots:
                    return i
            return min(range(self.n_partitions),
                       key=lambda i: (len(self.schedulers[i].tenants), i))
        if self.placement == "spread":
            return min(range(self.n_partitions),
                       key=lambda i: (sum(t.weight for t in
                                          self.schedulers[i]
                                          .tenants.values()), i))
        # load_aware: least measured load, ties by index
        return min(range(self.n_partitions),
                   key=lambda i: (self._load(i), i))

    def add_tenant(self, tenant_id: str, *, weight: float = 1.0,
                   policy=None, partition: Optional[int] = None) -> int:
        """Register a tenant and pin it to a partition (router-chosen
        unless ``partition`` forces one). Returns the partition index."""
        if tenant_id in self.tenant_partition:
            raise ValueError(f"tenant {tenant_id!r} already registered")
        idx = self._route(weight) if partition is None else partition
        self.schedulers[idx].add_tenant(tenant_id, weight=weight,
                                        policy=policy)
        self.tenant_partition[tenant_id] = idx
        self.tracers[idx].record("route", tenant=tenant_id,
                                 meta={"weight": weight,
                                       "placement": self.placement})
        return idx

    # -- facade (same verbs as StreamScheduler) -----------------------------
    def submit(self, tenant_id: str, req: Request) -> None:
        self.schedulers[self.tenant_partition[tenant_id]].submit(
            tenant_id, req)

    def pending(self) -> int:
        return sum(s.pending() for s in self.schedulers)

    @property
    def n_active(self) -> int:
        return sum(s.session.n_active for s in self.schedulers)

    def step(self) -> List[Request]:
        """One lockstep round: every partition with work advances one
        scheduler step. Returns all requests completed this round."""
        done: List[Request] = []
        for sched in self.schedulers:
            if sched.pending() or sched.session.n_active:
                done.extend(sched.step())
        return done

    def run(self, max_steps: int = 100_000) -> List[Request]:
        steps = 0
        while (self.pending() or self.n_active) and steps < max_steps:
            self.step()
            steps += 1
        return [r for sched in self.schedulers
                for t in sched.tenants.values() for r in t.completed]

    # -- fused telemetry ----------------------------------------------------
    def merged_tracer(self) -> telemetry.Tracer:
        """One fused event view over all partitions
        (:meth:`telemetry.Tracer.merge`; partition tags preserved)."""
        return telemetry.Tracer.merge(*self.tracers)

    def report(self) -> PartitionedReport:
        reps = [s.report() for s in self.schedulers]
        turnarounds = [t.mean_turnaround_steps
                       for rep in reps for t in rep.tenants
                       if t.completed]
        return PartitionedReport(
            placement=self.placement,
            admission=self.admission,
            quota="/".join(sorted({s.quota.name for s in self.schedulers})),
            n_partitions=self.n_partitions,
            n_tenants=sum(rep.n_tenants for rep in reps),
            steps=max((rep.steps for rep in reps), default=0),
            wall_s=max((rep.wall_s for rep in reps), default=0.0),
            tokens_out=sum(rep.tokens_out for rep in reps),
            fairness=cc.fairness(turnarounds),
            cv=cc.cv(turnarounds),
            tenant_partition=dict(self.tenant_partition),
            partitions=reps)


def run_partitioned(params, cfg, workloads: Dict[str, Sequence[Request]],
                    *, n_partitions: int = 1,
                    placement: str = "spread",
                    admission: str = "fair_quantum",
                    quota: Union[None, str] = None,
                    weights: Optional[Dict[str, float]] = None,
                    max_steps: int = 100_000,
                    **server_kw) -> PartitionedReport:
    """One-shot helper mirroring :func:`~repro.runtime.scheduler.
    run_tenants`: build the partitioned server, register + submit every
    tenant's workload, run to completion, return the fused report."""
    server = PartitionedServer(params, cfg, n_partitions=n_partitions,
                               placement=placement, admission=admission,
                               quota=quota, **server_kw)
    for tid in workloads:
        server.add_tenant(tid, weight=(weights or {}).get(tid, 1.0))
    for tid, reqs in workloads.items():
        for req in reqs:
            server.submit(tid, req)
    server.run(max_steps=max_steps)
    return server.report()
