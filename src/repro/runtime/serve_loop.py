"""Serving: prefill/decode step builders + continuous batching manager.

``make_serve_step``/``make_prefill_step`` produce the jittable functions the
dry-run lowers for the ``decode_*``/``prefill_*`` shapes. ``ServeSession``
implements paper-§9.2-style continuous batching on top ("vLLM-style,
requires ≥32 concurrent users" — the occupancy lever for FP8 serving):
requests join/leave slots between steps, each slot tracks its own length,
and FP8/2:4 weight compression applies per the configured policy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import execution as ex
from repro.models import decode_step, init_cache, prefill
from repro.models.layers import RuntimeCfg, DEFAULT_RT


def make_prefill_step(cfg: ArchConfig, rt: RuntimeCfg = DEFAULT_RT,
                      policy: Optional[ex.ExecutionPolicy] = None):
    if policy is not None:
        cfg, rt = ex.apply_policy(cfg, rt, policy)

    def prefill_step(params, inputs):
        return prefill(params, inputs, cfg, rt)
    return prefill_step


def make_serve_step(cfg: ArchConfig, rt: RuntimeCfg = DEFAULT_RT,
                    temperature: float = 0.0,
                    policy: Optional[ex.ExecutionPolicy] = None):
    """serve_step(params, tokens (B,1), caches, pos, rng) ->
    (next_tokens (B,1), logits, new_caches)."""
    if policy is not None:
        cfg, rt = ex.apply_policy(cfg, rt, policy)

    def serve_step(params, tokens, caches, pos, rng):
        logits, new_caches = decode_step(params, tokens, caches, pos, cfg, rt)
        if temperature > 0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, new_caches
    return serve_step


# ---------------------------------------------------------------------------
# Continuous batching (host-side slot manager)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (Lp,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeSession:
    """Fixed-slot continuous batching over a single shared KV cache.

    Slots run in lockstep positions (one global ``pos`` per step — each
    slot's own start offset is tracked so shorter requests simply mask).
    This is intentionally the simple production-shaped version: slot join =
    per-slot prefill write, slot leave = slot freed at EOS/max_new.
    """

    def __init__(self, params, cfg: ArchConfig, *, batch_slots: int,
                 max_len: int, rt: RuntimeCfg = DEFAULT_RT,
                 temperature: float = 0.0, eos_id: int = -1, seed: int = 0,
                 policy=None, auto_backend: Optional[str] = None,
                 verbose_policy: bool = False):
        if policy == "auto":
            # paper-§9.2 resolution at session construction: the dominant
            # decode GEMM is (slots, d_model, d_ff); decode is
            # latency-sensitive and each slot is a tenant.
            policy = ex.resolve_policy(
                batch_slots, cfg.d_model, cfg.d_ff,
                precision=cfg.precision, latency_sensitive=True,
                tenants=batch_slots, backend=auto_backend)
        if policy is not None:
            cfg, rt = ex.apply_policy(cfg, rt, policy)
            if policy.sparsity == "sparse24":
                # serving form of 2:4: prune+pack ONCE here so decode
                # streams packed weights (the §7 bandwidth win), instead
                # of re-pruning inside every jitted step
                params = ex.pack_model_params(params)
            if verbose_policy:
                print(f"[serve] policy: {policy.describe()}")
        self.policy = policy
        self.params = params
        self.cfg = cfg
        self.rt = rt
        self.max_len = max_len
        self.eos_id = eos_id
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.caches = init_cache(cfg, batch_slots, max_len)
        self.pos = 0
        self.step_fn = jax.jit(make_serve_step(cfg, rt, temperature))
        self.rng = jax.random.PRNGKey(seed)
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self.queue: List[Request] = []
        self.completed: List[Request] = []

    # -- request lifecycle -------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # feed prompt tokens one at a time from current pos (simple
                # token-by-token prefill keeps one jitted step; bulk prefill
                # is the make_prefill_step path used by the examples)
                toks = self.tokens
                for t in req.prompt:
                    toks = toks.at[i, 0].set(int(t))
                    self.tokens = toks
                    self._step_single()
                req._start = self.pos

    def _step_single(self):
        self.rng, sub = jax.random.split(self.rng)
        nxt, _, self.caches = self.step_fn(
            self.params, self.tokens, self.caches, self.pos, sub)
        self.pos += 1
        self.tokens = nxt

    def step(self):
        """One decode step for all active slots."""
        self._admit()
        if all(s is None for s in self.slots):
            return
        self.rng, sub = jax.random.split(self.rng)
        nxt, _, self.caches = self.step_fn(
            self.params, self.tokens, self.caches, self.pos, sub)
        self.pos += 1
        nxt_np = np.asarray(nxt[:, 0])
        self.tokens = nxt
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt_np[i])
            req.out.append(tok)
            if tok == self.eos_id or len(req.out) >= req.max_new \
                    or self.pos >= self.max_len:
                req.done = True
                self.completed.append(req)
                self.slots[i] = None

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps and self.pos < self.max_len - 1:
            self.step()
            steps += 1
        return self.completed
