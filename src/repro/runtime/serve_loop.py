"""Serving: prefill/decode step builders + continuous batching manager.

``make_serve_step``/``make_prefill_step`` produce the jittable functions the
dry-run lowers for the ``decode_*``/``prefill_*`` shapes. ``ServeSession``
implements paper-§9.2-style continuous batching on top ("vLLM-style,
requires ≥32 concurrent users" — the occupancy lever for FP8 serving):
requests join/leave slots between steps, each slot advances at its own
position, and FP8/2:4 weight compression applies per the configured policy.

Multi-tenant admission/fairness policy lives one layer up in
:mod:`repro.runtime.scheduler`; this module owns the slot mechanics it
builds on (``admit`` / ``decode_once`` / ``free_slot``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import concurrency as cc
from repro.core import execution as ex
from repro.core import paging
from repro.core import speculative as spv
from repro.models import (
    PAGED_KINDS, decode_step, init_cache, init_paged_cache, prefill)
from repro.models.transformer import paged_decode_step
from repro.models.layers import RuntimeCfg, DEFAULT_RT


def make_prefill_step(cfg: ArchConfig, rt: RuntimeCfg = DEFAULT_RT,
                      policy: Optional[ex.ExecutionPolicy] = None):
    if policy is not None:
        cfg, rt = ex.apply_policy(cfg, rt, policy)

    def prefill_step(params, inputs):
        return prefill(params, inputs, cfg, rt)
    return prefill_step


def make_serve_step(cfg: ArchConfig, rt: RuntimeCfg = DEFAULT_RT,
                    temperature: float = 0.0,
                    policy: Optional[ex.ExecutionPolicy] = None):
    """serve_step(params, tokens (B,1), caches, pos, rng) ->
    (next_tokens (B,1), logits, new_caches). ``pos`` is a scalar (lockstep)
    or a (B,) vector (continuous batching: per-slot positions)."""
    if policy is not None:
        cfg, rt = ex.apply_policy(cfg, rt, policy)

    def serve_step(params, tokens, caches, pos, rng):
        logits, new_caches = decode_step(params, tokens, caches, pos, cfg, rt)
        if temperature > 0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, new_caches
    return serve_step


def make_paged_serve_step(cfg: ArchConfig, rt: RuntimeCfg = DEFAULT_RT,
                          temperature: float = 0.0,
                          policy: Optional[ex.ExecutionPolicy] = None):
    """``make_serve_step`` over the paged cache layout: the step takes an
    extra ``page_map`` (B, max_pages) int32 operand (``-1`` = unallocated)
    and routes PAGED_KINDS attention through the pooled pages. Greedy
    sampling is identical — paged decode is bit-exact vs dense."""
    if policy is not None:
        cfg, rt = ex.apply_policy(cfg, rt, policy)

    def paged_serve_step(params, tokens, caches, pos, page_map, rng):
        logits, new_caches = paged_decode_step(params, tokens, caches, pos,
                                               page_map, cfg, rt)
        if temperature > 0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), logits, new_caches
    return paged_serve_step


# ---------------------------------------------------------------------------
# Continuous batching (host-side slot manager)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (Lp,) int32
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # Telemetry (filled by ServeSession/StreamScheduler; wall-clock seconds
    # from perf_counter, step indices in scheduler virtual time).
    tenant: Optional[str] = None
    submit_t: float = 0.0
    admit_t: float = 0.0
    finish_t: float = 0.0
    submit_step: int = -1
    admit_step: int = -1
    finish_step: int = -1

    @property
    def latency_s(self) -> float:
        return max(0.0, self.finish_t - self.submit_t)

    @property
    def queue_wait_s(self) -> float:
        return max(0.0, self.admit_t - self.submit_t)


@dataclasses.dataclass
class SlotExport:
    """One in-flight request's complete per-slot serving state, detached
    from its session: the KV/SSM cache slice (every cache leaf indexed at
    the slot's batch row), the slot-local write position, and the last
    sampled token (the next decode input). Produced by
    :meth:`ServeSession.export_slot`, consumed by
    :meth:`ServeSession.import_slot` — the live-migration cache handoff.
    Greedy decode resumes bit-exactly on the importing session as long as
    both sessions share (cfg, max_len) and an execution-compatible policy;
    sampled (temperature > 0) decode follows the importing session's RNG
    stream instead."""
    request: Request
    caches: Any                      # pytree: leaf shapes (n_layer, ...)
    pos: int
    token: int
    # Paged handoff metadata (0/0 on dense exports): paged leaves in
    # ``caches`` are shaped (n_layer, pages, page_size, ...) — only the
    # pages the slot actually wrote travel, so handoff volume is
    # O(pages-in-use), not O(max_len).
    pages: int = 0
    page_size: int = 0


def export_nbytes(export: SlotExport) -> int:
    """Bytes of cache state a handoff moves (the fig20 migration metric)."""
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(export.caches))


# Jitted step cache: sessions sharing (cfg, rt, temperature) share the
# compiled serve/prefill functions instead of re-tracing per session (the
# scheduler tests spin up many short-lived sessions over one tiny model).
# LRU-capped: a sweep over configs/policies/backends would otherwise pin
# every compiled step it ever built for the life of the process.
_JIT_CACHE: "OrderedDict[Any, Any]" = OrderedDict()
JIT_CACHE_MAX = 16


def clear_jit_cache() -> None:
    """Drop every cached jitted serve/prefill step (tests, sweeps)."""
    _JIT_CACHE.clear()


def _cached_jit(kind: str, maker: Callable[[], Callable], *key_parts):
    try:
        key = (kind,) + key_parts
        hash(key)
    except TypeError:                 # unhashable cfg/rt (e.g. shard_fn)
        return jax.jit(maker())
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = _JIT_CACHE[key] = jax.jit(maker())
    _JIT_CACHE.move_to_end(key)
    while len(_JIT_CACHE) > JIT_CACHE_MAX:
        _JIT_CACHE.popitem(last=False)
    return fn


# Cache-leaf classification for slot writes: attention leaves are row-per-
# position (axis 2 after the layer-stack dim), state leaves (mamba2 h/conv,
# rwkv6 S/prev_*) are whole-slot values. (rwkv6's "S" is uppercase — no
# collision with the attention keys.)
_SEQ_LEAVES = ("k", "v", "pos")


def _leaf_key(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", "")))


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_slot_cache(full, new, slot):
    """Insert a batch-1 prefill cache into ``slot`` of a batched session
    cache: k/v/pos write their first S rows (the prompt's positions), state
    leaves replace the slot wholesale. Jitted with the session cache
    donated so the update happens in place instead of copying every cache
    leaf per admission."""
    def write(path, f, n):
        row = n[:, 0]                             # drop the batch-1 dim
        if _leaf_key(path) in _SEQ_LEAVES:
            s = row.shape[1]
            return f.at[:, slot, :s].set(row.astype(f.dtype))
        return f.at[:, slot].set(row.astype(f.dtype))
    return jax.tree_util.tree_map_with_path(write, full, new)


@functools.partial(jax.jit, donate_argnums=(0,))
def _restore_slot_cache(full, state, slot):
    """Write one exported slot's cache state (every leaf already sliced to
    its slot row, full max_len for k/v/pos) wholesale into ``slot`` of a
    batched session cache — the receiving half of a live cache handoff.
    Jitted + donated like :func:`_write_slot_cache`."""
    return jax.tree_util.tree_map(
        lambda f, s: f.at[:, slot].set(s.astype(f.dtype)), full, state)


@functools.partial(jax.jit, donate_argnums=(0,))
def _clear_slot_cache(caches, slot):
    """Reset ``slot`` to its init_cache state: k/v zeroed, pos rows -1
    (the decode mask treats them as unwritten), SSM/linear-attention state
    zeroed. A freed slot keeps NOTHING of its previous occupant — slot
    reuse must never attend to stale keys/values. Jitted + donated like
    :func:`_write_slot_cache` (slot free is on the serving hot path)."""
    def clear(path, f):
        if _leaf_key(path) == "pos":
            return f.at[:, slot].set(-1)
        return f.at[:, slot].set(jnp.zeros((), f.dtype))
    return jax.tree_util.tree_map_with_path(clear, caches)


# -- paged-cache twins of the slot helpers ----------------------------------
# Paged leaves live under caches["layers"]["b{i}"] for PAGED_KINDS blocks,
# pooled as (n_super, n_pages+1, page_size, ...); everything else (window
# caches, SSM state, tail) keeps the dense slot-indexed layout and is
# handled exactly like the dense helpers above. ``phys`` vectors are padded
# to the per-slot table width with the trash-page index so the jitted
# scatters have a fixed shape — trash writes only ever carry scrub values.

def _paged_blocks(pat) -> frozenset:
    return frozenset(f"b{i}" for i, kind in enumerate(pat)
                     if kind in PAGED_KINDS)


def _is_paged_leaf(path, paged_blocks) -> bool:
    if len(path) < 3:
        return False
    root = str(getattr(path[0], "key", ""))
    blk = str(getattr(path[1], "key", ""))
    return (root == "layers" and blk in paged_blocks
            and _leaf_key(path) in _SEQ_LEAVES)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _paged_write_prompt(pat, full, new, slot, phys):
    """Paged ``_write_slot_cache``: the batch-1 prefill cache's seq rows
    are padded to ``max_len`` (k/v with zeros, pos with -1 — exactly the
    scrubbed-page values), split into pages, and scattered to the slot's
    physical pages. ``phys`` is (max_pages,) int32, unallocated entries
    pointing at the trash page (they carry pure padding, so the duplicate
    trash writes are deterministic)."""
    paged = _paged_blocks(pat)

    def write(path, f, n):
        row = n[:, 0]                             # drop the batch-1 dim
        if _is_paged_leaf(path, paged):
            ps = f.shape[2]
            mp = phys.shape[0]
            s = row.shape[1]
            pad_shape = (row.shape[0], mp * ps - s) + row.shape[2:]
            if _leaf_key(path) == "pos":
                fill = jnp.full(pad_shape, -1, row.dtype)
            else:
                fill = jnp.zeros(pad_shape, row.dtype)
            slab = jnp.concatenate([row, fill], axis=1).reshape(
                (row.shape[0], mp, ps) + row.shape[2:])
            return f.at[:, phys].set(slab.astype(f.dtype))
        if _leaf_key(path) in _SEQ_LEAVES:
            s = row.shape[1]
            return f.at[:, slot, :s].set(row.astype(f.dtype))
        return f.at[:, slot].set(row.astype(f.dtype))
    return jax.tree_util.tree_map_with_path(write, full, new)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def _paged_clear_slot(pat, caches, slot, phys):
    """Paged ``_clear_slot_cache``: scrub the slot's released physical
    pages back to their init state (k/v zeroed, pos -1) *before* the
    allocator reuses them — free-list reuse can never leak a previous
    tenant's KV — and clear the slot's dense (state/window) leaves."""
    paged = _paged_blocks(pat)

    def clear(path, f):
        if _is_paged_leaf(path, paged):
            ps = f.shape[2]
            mp = phys.shape[0]
            shape = (f.shape[0], mp, ps) + f.shape[3:]
            if _leaf_key(path) == "pos":
                return f.at[:, phys].set(jnp.full(shape, -1, f.dtype))
            return f.at[:, phys].set(jnp.zeros(shape, f.dtype))
        if _leaf_key(path) == "pos":
            return f.at[:, slot].set(-1)
        return f.at[:, slot].set(jnp.zeros((), f.dtype))
    return jax.tree_util.tree_map_with_path(clear, caches)


def _paged_take_slot(pat, caches, slot, page_ids):
    """Gather one slot's state for export: paged leaves as the slot's
    pages-in-use only (n_super, n_used, page_size, ...), dense leaves as
    the slot row. Unjitted — handoffs are rare and variable-sized."""
    paged = _paged_blocks(pat)
    idx = jnp.asarray(page_ids, jnp.int32)

    def take(path, f):
        if _is_paged_leaf(path, paged):
            return f[:, idx]
        return f[:, slot]
    return jax.tree_util.tree_map_with_path(take, caches)


def _paged_put_slot(pat, caches, state, slot, page_ids):
    """Scatter an exported slot's state into freshly allocated pages
    (paged leaves) and the slot row (dense leaves) — the receiving half
    of an O(pages) handoff."""
    paged = _paged_blocks(pat)
    idx = jnp.asarray(page_ids, jnp.int32)

    def put(path, f, s):
        if _is_paged_leaf(path, paged):
            return f.at[:, idx].set(s.astype(f.dtype))
        return f.at[:, slot].set(s.astype(f.dtype))
    return jax.tree_util.tree_map_with_path(put, caches, state)


@dataclasses.dataclass
class DecodeTicket:
    """One in-flight decode step: dispatched through an ExecutionLane but
    not yet joined. ``handle`` is None when the session had no active
    slots (nothing was enqueued; only ``oom_done`` carries information).
    Produced by :meth:`ServeSession.dispatch_decode`, consumed exactly
    once by :meth:`ServeSession.join_decode`."""
    handle: Optional[cc.LaneHandle]
    oom_done: List["Request"]
    lane: str = ""
    overlap_group: int = -1
    t0: float = 0.0
    # Speculative decode: the depth this step ran at (1 = plain decode)
    # and the draft chain's own lane handle (telemetry; the verify thunk
    # already consumes its result as an XLA data dependency).
    spec_k: int = 1
    draft_handle: Optional[cc.LaneHandle] = None


class ServeSession:
    """Fixed-slot continuous batching over a single shared KV cache.

    Each slot advances at its OWN position (``decode_step`` takes a (B,)
    position vector): admission is one bulk prefill (``make_prefill_step``)
    written into the slot's cache rows — active slots are untouched and
    lose no output — and a freed slot's cache rows are cleared before
    reuse. The first generated token is sampled from the prefill logits,
    so admission itself emits output token #1.

    ``submit``/``step``/``run`` drive a single FIFO queue; the multi-tenant
    scheduler (:mod:`repro.runtime.scheduler`) instead calls the slot-level
    API directly: ``has_free_slot`` → ``admit(req)`` → ``decode_once()``.
    """

    def __init__(self, params, cfg: ArchConfig, *, batch_slots: int,
                 max_len: int, rt: RuntimeCfg = DEFAULT_RT,
                 temperature: float = 0.0, eos_id: int = -1, seed: int = 0,
                 policy=None, auto_backend: Optional[str] = None,
                 verbose_policy: bool = False, telemetry=None,
                 paged: bool = False, page_size: int = 16,
                 pages: Optional[int] = None, speculative=None):
        # Speculative decoding rides on the greedy-exactness contract:
        # the verify pass accepts drafts by argmax comparison, so a
        # sampling session has no exact acceptance rule. Refuse up front
        # (the kill switch is SpecDecodeSpec(k=1) or speculative=None).
        self.speculative = spv.SpecDecodeSpec.from_any(speculative)
        if self.speculative is not None and temperature > 0:
            raise ValueError(
                "speculative decoding is greedy-only (temperature == 0): "
                "verify-by-argmax has no exact acceptance rule for "
                f"sampled decode (temperature={temperature})")
        # The draft chain may need the unpacked weights (a dense-layout
        # draft policy under a sparse24 session policy): keep the raw
        # reference from before any pack.
        raw_params = params
        if policy == "auto":
            # paper-§9.2 resolution at session construction: the dominant
            # decode GEMM is (slots, d_model, d_ff); decode is
            # latency-sensitive and each slot is a tenant.
            policy = ex.resolve_policy(
                batch_slots, cfg.d_model, cfg.d_ff,
                precision=cfg.precision, latency_sensitive=True,
                tenants=batch_slots, backend=auto_backend)
        if policy is not None:
            cfg, rt = ex.apply_policy(cfg, rt, policy)
            if policy.sparsity == "sparse24":
                # serving form of 2:4: prune+pack ONCE here so decode
                # streams packed weights (the §7 bandwidth win), instead
                # of re-pruning inside every jitted step
                params = ex.pack_model_params(params)
            if verbose_policy:
                print(f"[serve] policy: {policy.describe()}")
        self.policy = policy
        # telemetry: a repro.runtime.telemetry.Tracer (duck-typed) that
        # receives per-op serving events (prefill/decode wall times).
        self.tracer = telemetry
        self.params = params
        self.cfg = cfg
        self.rt = rt
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self._pat = cfg.superlayer_pattern
        self.paged = bool(paged)
        # The ambient default policy/backend is resolved at trace time by
        # dense() whenever rt.policy is unset, so it must be part of the
        # cache key — a --backend sweep flips it between sessions. Page
        # geometry is part of the key too: a different --page-size changes
        # the cache layout the step was traced for.
        ambient = ex.get_default_policy()
        if self.paged:
            if max_len % page_size:
                raise ValueError(f"max_len={max_len} must be a multiple of "
                                 f"page_size={page_size}")
            # register the paged-decode kernel backend (telemetry naming)
            from repro.kernels import paged_attention  # noqa: F401
            mp = max_len // page_size
            if pages is None:
                pages = batch_slots * mp      # dense-equivalent capacity
            self.page_size, self.pages = int(page_size), int(pages)
            self.pager = paging.PageAllocator(
                self.pages, self.page_size, mp, batch_slots,
                state_block_tokens=paging.state_block_tokens(cfg))
            self.caches = init_paged_cache(cfg, batch_slots, max_len,
                                           self.page_size, self.pages)
            self._page_map = jnp.asarray(self.pager.page_map())
            self.step_fn = _cached_jit(
                "serve_paged",
                lambda: make_paged_serve_step(cfg, rt, temperature),
                cfg, rt, temperature, ambient, self.page_size, self.pages)
        else:
            self.page_size, self.pages = 0, 0
            self.pager = None
            self.caches = init_cache(cfg, batch_slots, max_len)
            self.step_fn = _cached_jit(
                "serve", lambda: make_serve_step(cfg, rt, temperature),
                cfg, rt, temperature, ambient)
        # next write position per slot (slot-local: every request starts
        # at position 0 regardless of when it was admitted)
        self.slot_pos = np.zeros((batch_slots,), np.int32)
        self.prefill_fn = _cached_jit(
            "prefill", lambda: make_prefill_step(cfg, rt), cfg, rt, ambient)
        self.rng = jax.random.PRNGKey(seed)
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self._inflight: Optional[DecodeTicket] = None
        # -- speculative decode state ----------------------------------
        self._spec_fns: Dict[int, Tuple[Callable, Callable]] = {}
        self._spec_deltas: List[Tuple[str, int, int]] = []
        self.spec_totals: Dict[str, Dict[str, int]] = {}
        self.adaptive_k: Optional[spv.AdaptiveK] = None
        self._draft_params = None
        if self.speculative is not None:
            dpol = self.speculative.resolved()
            if dpol.sparsity == "sparse24":
                # share the session's already-packed weights when both
                # policies are sparse24; otherwise pack a draft copy once
                if isinstance(self.policy, ex.ExecutionPolicy) \
                        and self.policy.sparsity == "sparse24":
                    self._draft_params = self.params
                else:
                    self._draft_params = ex.pack_model_params(raw_params)
            else:
                self._draft_params = raw_params
            if self.speculative.adaptive:
                self.adaptive_k = spv.AdaptiveK(self.speculative)

    # -- slot-level API (used by the scheduler) ----------------------------
    def _policy_scope(self):
        """Partition-local policy scope around every prefill/decode call:
        trace-time consumers that would fall back to the ambient default
        policy resolve THIS session's policy instead — under heterogeneous
        per-partition policies the ambient default belongs to no one."""
        if isinstance(self.policy, ex.ExecutionPolicy):
            return ex.policy_scope(self.policy)
        return contextlib.nullcontext()

    def _policy_tag(self) -> Dict[str, str]:
        """Event attribution for this session's serving ops."""
        if isinstance(self.policy, ex.ExecutionPolicy):
            return {"policy": self.policy.spec(),
                    "backend": self.policy.backend}
        return {}

    @property
    def n_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def has_free_slot(self) -> bool:
        return any(s is None for s in self.slots)

    def free_slots(self) -> int:
        return sum(s is None for s in self.slots)

    def can_admit(self, req: Request) -> bool:
        """Admission headroom: a free slot AND (paged) enough free pages
        for the prompt plus its first decode write. The dense path is
        exactly ``has_free_slot`` — slots ARE the capacity unit there."""
        if not self.has_free_slot():
            return False
        if not self.paged:
            return True
        return self.pager.can_admit_tokens(len(req.prompt) + 1)

    def _phys_padded(self, page_ids: List[int]) -> jax.Array:
        """(max_pages,) int32 scatter vector: the slot's physical pages,
        padded with the trash-page index (fixed shape → one jitted trace)."""
        mp = self.pager.max_pages_per_slot
        trash = self.pages                        # pool row past the last page
        out = np.full((mp,), trash, np.int32)
        out[:len(page_ids)] = page_ids
        return jnp.asarray(out)

    def _sync_page_map(self) -> None:
        self._page_map = jnp.asarray(self.pager.page_map())

    def admit(self, req: Request) -> int:
        """Bulk-prefill ``req`` into a free slot and sample its first
        output token from the prefill logits. Active slots do not step —
        admission can never drop another request's tokens. Returns the
        slot index (the request may already be done if ``max_new == 1``)."""
        slot = next((i for i, s in enumerate(self.slots) if s is None), None)
        if slot is None:
            raise RuntimeError("admit() with no free slot")
        lp = len(req.prompt)
        if not 0 < lp < self.max_len:
            raise ValueError(f"prompt length {lp} not in [1, {self.max_len})")
        if self.paged:
            # reserve pages BEFORE the prefill: lp prompt positions plus
            # the first decode write at position lp. Raises PagesExhausted
            # (admission refused) — callers gate on can_admit() first.
            page_ids = self.pager.alloc_slot(slot, lp + 1)
        prompt = jnp.asarray(np.asarray(req.prompt, np.int32))[None, :]
        t0 = time.perf_counter()
        with self._policy_scope():
            logits, pcaches = self.prefill_fn(self.params, prompt)
        if self.tracer is not None:
            jax.block_until_ready(logits)
            self.tracer.record(
                "prefill", m=lp, k=self.cfg.d_model, n=self.cfg.d_ff,
                precision=self.cfg.precision, **self._policy_tag(),
                wall_s=time.perf_counter() - t0,
                tenant=req.tenant or "", meta={"uid": req.uid, "slot": slot})
        if self.paged:
            self.caches = _paged_write_prompt(
                self._pat, self.caches, pcaches, slot,
                self._phys_padded(page_ids))
            self._sync_page_map()
            self.pager.record(self.tracer, phase="admit", slot=slot,
                              tenant=req.tenant or "", uid=req.uid)
        else:
            self.caches = _write_slot_cache(self.caches, pcaches, slot)
        if self.temperature > 0:
            self.rng, sub = jax.random.split(self.rng)
            tok = int(jax.random.categorical(
                sub, logits[0] / self.temperature))
        else:
            tok = int(jnp.argmax(logits[0]))
        self.slots[slot] = req
        self.slot_pos[slot] = lp
        self.tokens = self.tokens.at[slot, 0].set(tok)
        req.admit_t = time.perf_counter()
        req.out.append(tok)
        self._maybe_finish(slot, tok)
        return slot

    def free_slot(self, slot: int):
        self.slots[slot] = None
        self.slot_pos[slot] = 0
        if self.paged:
            released = self.pager.free_slot(slot)
            # scrub the released pages BEFORE the free list hands them out
            self.caches = _paged_clear_slot(self._pat, self.caches, slot,
                                            self._phys_padded(released))
            self._sync_page_map()
            self.pager.record(self.tracer, phase="free", slot=slot)
        else:
            self.caches = _clear_slot_cache(self.caches, slot)
        self.tokens = self.tokens.at[slot, 0].set(0)

    # -- live cache handoff (tenant migration) ------------------------------
    def export_slot(self, slot: int) -> SlotExport:
        """Detach ``slot``'s in-flight request with its complete serving
        state (cache slice, position, next-token input) and clear the slot
        — the request is NOT finished; it resumes wherever the export is
        imported. The slot is left exactly as :meth:`free_slot` leaves it,
        so the next occupant cannot attend to the emigrant's KV rows."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"slot {slot} is empty")
        # Materialize the slices BEFORE _clear_slot_cache donates the
        # session buffers: these are fresh arrays, not views.
        if self.paged:
            page_ids = self.pager.slot_pages(slot)
            state = _paged_take_slot(self._pat, self.caches, slot, page_ids)
            out = SlotExport(request=req, caches=state,
                             pos=int(self.slot_pos[slot]),
                             token=int(self.tokens[slot, 0]),
                             pages=len(page_ids), page_size=self.page_size)
            if self.tracer is not None:
                self.pager.record(self.tracer, phase="export", slot=slot,
                                  tenant=req.tenant or "",
                                  pages_moved=len(page_ids),
                                  handoff_bytes=export_nbytes(out))
        else:
            state = jax.tree_util.tree_map(lambda f: f[:, slot], self.caches)
            out = SlotExport(request=req, caches=state,
                             pos=int(self.slot_pos[slot]),
                             token=int(self.tokens[slot, 0]))
        jax.block_until_ready(state)
        self.free_slot(slot)
        return out

    def handoff_pages(self, slot: int) -> int:
        """Pages a migration of ``slot`` would move (0 on dense sessions —
        dense handoffs move the whole max_len slice regardless)."""
        return len(self.pager.slot_pages(slot)) if self.paged else 0

    def can_accept_pages(self, n_pages: int, page_size: int) -> bool:
        """Import-side headroom check *before* the exporter detaches the
        slot: free slot, and on paged sessions matching page geometry plus
        enough free pages for the ``n_pages`` the handoff would move."""
        if not self.has_free_slot():
            return False
        if not self.paged:
            return True
        return (page_size == self.page_size
                and n_pages <= self.pager.max_pages_per_slot
                and self.pager.can_alloc(n_pages))

    def can_accept_handoff(self, export: SlotExport) -> bool:
        """Would :meth:`import_slot` succeed right now?"""
        return self.can_accept_pages(export.pages, export.page_size)

    def import_slot(self, export: SlotExport) -> int:
        """Resume an exported in-flight request in a free slot of THIS
        session. Sessions must share the cache layout — same config and
        ``max_len`` (checked leaf-by-leaf). Returns the slot index."""
        slot = next((i for i, s in enumerate(self.slots) if s is None), None)
        if slot is None:
            raise RuntimeError("import_slot() with no free slot")
        if self.paged != bool(export.pages or export.page_size):
            raise ValueError(
                "cache layout mismatch: paged and dense sessions cannot "
                "hand off slots to each other")
        if self.paged:
            if export.page_size != self.page_size:
                raise ValueError(
                    f"page_size mismatch: export {export.page_size} vs "
                    f"session {self.page_size}")
            # Both sides paged: paged leaves compare trailing (page
            # geometry) dims — the export carries pages-in-use, not the
            # full pool — dense state leaves compare whole slot slices.
            paged_blocks = _paged_blocks(self._pat)
            ours: List[tuple] = []
            theirs: List[tuple] = []

            def collect(path, f, s):
                ours.append(f.shape[:1] + f.shape[2:])
                theirs.append(s.shape[:1] + s.shape[2:]
                              if _is_paged_leaf(path, paged_blocks)
                              else s.shape)
                return f
            jax.tree_util.tree_map_with_path(collect, self.caches,
                                             export.caches)
            if ours != theirs:
                raise ValueError(
                    "cache layout mismatch: the exporting session's slot "
                    "state does not fit this session (same cfg, max_len "
                    "and page_size required for a live handoff)")
            # May raise PagesExhausted — callers gate on
            # can_accept_handoff() first.
            page_ids = self.pager.import_slot(slot, export.pages,
                                              export.pos + 1)
            self.caches = _paged_put_slot(self._pat, self.caches,
                                          export.caches, slot, page_ids)
            self._sync_page_map()
            self.pager.record(self.tracer, phase="import", slot=slot,
                              tenant=export.request.tenant or "",
                              pages_moved=export.pages)
        else:
            ours = [f.shape[:1] + f.shape[2:]
                    for f in jax.tree_util.tree_leaves(self.caches)]
            theirs = [s.shape
                      for s in jax.tree_util.tree_leaves(export.caches)]
            if ours != theirs:
                raise ValueError(
                    "cache layout mismatch: the exporting session's slot "
                    "state does not fit this session (same cfg and max_len "
                    "required for a live handoff)")
            self.caches = _restore_slot_cache(self.caches, export.caches,
                                              slot)
        self.slots[slot] = export.request
        self.slot_pos[slot] = export.pos
        self.tokens = self.tokens.at[slot, 0].set(export.token)
        return slot

    # -- speculative decode plumbing ----------------------------------------
    def _next_spec_k(self) -> int:
        """Depth for the next decode step: the spec's k, or the adaptive
        controller's current actuation (floor 1 = drafting disabled)."""
        if self.speculative is None:
            return 1
        if self.adaptive_k is not None:
            return max(1, min(self.adaptive_k.k, self.speculative.k))
        return self.speculative.k

    def _spec_fns_for(self, k: int) -> Tuple[Callable, Callable]:
        """Jitted (draft, verify) pair for depth ``k``.

        The speculative geometry — the draft policy's full spec AND k —
        is part of the draft jit key: k and the policy are baked into the
        trace, so two sessions differing only in speculative geometry
        must not share a compiled draft chain. Audit of the remaining
        ``ServingSpec``-derived key components: cfg/rt (session policy
        applied), the ambient default policy, temperature (speculation is
        greedy-only, so the verify excludes it by construction), and page
        geometry are already in the plain-step keys; ``batch_slots`` /
        ``max_len`` / k-as-operand-width only change traced *shapes*,
        which one ``jax.jit`` re-traces per shape on its own."""
        fns = self._spec_fns.get(k)
        if fns is None:
            spec = self.speculative
            dkey = spec.spec_key()
            ambient = ex.get_default_policy()
            geo = (self.page_size, self.pages) if self.paged else ()
            draft_fn = _cached_jit(
                "spec_draft",
                lambda: spv.make_draft_step(self.cfg, self.rt,
                                            spec.resolved(), k - 1,
                                            paged=self.paged),
                self.cfg, self.rt, ambient, dkey, k, self.paged, *geo)
            verify_fn = _cached_jit(
                "spec_verify",
                lambda: spv.make_verify_step(self.cfg, self.rt,
                                             paged=self.paged),
                self.cfg, self.rt, ambient, self.paged, *geo)
            fns = self._spec_fns[k] = (draft_fn, verify_fn)
        return fns

    def drain_spec_deltas(self) -> List[Tuple[str, int, int]]:
        """Hand the per-slot ``(tenant, drafted, accepted)`` samples since
        the last drain to the caller (the scheduler folds them into its
        per-tenant accounting)."""
        out, self._spec_deltas = self._spec_deltas, []
        return out

    def dispatch_decode(self, lane: Optional[cc.ExecutionLane] = None, *,
                        overlap_group: int = -1) -> DecodeTicket:
        """Dispatch half of a decode step: page bookkeeping, then enqueue
        the jitted step through ``lane`` (JAX async dispatch — the call
        returns future arrays without blocking) and hand back a
        :class:`DecodeTicket`. The session's cache references advance to
        the in-flight arrays immediately, but host state (tokens,
        positions, completions) is only touched by :meth:`join_decode` —
        so the token stream is byte-identical to the synchronous path
        regardless of what other lanes do in between."""
        if self._inflight is not None:
            raise RuntimeError(
                "decode already in flight: join_decode the previous "
                "ticket before dispatching another step")
        if self.n_active == 0:
            return DecodeTicket(handle=None, oom_done=[])
        k = self._next_spec_k()
        oom_done: List[Request] = []
        if self.paged:
            if k > 1:
                # batch-wide feasibility first: a k-deep verify needs a
                # page for every candidate position. If the pool cannot
                # cover the whole batch, downgrade THIS step to plain
                # decode (k=1) instead of truncating requests that plain
                # decode could still serve.
                need = 0
                for i, req in enumerate(self.slots):
                    if req is None:
                        continue
                    tgt = min(int(self.slot_pos[i]) + k, self.max_len)
                    need += max(0, self.pager.pages_for(tgt)
                                - len(self.pager.slot_pages(i)))
                if need > self.pager.free_pages:
                    self.pager.record(self.tracer, phase="spec_downgrade",
                                      need_pages=need)
                    k = 1
            # lazy page append: make sure every active slot has a page
            # for each position this step may write (k candidates on a
            # speculative step; positions past max_len route to the
            # trash page in-kernel). Pool exhaustion finishes the
            # request truncated (refused, never crashed).
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                need = min(int(self.slot_pos[i]) + k, self.max_len) \
                    if k > 1 else int(self.slot_pos[i]) + 1
                if self.pager.pages_for(need) > \
                        len(self.pager.slot_pages(i)):
                    try:
                        self.pager.extend_slot(i, need)
                        self._sync_page_map()
                    except paging.PagesExhausted:
                        self.pager.record(self.tracer, phase="page_oom",
                                          slot=i, tenant=req.tenant or "",
                                          uid=req.uid)
                        req.done = True
                        req.finish_t = time.perf_counter()
                        self.completed.append(req)
                        self.free_slot(i)
                        oom_done.append(req)
            if self.n_active == 0:
                return DecodeTicket(handle=None, oom_done=oom_done)
        self.rng, sub = jax.random.split(self.rng)
        if lane is None:
            lane = cc.ExecutionLane("session")
        t0 = time.perf_counter()
        posv = jnp.asarray(self.slot_pos)
        if k > 1:
            # draft on its own lane; the verify thunk consumes the draft
            # handle's *future* tokens (an XLA data dependency — the host
            # never materializes draft tokens), so a caller that
            # dispatches the next draft before joining this verify gets
            # draft(n+1)/verify(n) overlap on real async hardware.
            active = jnp.asarray(
                np.array([s is not None for s in self.slots], np.bool_))
            draft_fn, verify_fn = self._spec_fns_for(k)
            draft_lane = cc.ExecutionLane("draft", tracer=self.tracer)
            with self._policy_scope():
                if self.paged:
                    dthunk = functools.partial(
                        draft_fn, self._draft_params, self.tokens,
                        self.caches, posv, self._page_map)
                else:
                    dthunk = functools.partial(
                        draft_fn, self._draft_params, self.tokens,
                        self.caches, posv)
                dh = draft_lane.dispatch(dthunk, label="draft",
                                         overlap_group=overlap_group)
                tokens_seq = dh.result
                if self.paged:
                    thunk = functools.partial(
                        verify_fn, self.params, tokens_seq, self.caches,
                        posv, active, self._page_map)
                else:
                    thunk = functools.partial(
                        verify_fn, self.params, tokens_seq, self.caches,
                        posv, active)
                handle = lane.dispatch(thunk, label="decode",
                                       overlap_group=overlap_group)
            _, _, _, self.caches = handle.result
            ticket = DecodeTicket(handle=handle, oom_done=oom_done,
                                  lane=lane.name,
                                  overlap_group=overlap_group, t0=t0,
                                  spec_k=k, draft_handle=dh)
            self._inflight = ticket
            return ticket
        with self._policy_scope():
            if self.paged:
                thunk = functools.partial(
                    self.step_fn, self.params, self.tokens, self.caches,
                    posv, self._page_map, sub)
            else:
                thunk = functools.partial(
                    self.step_fn, self.params, self.tokens, self.caches,
                    posv, sub)
            handle = lane.dispatch(thunk, label="decode",
                                   overlap_group=overlap_group)
        # the cache references advance to the enqueued (future) arrays
        # now, so a later dispatch on another lane never aliases stale
        # state; nothing here blocks
        _, _, self.caches = handle.result
        ticket = DecodeTicket(handle=handle, oom_done=oom_done,
                              lane=lane.name, overlap_group=overlap_group,
                              t0=t0)
        self._inflight = ticket
        return ticket

    def join_decode(self, ticket: DecodeTicket) -> List[Request]:
        """Join half of a decode step: block on the ticket's result, then
        run the host-side token accounting exactly as the synchronous path
        did. Records the ``decode`` event with the lane/overlap-group the
        step actually ran under."""
        self._inflight = None
        if ticket.handle is None:
            return list(ticket.oom_done)
        if ticket.spec_k > 1:
            return self._join_spec(ticket)
        nxt = ticket.handle.join()[0]
        nxt_np = np.asarray(nxt[:, 0])       # forces the step to complete
        if self.tracer is not None:
            self.tracer.record(
                "decode", m=self.batch_slots, k=self.cfg.d_model,
                n=self.cfg.d_ff, precision=self.cfg.precision,
                **self._policy_tag(),
                wall_s=time.perf_counter() - ticket.t0,
                lane=ticket.lane, overlap_group=ticket.overlap_group,
                meta={"n_active": self.n_active,
                      "dispatch_to_ready_s":
                          ticket.handle.dispatch_to_ready_s})
        self.tokens = nxt
        done = list(ticket.oom_done)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slot_pos[i] += 1
            tok = int(nxt_np[i])
            req.out.append(tok)
            if self._maybe_finish(i, tok):
                done.append(req)
            elif self.paged:
                # utilization accounting: positions written so far plus
                # the pending next write
                self.pager.note_tokens(i, int(self.slot_pos[i]) + 1)
        if self.adaptive_k is not None:
            self.adaptive_k.on_step()
        return done

    def _join_spec(self, ticket: DecodeTicket) -> List[Request]:
        """Join half of a speculative step: commit the accepted prefix
        (plus the verify's own token) per slot, record acceptance
        telemetry, and — paged — trim the candidate pages the verify
        already scrubbed back to the free list."""
        nxt, greedy, n_acc, _ = ticket.handle.join()
        g_np = np.asarray(greedy)            # forces the step to complete
        acc_np = np.asarray(n_acc)
        k = ticket.spec_k
        if self.tracer is not None:
            self.tracer.record(
                "decode", m=self.batch_slots, k=self.cfg.d_model,
                n=self.cfg.d_ff, precision=self.cfg.precision,
                **self._policy_tag(),
                wall_s=time.perf_counter() - ticket.t0,
                lane=ticket.lane, overlap_group=ticket.overlap_group,
                meta={"n_active": self.n_active, "spec_k": k,
                      "dispatch_to_ready_s":
                          ticket.handle.dispatch_to_ready_s})
        self.tokens = nxt
        done = list(ticket.oom_done)
        trimmed = False
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            acc = int(acc_np[i])
            drafted = k - 1
            finished = False
            committed = 0
            # the accepted drafts and the verify token, in order; finish
            # mid-commit truncates exactly where plain decode would have
            # stopped (the surplus accepted tokens were never committed)
            for t in range(acc + 1):
                tok = int(g_np[i, t])
                self.slot_pos[i] += 1
                req.out.append(tok)
                committed += 1
                if self._maybe_finish(i, tok):
                    done.append(req)
                    finished = True
                    break
            tenant = req.tenant or ""
            self._spec_deltas.append((tenant, drafted, acc))
            tot = self.spec_totals.setdefault(
                tenant, {"steps": 0, "drafted": 0, "accepted": 0,
                         "committed": 0})
            tot["steps"] += 1
            tot["drafted"] += drafted
            tot["accepted"] += acc
            tot["committed"] += committed
            if self.adaptive_k is not None:
                self.adaptive_k.observe(tenant, drafted, acc)
            if self.tracer is not None:
                self.tracer.record(
                    "spec", tenant=tenant,
                    meta={"k": k, "drafted": drafted, "accepted": acc,
                          "committed": committed, "uid": req.uid})
            if not finished and self.paged:
                # release the candidate pages the rejected writes grew
                # into (the verify scrubbed them in-jit before the host
                # saw n_acc, so they re-enter the free list clean)
                if self.pager.trim_slot(i, int(self.slot_pos[i]) + 1):
                    trimmed = True
                self.pager.note_tokens(i, int(self.slot_pos[i]) + 1)
        if trimmed:
            self._sync_page_map()
        if self.adaptive_k is not None:
            self.adaptive_k.on_step()
        return done

    def decode_once(self, lane: Optional[cc.ExecutionLane] = None
                    ) -> List[Request]:
        """One decode step over the active slots (no admission); returns
        the requests that completed this step. Dispatch immediately
        followed by join — the synchronous composition of the lane seam."""
        return self.join_decode(self.dispatch_decode(lane))

    def _maybe_finish(self, slot: int, tok: int) -> bool:
        req = self.slots[slot]
        if tok == self.eos_id or len(req.out) >= req.max_new \
                or self.slot_pos[slot] >= self.max_len:
            req.done = True
            req.finish_t = time.perf_counter()
            self.completed.append(req)
            self.free_slot(slot)
            return True
        return False

    # -- single-queue request lifecycle ------------------------------------
    def submit(self, req: Request):
        req.submit_t = time.perf_counter()
        self.queue.append(req)

    def _admit_from_queue(self):
        while self.queue and self.can_admit(self.queue[0]):
            self.admit(self.queue.pop(0))
        if (self.paged and self.queue and self.n_active == 0
                and self.pager.pages_in_use == 0
                and not self.can_admit(self.queue[0])):
            # nothing running, nothing allocated, and the head request
            # still doesn't fit: it never will — surface the config error
            # instead of spinning forever in run().
            req = self.queue[0]
            raise paging.PagesExhausted(
                f"request uid={req.uid} needs "
                f"{self.pager.pages_for(len(req.prompt) + 1)} pages but the "
                f"pool only has {self.pages}")

    def step(self):
        """Admit what fits, then one decode step for all active slots."""
        self._admit_from_queue()
        return self.decode_once()

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or self.n_active) and steps < max_steps:
            self.step()
            steps += 1
        return self.completed
