"""Chrome/Perfetto ``trace_event`` export for Tracer event streams.

The observability plane's *visual* surface: any :class:`Tracer` (or a
``Tracer.merge`` fused view — partition tags are preserved, so a full
``ServingRuntime.merged_tracer()`` exports in one call) renders to the
Chrome trace-event JSON format that ``chrome://tracing``, Perfetto UI
(https://ui.perfetto.dev) and ``about:tracing`` all open directly.

Mapping:

* one *process* per partition (``pid = partition + 1``; the
  unpartitioned ``-1`` tag becomes pid 0), one *thread* per execution
  lane within it (``tid 0`` is the partition's control/scheduler track)
  — so the fig21 question "did those two lanes actually overlap?" is
  answered by looking;
* ``decode`` / ``prefill`` / ``matmul`` / ``stream`` events with a
  measured ``wall_s`` become complete duration slices (``ph="X"``).
  Events are recorded at *join* time, so a slice starts at
  ``ev.t - ev.wall_s`` ≈ its dispatch — two planner-paired decode steps
  therefore appear as temporally overlapping slices on their two lane
  tracks, which is the whole point;
* ``migrate`` handoffs become flow (arrow) events between the source
  and destination partition tracks (the runtime records each phase on
  *both* endpoint tracers, which is exactly what lets one export bind
  the arrow's ends); start/done phases render as instants;
* completed per-tenant requests become async ``b``/``e`` spans keyed by
  request uid (submit→finish wall), grouped under the tenant name;
* ``admit`` / ``paging`` / ``overlap`` events become thread-scoped
  instants carrying their meta as args.

:func:`overlapping_groups` and :func:`migration_flow_pairs` re-read an
exported trace and verify those structural claims — CI asserts the
fig21 artifact through them, and ``tests/test_observability.py`` pins
the geometry.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

# Event kinds that render as duration slices when they carry a measured
# wall time. Recorded at completion/join, so start = t - wall_s.
SLICE_KINDS = ("decode", "prefill", "matmul", "stream")
# Kinds that render as thread-scoped instants.
INSTANT_KINDS = ("admit", "paging", "overlap", "quota", "route")

_ARG_FIELDS = ("m", "k", "n", "precision", "backend", "policy", "stream",
               "tenant", "step", "lane", "overlap_group")


def _pid(partition: int) -> int:
    return int(partition) + 1


def _us(seconds: float) -> float:
    return round(seconds * 1e6, 3)


def _args(ev) -> Dict[str, Any]:
    out = {}
    for f in _ARG_FIELDS:
        v = getattr(ev, f)
        if f == "overlap_group":
            if v is not None and v >= 0:     # 0 is a real group id
                out[f] = v
        elif v not in ("", -1, 0, None) or f in ("m", "k", "n"):
            out[f] = v
    for k, v in ev.meta.items():
        if isinstance(v, (int, float, str, bool)) or v is None:
            out[k] = v
        else:
            out[k] = repr(v)
    return out


def to_chrome_trace(tracer, *, include_instants: bool = True) -> Dict[str, Any]:
    """Render a Tracer's retained window as a Chrome ``trace_event``
    document (the ``{"traceEvents": [...]}`` object form).

    Timestamps are rebased so the earliest slice start is 0 µs — the
    absolute ``perf_counter`` epoch is meaningless across processes.
    """
    events = tracer.events()
    if not events:
        return {"traceEvents": [], "displayTimeUnit": "ms",
                "otherData": {"note": "empty tracer"}}

    # Rebase: earliest start across everything we will draw.
    t0 = min(min(ev.t - max(ev.wall_s, 0.0) for ev in events),
             min(ev.t for ev in events))

    # Track discovery: pid per partition, tid per lane within it.
    lanes: Dict[int, Dict[str, int]] = {}    # pid -> lane name -> tid
    for ev in events:
        tids = lanes.setdefault(_pid(ev.partition), {"": 0})
        if ev.lane and ev.lane not in tids:
            tids[ev.lane] = 0                # numbered below, sorted
    for pid, tids in lanes.items():
        for i, name in enumerate(sorted(n for n in tids if n)):
            tids[name] = i + 1

    out: List[Dict[str, Any]] = []
    for pid in sorted(lanes):
        pname = f"partition {pid - 1}" if pid > 0 else "unpartitioned"
        out.append({"ph": "M", "pid": pid, "tid": 0,
                    "name": "process_name", "args": {"name": pname}})
        for lname, tid in sorted(lanes[pid].items(), key=lambda kv: kv[1]):
            out.append({"ph": "M", "pid": pid, "tid": tid,
                        "name": "thread_name",
                        "args": {"name": f"lane {lname}" if lname
                                 else "control"}})

    flow_n = 0
    for ev in events:
        pid = _pid(ev.partition)
        tid = lanes[pid].get(ev.lane, 0)
        if ev.kind in SLICE_KINDS and ev.wall_s > 0:
            name = ev.kind
            if ev.kind in ("decode", "prefill", "matmul") and ev.m:
                name = f"{ev.kind} {ev.m}x{ev.k}x{ev.n}"
            out.append({"ph": "X", "pid": pid, "tid": tid,
                        "ts": _us(ev.t - ev.wall_s - t0),
                        "dur": _us(ev.wall_s),
                        "cat": ev.kind, "name": name, "args": _args(ev)})
        elif ev.kind == "migrate":
            phase = ev.meta.get("phase", "?")
            src, dst = ev.meta.get("src"), ev.meta.get("dst")
            name = f"migrate {ev.tenant} p{src}->p{dst} [{phase}]"
            ts = _us(ev.t - t0)
            out.append({"ph": "i", "pid": pid, "tid": tid, "ts": ts,
                        "s": "t", "cat": "migrate", "name": name,
                        "args": _args(ev)})
            if phase == "handoff":
                # Recorded on both endpoint tracers with identical meta:
                # the source copy opens the arrow, the destination copy
                # closes it, and the shared id binds the two.
                fid = (f"mig:{ev.tenant}:{ev.meta.get('uid', '?')}"
                       f":{src}->{dst}")
                if ev.partition == src:
                    out.append({"ph": "s", "pid": pid, "tid": tid,
                                "ts": ts, "cat": "migrate",
                                "name": "handoff", "id": fid})
                    flow_n += 1
                elif ev.partition == dst:
                    out.append({"ph": "f", "pid": pid, "tid": tid,
                                "ts": ts, "bp": "e", "cat": "migrate",
                                "name": "handoff", "id": fid})
        elif ev.kind == "request" and ev.wall_s > 0:
            span_id = f"req:{ev.meta.get('uid', id(ev))}"
            base = {"pid": pid, "tid": tid, "cat": "request",
                    "name": f"request {ev.tenant}", "id": span_id}
            out.append({**base, "ph": "b", "ts": _us(ev.t - ev.wall_s - t0),
                        "args": _args(ev)})
            out.append({**base, "ph": "e", "ts": _us(ev.t - t0)})
        elif include_instants and ev.kind in INSTANT_KINDS:
            out.append({"ph": "i", "pid": pid, "tid": tid,
                        "ts": _us(ev.t - t0), "s": "t", "cat": ev.kind,
                        "name": ev.kind, "args": _args(ev)})

    counts = tracer.counts(include_dropped=True) \
        if hasattr(tracer, "counts") else {}
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"retained_events": len(events),
                          "flows": flow_n, "counts": counts}}


def export_chrome_trace(tracer, path: str, **kw) -> str:
    """Write :func:`to_chrome_trace` to ``path``; open the file in
    Perfetto UI or ``chrome://tracing`` as-is."""
    doc = to_chrome_trace(tracer, **kw)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path


# ---------------------------------------------------------------------------
# Structural validators (CI + tests re-read exported traces through these)
# ---------------------------------------------------------------------------

def _slices(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    return [e for e in doc.get("traceEvents", []) if e.get("ph") == "X"]


def overlapping_groups(doc: Dict[str, Any]) -> Dict[int, bool]:
    """For every ``overlap_group`` id appearing on duration slices:
    ``True`` iff the group renders as ≥2 *temporally overlapping* slices
    on *distinct* (pid, tid) tracks — i.e. the planner pairing actually
    shows up as concurrent execution in the trace."""
    groups: Dict[int, List[Tuple[Tuple[int, int], float, float]]] = {}
    for e in _slices(doc):
        gid = e.get("args", {}).get("overlap_group", -1)
        if gid is None or int(gid) < 0:
            continue
        groups.setdefault(int(gid), []).append(
            ((e["pid"], e["tid"]), float(e["ts"]),
             float(e["ts"]) + float(e["dur"])))
    out: Dict[int, bool] = {}
    for gid, spans in groups.items():
        ok = False
        for i in range(len(spans)):
            for j in range(i + 1, len(spans)):
                (ta, sa, ea), (tb, sb, eb) = spans[i], spans[j]
                if ta != tb and max(sa, sb) < min(ea, eb):
                    ok = True
        out[gid] = ok
    return out


def migration_flow_pairs(doc: Dict[str, Any]) -> List[Tuple[int, int]]:
    """(src_pid, dst_pid) for every migration flow whose start (``s``)
    and finish (``f``) events both exist and share an id — unbound
    arrows don't count."""
    starts: Dict[str, int] = {}
    ends: Dict[str, int] = {}
    for e in doc.get("traceEvents", []):
        if e.get("cat") != "migrate":
            continue
        if e.get("ph") == "s":
            starts[e["id"]] = e["pid"]
        elif e.get("ph") == "f":
            ends[e["id"]] = e["pid"]
    return sorted((starts[i], ends[i]) for i in starts if i in ends)


def validate(doc: Dict[str, Any]) -> Dict[str, Any]:
    """One-call structural summary used by the CI smoke asserts."""
    og = overlapping_groups(doc)
    return {
        "n_events": len(doc.get("traceEvents", [])),
        "n_slices": len(_slices(doc)),
        "overlap_groups": len(og),
        "overlap_groups_overlapping": sum(1 for v in og.values() if v),
        "migration_flows": migration_flow_pairs(doc),
    }


def load(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)
