"""Runtime telemetry: the "execution observatory" event layer.

The paper's techniques pay off only *context-dependently* (FP8 above an
occupancy threshold §5, concurrency below the fairness-collapse knee §6,
2:4 under memory-bound/multi-tenant execution §7), so the policy layer
needs to *see* execution, not just predict it. This module is the seeing
half of the closed loop (the acting half is
:mod:`repro.core.autotune`):

* :class:`Event` — one observation: op kind, (M, K, N), policy/backend,
  wall / estimated seconds, stream id, tenant id, scheduler step.
* :class:`Tracer` — bounded ring buffer of events with monotonic per-kind
  counters and aggregate views: occupancy histogram (grid-tile fill of
  the observed GEMMs), per-shape latency EMAs, per-tenant request counts
  and p50/p99, fairness/overlap over tenants.

Producers: ``core/execution.matmul``/``resolve_policy`` (trace-time shape
and policy events), ``core/concurrency.characterize_streams`` (per-stream
wall times), ``runtime/scheduler.StreamScheduler`` (admission + request
completion per tenant), ``ServeSession`` (prefill/decode wall times), and
``runtime/train_loop``/``launch/train.py`` (per-step wall times).

An *ambient* tracer can be installed with :func:`set_tracer` so deep call
sites (every ``dense()`` in the model stack) need no plumbing; harness
code that owns its tracer passes it explicitly instead.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import warnings
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import concurrency as cc

# One unit of grid parallelism (mirrors execution.MXU_TILE without the
# import cycle: execution lazily consults this module's ambient tracer).
MXU_TILE = 128


def _grid_tiles(m: int, n: int, tile: int = MXU_TILE) -> int:
    return max(1, -(-int(m) // tile)) * max(1, -(-int(n) // tile))


@dataclasses.dataclass
class Event:
    """One observed execution event. ``wall_s`` is a measured duration
    (0.0 for trace-time events, which observe shape/policy but run before
    any computation); ``est_s`` carries model-derived estimates when a
    producer has one (roofline terms)."""
    kind: str                        # matmul|resolve|stream|admit|request|...
    t: float = 0.0                   # perf_counter timestamp at record
    m: int = 0
    k: int = 0
    n: int = 0
    precision: str = ""
    backend: str = ""
    policy: str = ""
    wall_s: float = 0.0
    est_s: float = 0.0
    stream: int = -1
    tenant: str = ""
    step: int = -1
    partition: int = -1              # spatial sub-mesh id (-1: unpartitioned)
    lane: str = ""                   # ExecutionLane the op dispatched on
    overlap_group: int = -1          # co-dispatched group id (-1: serial)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def grid_tiles(self) -> int:
        return _grid_tiles(self.m, self.n) if self.m and self.n else 0

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class Tracer:
    """Bounded event recorder with aggregate views.

    Events land in a ring buffer of ``capacity`` (old events evicted).
    The counting views — :meth:`counts`, :meth:`tenant_counts` — and the
    per-shape latency EMAs are maintained as monotonic counters that
    survive eviction, so they stay exact on long runs; the sample views
    (:meth:`events`, :meth:`tenant_latencies`/:meth:`tenant_percentiles`,
    :meth:`occupancy_histogram`) cover the retained window only.
    Thread-safe: the serving loop, stream runners, and host callbacks may
    record concurrently.
    """

    def __init__(self, capacity: int = 4096, ema_alpha: float = 0.25,
                 partition: int = -1):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self.ema_alpha = ema_alpha
        # Default partition tag stamped onto every event recorded here that
        # doesn't carry one (a per-partition tracer inside PartitionedServer
        # tags its whole stream so Tracer.merge keeps provenance).
        self.partition = partition
        self._ring: deque = deque(maxlen=capacity)
        self._counts: Dict[str, int] = {}
        self._tenant_counts: Dict[Tuple[str, str], int] = {}
        self._ema: Dict[Tuple[int, int, int, str], float] = {}
        self._dropped: Dict[str, int] = {}   # kind -> ring evictions
        self._warned_drop = False
        self._sinks: List[Any] = []          # duck-typed: on_event/on_drop
        self._lock = threading.Lock()

    # -- sinks (the metrics plane subscribes here) --------------------------
    def add_sink(self, sink) -> "Tracer":
        """Subscribe a sink (duck-typed: ``on_event(ev)``, optionally
        ``on_drop(kind)``) to every event folded into this tracer — the
        seam :class:`repro.runtime.metrics.MetricsSink` attaches through.
        Sinks run on the recording thread, outside the tracer lock."""
        with self._lock:
            if sink not in self._sinks:
                self._sinks.append(sink)
        return self

    def remove_sink(self, sink) -> None:
        with self._lock:
            if sink in self._sinks:
                self._sinks.remove(sink)

    # -- recording ----------------------------------------------------------
    def record(self, kind: str, **fields) -> Event:
        fields.setdefault("partition", self.partition)
        ev = Event(kind=kind, t=time.perf_counter(), **fields)
        self._ingest(ev)
        return ev

    def _ingest(self, ev: Event) -> None:
        """Fold one already-built event in: ring append + every counter,
        all under the lock (concurrent emitters — multi-partition steps,
        ``run_async_dispatch`` threads — may interleave). Eviction past
        ``capacity`` is *counted* (per evicted kind) and warned about once:
        the sample views silently narrowing to a truncated window while
        the monotonic counters keep the true totals is exactly the
        observability gap the dropped counters close."""
        with self._lock:
            evicted = self._ring[0] if len(self._ring) == self.capacity \
                else None
            self._ring.append(ev)
            if evicted is not None:
                self._dropped[evicted.kind] = \
                    self._dropped.get(evicted.kind, 0) + 1
            first_drop = evicted is not None and not self._warned_drop
            if first_drop:
                self._warned_drop = True
            self._counts[ev.kind] = self._counts.get(ev.kind, 0) + 1
            if ev.tenant:
                tkey = (ev.kind, ev.tenant)
                self._tenant_counts[tkey] = self._tenant_counts.get(
                    tkey, 0) + 1
            if ev.wall_s > 0 and ev.m and ev.k and ev.n:
                key = (ev.m, ev.k, ev.n, ev.precision)
                prev = self._ema.get(key)
                self._ema[key] = ev.wall_s if prev is None else \
                    (1 - self.ema_alpha) * prev + self.ema_alpha * ev.wall_s
            sinks = list(self._sinks)
        if first_drop:
            warnings.warn(
                f"Tracer(capacity={self.capacity}) began evicting events: "
                "sample views (tenant_latencies/percentiles, occupancy "
                "histogram, overlap_groups) now cover a truncated window; "
                "monotonic counts stay exact — see Tracer.dropped()",
                RuntimeWarning, stacklevel=4)
        for sink in sinks:
            if evicted is not None and hasattr(sink, "on_drop"):
                sink.on_drop(evicted.kind)
            sink.on_event(ev)

    def record_matmul(self, m: int, k: int, n: int, *, precision: str = "",
                      backend: str = "", policy: str = "",
                      wall_s: float = 0.0, **meta) -> Event:
        return self.record("matmul", m=m, k=k, n=n, precision=precision,
                           backend=backend, policy=policy, wall_s=wall_s,
                           meta=meta)

    def record_resolve(self, m: int, k: int, n: int, *, policy: str,
                       precision: str = "", backend: str = "",
                       **meta) -> Event:
        return self.record("resolve", m=m, k=k, n=n, precision=precision,
                           backend=backend, policy=policy, meta=meta)

    def record_stream(self, stream: int, wall_s: float, *, mode: str = "",
                      n_streams: int = 0, **meta) -> Event:
        meta.update(mode=mode, n_streams=n_streams)
        return self.record("stream", stream=stream, wall_s=wall_s, meta=meta)

    def record_request(self, tenant: str, *, wall_s: float = 0.0,
                       tokens: int = 0, turnaround_steps: int = -1,
                       step: int = -1, **meta) -> Event:
        meta.update(tokens=tokens, turnaround_steps=turnaround_steps)
        return self.record("request", tenant=tenant, wall_s=wall_s,
                           step=step, meta=meta)

    def record_migrate(self, tenant: str, *, src: int, dst: int,
                       phase: str, step: int = -1, **meta) -> Event:
        """One live-migration lifecycle event (``phase`` ∈ start / handoff
        / done). Recorded on *both* endpoints' tracers by the serving
        runtime so the fused view keeps provenance, and consumed by the
        fairness accounting tests: a migrated tenant's request events stay
        keyed by the same tenant id across partitions, so per-tenant
        percentiles remain exact across the move."""
        meta.update(src=src, dst=dst, phase=phase)
        return self.record("migrate", tenant=tenant, step=step, meta=meta)

    # -- raw views ----------------------------------------------------------
    def events(self, kind: Optional[str] = None) -> List[Event]:
        with self._lock:
            evs = list(self._ring)
        return evs if kind is None else [e for e in evs if e.kind == kind]

    def counts(self, include_dropped: bool = False) -> Dict[str, int]:
        """Monotonic per-kind totals (exact even after ring eviction).
        With ``include_dropped`` the per-kind ring-eviction counters ride
        along under ``"dropped.<kind>"`` keys, so one call exposes both
        the true totals and how much of each kind the sample window has
        lost."""
        with self._lock:
            out = dict(self._counts)
            if include_dropped:
                for kind, n in self._dropped.items():
                    out[f"dropped.{kind}"] = n
            return out

    def dropped(self) -> Dict[str, int]:
        """Per-kind count of events evicted from the ring (the gap
        between :meth:`counts` and what the sample views can still see).
        Empty until the tracer overflows ``capacity``."""
        with self._lock:
            return dict(self._dropped)

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    # -- aggregate views ----------------------------------------------------
    def shape_latency_ema(self) -> Dict[Tuple[int, int, int, str], float]:
        """(M, K, N, precision) → EMA of measured wall seconds."""
        with self._lock:
            return dict(self._ema)

    def occupancy_histogram(self, n_cores: Optional[int] = None,
                            bins: Sequence[float] = (0.25, 0.5, 1.0, 2.0,
                                                     4.0, 8.0)
                            ) -> Dict[str, int]:
        """Histogram of grid-tile *fill* (tiles / cores) over the observed
        matmul/resolve events — the §5 occupancy axis as seen at runtime.
        ``n_cores`` defaults to the *detected* hardware core count
        (:func:`repro.core.concurrency.detect_core_count`), so fills are
        hardware-correct without every caller remembering to pass it."""
        if n_cores is None:
            n_cores = cc.detect_core_count()
        edges = list(bins)
        labels = [f"<{edges[0]}"] + \
            [f"{lo}-{hi}" for lo, hi in zip(edges, edges[1:])] + \
            [f">={edges[-1]}"]
        hist = {lab: 0 for lab in labels}
        for ev in self.events():
            if ev.kind not in ("matmul", "resolve") or not ev.grid_tiles:
                continue
            fill = ev.grid_tiles / max(1, n_cores)
            idx = int(np.searchsorted(edges, fill, side="right"))
            hist[labels[idx]] += 1
        return hist

    def mean_fill(self, n_cores: Optional[int] = None) -> Optional[float]:
        """Mean grid-tile fill (tiles / cores) over the retained
        matmul/resolve events; ``None`` with no samples. The scalar form
        of :meth:`occupancy_histogram` that :class:`~repro.runtime.
        scheduler.AdaptiveQuota` consumes as its second signal: when the
        observed fill collapses, the §6 guidance is to *shrink* the
        concurrency budget, not just rebalance it. ``n_cores`` defaults
        to the detected hardware core count."""
        if n_cores is None:
            n_cores = cc.detect_core_count()
        fills = [ev.grid_tiles / max(1, n_cores) for ev in self.events()
                 if ev.kind in ("matmul", "resolve") and ev.grid_tiles]
        return float(np.mean(fills)) if fills else None

    def tenant_counts(self, kind: str = "request") -> Dict[str, int]:
        """Monotonic per-tenant event totals — exact on long runs (kept as
        counters, not derived from the evicting ring)."""
        with self._lock:
            return {tenant: c for (k, tenant), c
                    in self._tenant_counts.items() if k == kind}

    def known_tenants(self) -> List[str]:
        """Every tenant id that ever produced *any* event (register /
        route / admit / request / migrate …), sorted. Backed by the
        monotonic counters, so a tenant that was registered but never
        submitted a request still shows up — the fairness-report views
        must enumerate the full tenant population, not just the tenants
        with traffic."""
        with self._lock:
            return sorted({tenant for (_, tenant) in self._tenant_counts})

    def tenant_latencies(self, metric: str = "wall_s"
                         ) -> Dict[str, List[float]]:
        """Per-tenant request-latency samples over the *retained window*
        (the newest ``capacity`` events): a sliding view by design — the
        quota loop wants recent behavior, not all-time history.

        ``metric`` selects the latency domain: ``"wall_s"`` (wall-clock
        seconds) or ``"turnaround_steps"`` (deterministic scheduler steps,
        carried in the request event's meta — what :class:`~repro.runtime.
        scheduler.AdaptiveQuota` consumes so quota decisions are
        reproducible run-to-run)."""
        out: Dict[str, List[float]] = {}
        for ev in self.events("request"):
            if not ev.tenant:
                continue
            if metric == "wall_s":
                out.setdefault(ev.tenant, []).append(ev.wall_s)
            else:
                v = ev.meta.get(metric)
                if v is not None and v >= 0:
                    out.setdefault(ev.tenant, []).append(float(v))
        return out

    def tenant_percentiles(self, metric: str = "wall_s"
                           ) -> Dict[str, Dict[str, float]]:
        """Per-tenant p50/p99 of request latency over the retained window
        — the signal the fair_quantum quota loop consumes instead of
        static stream budgets."""
        return {t: cc.latency_percentiles(ls)
                for t, ls in self.tenant_latencies(metric).items()}

    def tenant_fairness(self) -> float:
        """Paper fairness index over per-tenant mean request latency
        (retained window)."""
        means = [float(np.mean(ls)) for ls in self.tenant_latencies().values()
                 if ls]
        return cc.fairness(means)

    def partition_counts(self, kind: Optional[str] = None) -> Dict[int, int]:
        """Events per partition tag over the retained window (fused-report
        provenance view: which sub-mesh produced what)."""
        out: Dict[int, int] = {}
        for ev in self.events(kind):
            out[ev.partition] = out.get(ev.partition, 0) + 1
        return out

    def mean_wall(self, kind: str) -> float:
        """Mean measured wall seconds of a kind over the retained window
        (0.0 with no measured samples). ``load_aware`` placement reads the
        per-partition ``decode`` mean as its congestion signal."""
        walls = [e.wall_s for e in self.events(kind) if e.wall_s > 0]
        return float(np.mean(walls)) if walls else 0.0

    # -- merging (fused multi-partition view) -------------------------------
    @classmethod
    def merge(cls, *tracers: "Tracer") -> "Tracer":
        """Fuse several tracers (one per spatial partition) into one view.

        The merged ring replays every retained event in timestamp order
        (capacity = sum of the sources', so nothing retained is dropped);
        monotonic counters are *summed from the sources' counters* — they
        stay exact even where the source rings have already evicted.
        Partition tags on the events are preserved, so per-partition
        provenance survives the merge."""
        if not tracers:
            return cls()
        merged = cls(capacity=sum(t.capacity for t in tracers),
                     ema_alpha=tracers[0].ema_alpha)
        events: List[Event] = []
        for tr in tracers:
            events.extend(tr.events())
        for ev in sorted(events, key=lambda e: e.t):
            merged._ring.append(ev)
            if ev.wall_s > 0 and ev.m and ev.k and ev.n:
                key = (ev.m, ev.k, ev.n, ev.precision)
                prev = merged._ema.get(key)
                merged._ema[key] = ev.wall_s if prev is None else \
                    (1 - merged.ema_alpha) * prev \
                    + merged.ema_alpha * ev.wall_s
        for tr in tracers:
            with tr._lock:
                counts = dict(tr._counts)
                tcounts = dict(tr._tenant_counts)
                dropped = dict(tr._dropped)
            for k, v in counts.items():
                merged._counts[k] = merged._counts.get(k, 0) + v
            for k, v in tcounts.items():
                merged._tenant_counts[k] = \
                    merged._tenant_counts.get(k, 0) + v
            for k, v in dropped.items():
                merged._dropped[k] = merged._dropped.get(k, 0) + v
        merged._warned_drop = True       # sources already warned
        return merged

    def overlap_groups(self) -> Dict[int, List[Event]]:
        """Wall-bearing events per overlap group over the retained window.
        A group is a set of ops the :class:`~repro.core.execution.
        OverlapPlanner` co-dispatched (same ``overlap_group`` id across
        lanes); serial events (``overlap_group == -1``) are excluded."""
        groups: Dict[int, List[Event]] = {}
        for ev in self.events():
            if ev.overlap_group >= 0 and ev.wall_s > 0:
                groups.setdefault(ev.overlap_group, []).append(ev)
        return groups

    def overlap_summary(self) -> Dict[str, float]:
        """Overlap efficiency achieved by the recorded overlap groups.

        Per group the serial estimate is the sum of member dispatch→ready
        walls and the concurrent estimate is their max (each member's wall
        already spans the co-dispatched region), mirroring
        :meth:`stream_overlap` but attributed to planner decisions.
        Groups need ≥2 wall-bearing members to count."""
        groups = [evs for evs in self.overlap_groups().values()
                  if len(evs) >= 2]
        if not groups:
            return {"groups": 0, "events": 0,
                    "mean_efficiency": 0.0, "mean_speedup": 0.0}
        effs, spds = [], []
        for evs in groups:
            walls = [e.wall_s for e in evs]
            serial, conc = float(sum(walls)), float(max(walls))
            effs.append(cc.overlap_efficiency(serial, conc, len(walls)))
            spds.append(serial / conc if conc > 0 else 0.0)
        return {"groups": len(groups),
                "events": int(sum(len(evs) for evs in groups)),
                "mean_efficiency": float(np.mean(effs)),
                "mean_speedup": float(np.mean(spds))}

    def stream_overlap(self) -> float:
        """Overlap efficiency implied by the recorded stream events (serial
        estimate = sum of per-stream times; wall = max)."""
        per_stream = [e.wall_s for e in self.events("stream")]
        if len(per_stream) < 2:
            return 0.0
        return cc.overlap_efficiency(float(sum(per_stream)),
                                     float(max(per_stream)),
                                     len(per_stream))

    # -- reporting / serialization -----------------------------------------
    def to_dicts(self) -> List[Dict[str, Any]]:
        return [e.to_dict() for e in self.events()]

    def summary(self, n_cores: Optional[int] = None) -> str:
        if n_cores is None:
            n_cores = cc.detect_core_count()
        counts = self.counts()
        lines = ["[telemetry] events: " + (", ".join(
            f"{k}={v}" for k, v in sorted(counts.items())) or "none")]
        dropped = self.dropped()
        if dropped:
            lines.append("  dropped (ring evictions): " + ", ".join(
                f"{k}={v}" for k, v in sorted(dropped.items())))
        hist = self.occupancy_histogram(n_cores=n_cores)
        if any(hist.values()):
            lines.append("  occupancy fill (×cores): " + " ".join(
                f"{lab}:{c}" for lab, c in hist.items() if c))
        ema = self.shape_latency_ema()
        if ema:
            worst = sorted(ema.items(), key=lambda kv: -kv[1])[:5]
            lines.append("  slowest shapes (EMA): " + "; ".join(
                f"{m}x{k}x{n}/{p or '?'}={s * 1e3:.2f}ms"
                for (m, k, n, p), s in worst))
        known = self.known_tenants()
        if known:
            tcounts = self.tenant_counts()
            pcts = self.tenant_percentiles()
            # enumerate EVERY known tenant: one that registered but never
            # submitted still appears (0 req) instead of silently
            # vanishing from the report
            lines.append("  tenants: " + "; ".join(
                (f"{t}: {tcounts[t]} req "
                 f"p50={pcts.get(t, {}).get('p50', 0.0) * 1e3:.1f}ms "
                 f"p99={pcts.get(t, {}).get('p99', 0.0) * 1e3:.1f}ms")
                if t in tcounts else f"{t}: 0 req"
                for t in known))
            lines.append(f"  tenant fairness={self.tenant_fairness():.3f}")
        migs = self.counts().get("migrate", 0)
        if migs:
            lines.append(f"  migrations: {migs} events")
        ov = self.overlap_summary()
        if ov["groups"]:
            lines.append(
                f"  overlap: {ov['groups']} group(s) / {ov['events']} ops, "
                f"mean efficiency={ov['mean_efficiency']:.3f} "
                f"speedup={ov['mean_speedup']:.2f}x")
        parts = {p: c for p, c in self.partition_counts().items() if p >= 0}
        if parts:
            lines.append("  partitions: " + " ".join(
                f"p{p}:{c}" for p, c in sorted(parts.items())))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Ambient tracer (deep call sites observe without plumbing)
# ---------------------------------------------------------------------------

_GLOBAL: Optional[Tracer] = None


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or clear, with None) the ambient tracer consulted by
    ``execution.matmul``/``resolve_policy``. Returns the previous one so
    callers can restore it."""
    global _GLOBAL
    prev, _GLOBAL = _GLOBAL, tracer
    return prev


def get_tracer() -> Optional[Tracer]:
    return _GLOBAL
