"""SLO-enforcing closed loop: attainment signal in, scheduler actions out.

PR 8 made SLO attainment *observable* (reports, metrics, dashboard);
nothing in the runtime acted on it. :class:`SLOController` closes the
loop: every ``interval`` global steps it re-derives a *recent* per-tenant
latency-class attainment from the scheduler's own records and, when a
latency tenant is missing (or trending toward a miss — queued/active
requests already past their turnaround target count as misses-in-
progress), pulls slots from batch-class co-tenants through the seams the
scheduler already has:

* ``freeze(tid)`` — the migration drain switch doubles as preemption:
  a frozen batch tenant admits nothing new, its in-flight requests
  finish, and its slots fall to the latency tenant. One freeze per
  control check (gradual actuation), biggest slot-holder first.
* ``cap_overrides`` — a :class:`~repro.runtime.scheduler.StreamScheduler`
  per-tenant slot-cap override (wins over the QuotaPolicy) that boosts
  the missing latency tenant to the full slot budget for the duration
  of the episode.

Release is hysteretic: enforcement starts below ``low``, but thaw only
begins after every latency tenant has held at/above ``high`` for
``hold`` consecutive checks, and unwinds one tenant per check (LIFO).
The ``low < high`` deadband plus the hold streak is what prevents
freeze/thaw ping-pong — the same shape as the migration loop's
hysteresis, test-pinned here too.

Every action lands in three places: the in-memory ledger
(:class:`ControllerAction`), a ``controller`` Tracer event (which
``MetricsSink`` folds into ``repro_controller_actions_total{action}``),
and the ``launch/top.py`` CTRL line.

Greedy decode is deterministic given admission order, and the PR 2
invariant (multi-tenant greedy == solo greedy, token-for-token) means
controller actions reshuffle WHEN requests run, never WHAT they decode —
fig23 asserts that equality in-benchmark.

The controller is duck-typed over the runtime (anything with
``step_count`` / ``schedulers`` / ``tracers``) so this module never
imports ``runtime.server`` — the server imports us.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Union

ACTIONS = ("freeze", "thaw", "boost", "unboost")
# Trend deadband: attainment deltas smaller than this are "steady".
TREND_EPS = 0.02


@dataclasses.dataclass(frozen=True)
class ControllerSpec:
    """Knobs for the closed loop (``ServingSpec(controller=...)``).

    ``low``/``high`` bound the hysteresis band on recent latency-class
    attainment: enforce below ``low``, release only after ``hold``
    consecutive checks at/above ``high``. ``window`` is the number of
    recent completions the attainment is computed over (the full-history
    report attainment is too sticky for control — early misses would
    keep a recovered tenant in the "missing" state forever).
    """
    enabled: bool = True
    interval: int = 4                # control period (global steps)
    low: float = 0.90                # enforce below this
    high: float = 0.97               # release at/above this (hysteresis)
    hold: int = 2                    # healthy checks before release
    window: int = 32                 # recent completions per tenant
    boost: bool = True               # slot-cap override for the victim
    max_frozen: int = 0              # frozen-tenant cap per partition
    #                                  (0: no cap)

    def __post_init__(self):
        if self.interval < 1:
            raise ValueError("controller interval must be >= 1")
        if not 0.0 < self.low < self.high <= 1.0:
            raise ValueError(f"controller needs 0 < low < high <= 1, "
                             f"got low={self.low} high={self.high}")
        if self.hold < 1:
            raise ValueError("controller hold must be >= 1")
        if self.window < 1:
            raise ValueError("controller window must be >= 1")
        if self.max_frozen < 0:
            raise ValueError("controller max_frozen must be >= 0")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_any(cls, v: Union[None, bool, Dict, "ControllerSpec"]
                 ) -> Optional["ControllerSpec"]:
        """None/False → None (no controller); True → defaults; dict →
        kwargs (unknown fields rejected); instance passes through."""
        if v is None or v is False:
            return None
        if v is True:
            return cls()
        if isinstance(v, ControllerSpec):
            return v
        if isinstance(v, dict):
            known = {f.name for f in dataclasses.fields(cls)}
            unknown = set(v) - known
            if unknown:
                raise ValueError(f"unknown ControllerSpec fields: "
                                 f"{sorted(unknown)}")
            return cls(**v)
        raise TypeError(f"controller spec {v!r} is not "
                        "None/bool/dict/ControllerSpec")

    @classmethod
    def parse(cls, s: Union[None, str]) -> Optional["ControllerSpec"]:
        """CLI form: ``"on"`` / ``""`` → defaults, else
        ``"interval=2,low=0.85,boost=0"`` key=value pairs."""
        if s is None:
            return None
        s = s.strip()
        if s in ("", "on", "true", "1"):
            return cls()
        if s in ("off", "false", "0"):
            return None
        kw: Dict[str, Any] = {}
        fields = {f.name: f.type for f in dataclasses.fields(cls)}
        for part in s.split(","):
            k, _, v = part.partition("=")
            k = k.strip()
            if k not in fields or not v.strip():
                raise ValueError(f"controller spec token {part!r} "
                                 f"(known keys: {sorted(fields)})")
            if k in ("enabled", "boost"):
                kw[k] = v.strip().lower() in ("1", "true", "on", "yes")
            elif k in ("interval", "hold", "window", "max_frozen"):
                kw[k] = int(v)
            else:
                kw[k] = float(v)
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class ControllerAction:
    """One ledger entry: what the loop did, to whom, and why."""
    step: int
    partition: int
    action: str                      # one of ACTIONS
    tenant: str                      # the acted-on tenant
    victim: str = ""                 # the latency tenant being protected
    attainment: Optional[float] = None   # victim's recent attainment

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _is_batch_class(t) -> bool:
    """Preemptible: no SLO, or an explicit best-effort batch class.
    throughput-class tenants hold a rate floor and are left alone."""
    return t.slo is None or t.slo.kind == "batch"


class SLOController:
    """The closed loop. Hook :meth:`on_step` into the runtime's global
    step; it is a no-op except every ``spec.interval`` steps."""

    def __init__(self, spec: ControllerSpec):
        self.spec = spec
        self.actions: List[ControllerAction] = []
        self.checks = 0
        # Per-partition actuation state. _frozen is OUR freeze list
        # (LIFO) — never touches tenants frozen by the migration drain.
        self._frozen: Dict[int, List[str]] = {}
        self._boosted: Dict[int, List[str]] = {}
        self._healthy_streak: Dict[int, int] = {}
        # Latest recent-attainment and its delta, for trend arrows.
        self._att: Dict[str, Optional[float]] = {}
        self._trend: Dict[str, float] = {}

    # -- introspection (top.py / tests) --------------------------------------
    def counts(self) -> Dict[str, int]:
        out = {a: 0 for a in ACTIONS}
        for act in self.actions:
            out[act.action] += 1
        return out

    def frozen_now(self) -> int:
        return sum(len(v) for v in self._frozen.values())

    def attainment(self, tenant_id: str) -> Optional[float]:
        return self._att.get(tenant_id)

    def trend_arrow(self, tenant_id: str) -> str:
        """"^" improving / "v" degrading / "=" steady / "" untracked."""
        if tenant_id not in self._att:
            return ""
        d = self._trend.get(tenant_id, 0.0)
        if d > TREND_EPS:
            return "^"
        if d < -TREND_EPS:
            return "v"
        return "="

    # -- the signal ----------------------------------------------------------
    def _recent_attainment(self, sched, t, now: int) -> Optional[float]:
        """Latency-class attainment over the last ``window`` completions
        PLUS every queued/active request already past target (a miss in
        progress — this is the "trending toward a miss" signal). Demand
        with no samples is starvation: 0.0. No demand, no samples: None."""
        slo = t.slo
        samples = [float(r.finish_step - r.submit_step)
                   for r in t.completed[-self.spec.window:]]
        # Misses in progress, counted per queued request: PENDING (waited
        # a full control period without a slot — under a deep batch
        # convoy this is how a miss starts, long before the deadline) or
        # DOOMED (age plus remaining decode budget at 1 token/step
        # already exceeds the target, so no admission can save it).
        overdue = sum(1 for r in t.queue
                      if now - r.submit_step >= self.spec.interval
                      or now - r.submit_step + r.max_new > slo.target)
        for slot in sched.session.slots:
            if (slot is not None and slot.tenant == t.tenant_id
                    and now - slot.submit_step
                    + (slot.max_new - len(slot.out)) > slo.target):
                overdue += 1
        demand = bool(t.queue) or t.active > 0
        n = len(samples) + overdue
        if n == 0:
            return 0.0 if demand else None
        met = sum(1 for s in samples if s <= slo.target)
        return met / n

    # -- actuation -----------------------------------------------------------
    def _record(self, runtime, p: int, action: str, tenant: str,
                victim: str = "",
                attainment: Optional[float] = None) -> None:
        step = runtime.step_count
        self.actions.append(ControllerAction(
            step=step, partition=p, action=action, tenant=tenant,
            victim=victim, attainment=attainment))
        tracer = runtime.tracers[p] if runtime.tracers else None
        if tracer is not None:
            tracer.record("controller", tenant=tenant, step=step,
                          partition=p,
                          meta={"action": action, "victim": victim,
                                "attainment": attainment})

    def _enforce(self, runtime, p: int, sched,
                 missing: List[Any]) -> None:
        """One check's worth of pressure: boost every missing latency
        tenant's cap, freeze ONE more batch tenant (largest holder of
        slots+queue first)."""
        self._healthy_streak[p] = 0
        if self.spec.boost:
            boosted = self._boosted.setdefault(p, [])
            for t, att in missing:
                if t.tenant_id in boosted:
                    continue
                sched.cap_overrides[t.tenant_id] = \
                    sched.session.batch_slots
                boosted.append(t.tenant_id)
                self._record(runtime, p, "boost", t.tenant_id,
                             victim=t.tenant_id, attainment=att)
        frozen = self._frozen.setdefault(p, [])
        if self.spec.max_frozen and len(frozen) >= self.spec.max_frozen:
            return
        order = {tid: i for i, tid in enumerate(sched._order)}
        cands = [t for t in sched.tenants.values()
                 if _is_batch_class(t) and not t.frozen]
        if not cands:
            return
        victim_t, victim_att = missing[0]
        prey = max(cands, key=lambda t: (t.active + len(t.queue),
                                         -order[t.tenant_id]))
        sched.freeze(prey.tenant_id)
        frozen.append(prey.tenant_id)
        self._record(runtime, p, "freeze", prey.tenant_id,
                     victim=victim_t.tenant_id, attainment=victim_att)

    def _release(self, runtime, p: int, sched) -> None:
        """After ``hold`` healthy checks: unwind one freeze per check
        (LIFO); once nothing is frozen, drop the boosts too."""
        frozen = self._frozen.get(p) or []
        while frozen:
            tid = frozen.pop()
            if tid not in sched.tenants:
                continue            # migrated away; nothing to thaw here
            sched.thaw(tid)
            self._record(runtime, p, "thaw", tid)
            break
        if frozen:
            return
        for tid in self._boosted.get(p) or []:
            sched.cap_overrides.pop(tid, None)
            self._record(runtime, p, "unboost", tid)
        self._boosted[p] = []

    # -- the loop ------------------------------------------------------------
    def on_step(self, runtime) -> None:
        step = runtime.step_count
        if step == 0 or step % self.spec.interval:
            return
        self.checks += 1
        for p, sched in enumerate(runtime.schedulers):
            now = sched.step_count
            lat = [t for t in sched.tenants.values()
                   if t.slo is not None and t.slo.kind == "latency"]
            missing: List[Any] = []
            all_healthy = True
            for t in lat:
                att = self._recent_attainment(sched, t, now)
                prev = self._att.get(t.tenant_id)
                self._trend[t.tenant_id] = (
                    (att - prev) if att is not None and prev is not None
                    else 0.0)
                self._att[t.tenant_id] = att
                # A latency tenant with nothing left to serve is healthy
                # no matter what its history says: there is nothing to
                # protect, and holding batch tenants frozen for it would
                # deadlock the drain.
                if att is None or not (t.queue or t.active):
                    continue
                if att < self.spec.low:
                    missing.append((t, att))
                if att < self.spec.high:
                    all_healthy = False
            if missing:
                missing.sort(key=lambda ta: ta[1])
                self._enforce(runtime, p, sched, missing)
            elif all_healthy:
                streak = self._healthy_streak.get(p, 0) + 1
                self._healthy_streak[p] = streak
                if streak >= self.spec.hold and (
                        self._frozen.get(p) or self._boosted.get(p)):
                    self._release(runtime, p, sched)
            else:
                # Deadband (low <= att < high somewhere): hold position.
                self._healthy_streak[p] = 0
