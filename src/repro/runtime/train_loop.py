"""Train-step builder: loss, grads, compression, optimizer, metrics.

``make_train_step`` returns a pure function suitable for ``jax.jit`` with
in/out shardings from runtime/sharding.py; the launcher (launch/train.py)
and the dry-run (launch/dryrun.py) both consume it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, RunConfig
from repro.core import execution as ex
from repro.models import forward
from repro.models.transformer import forward_hidden
from repro.models.layers import RuntimeCfg, DEFAULT_RT, lm_logits
from repro.optim import adamw
from repro.optim import grad_compress as gc

AUX_LOSS_WEIGHT = 0.01
CE_CHUNK = 512         # seq-chunked fused LM-head loss (never materializes
                       # the full f32 (B, S, V) logits tensor)


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    grad_error: Optional[Any]       # int8 error-feedback carry (or None)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_size: int) -> jax.Array:
    """Mean next-token CE. logits (B,S,Vp) f32 (padding already -inf)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def chunked_cross_entropy(hidden: jax.Array, head_w: jax.Array,
                          labels: jax.Array, vocab_size: int,
                          chunk: int = CE_CHUNK,
                          policy=None) -> jax.Array:
    """Fused head+CE over seq chunks; each chunk rematted so backward
    recomputes its logits instead of keeping them resident."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)

    def one(h_c, l_c):
        logits = lm_logits(h_c, head_w, vocab_size, policy=policy)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, l_c[..., None], axis=-1)[..., 0]
        return -jnp.sum(ll)

    one = jax.checkpoint(one)
    total = jnp.zeros((), jnp.float32)
    for i in range(s // chunk):
        sl = slice(i * chunk, (i + 1) * chunk)
        total = total + one(hidden[:, sl], labels[:, sl])
    return total / (b * s)


def make_loss_fn(cfg: ArchConfig, rt: RuntimeCfg):
    pol = ex.policy_from(cfg, rt)

    def loss_fn(params, batch):
        hidden, aux = forward_hidden(params, batch["inputs"], cfg, rt)
        ce = chunked_cross_entropy(hidden, params["head"], batch["labels"],
                                   cfg.vocab_size, policy=pol)
        loss = ce + AUX_LOSS_WEIGHT * aux
        return loss, {"loss": loss, "ce": ce, "aux": aux}
    return loss_fn


def init_state(params, opt_cfg: adamw.AdamWConfig,
               grad_compress: str = "none") -> TrainState:
    err = gc.init_error(params) if grad_compress == "int8_ef" else None
    return TrainState(params=params, opt=adamw.init(params, opt_cfg),
                      grad_error=err)


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    rt: RuntimeCfg = DEFAULT_RT,
                    grad_compress: str = "none",
                    microbatch: int = 0,
                    policy: Optional[ex.ExecutionPolicy] = None,
                    telemetry=None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatch > 0`` enables gradient accumulation: the global batch is
    split into ``global_batch // microbatch`` sequential chunks (scanned) —
    the activation-memory knob for the big train cells.

    ``policy`` (when given) overrides cfg.precision / cfg.sparsity_24 /
    rt.use_pallas for every matmul in the step — the one seam for backend
    sweeps (see core/execution.apply_policy).

    ``telemetry`` (a :class:`repro.runtime.telemetry.Tracer`, duck-typed)
    records the build-time configuration and is installed as the ambient
    tracer while the step traces, so every ``matmul`` the step dispatches
    lands in the tracer's occupancy/shape accounting. The returned step is
    jitted by the caller — per-step wall times are the launcher's to
    record (it owns the host-side clock).
    """
    if policy is not None:
        cfg, rt = ex.apply_policy(cfg, rt, policy)
    if telemetry is not None:
        telemetry.record("train_build", precision=cfg.precision,
                         policy=policy.spec() if policy else "",
                         meta={"grad_compress": grad_compress,
                               "microbatch": microbatch,
                               "d_model": cfg.d_model, "d_ff": cfg.d_ff})
    loss_fn = make_loss_fn(cfg, rt)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if not microbatch:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        b = batch["inputs"].shape[0]
        assert b % microbatch == 0, (b, microbatch)
        n_chunks = b // microbatch
        chunked = jax.tree.map(
            lambda x: x.reshape(n_chunks, microbatch, *x.shape[1:]), batch)

        def body(acc, mb):
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return acc, metrics
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, metrics = jax.lax.scan(body, zeros, chunked)
        grads = jax.tree.map(lambda g: g / n_chunks, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return grads, metrics

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        grads, metrics = compute_grads(state.params, batch)
        new_err = state.grad_error
        if grad_compress == "bf16":
            grads = gc.compress_bf16(grads)
        elif grad_compress == "int8_ef":
            grads, new_err = gc.compress_int8_ef(grads, state.grad_error)
        new_params, new_opt, opt_metrics = adamw.apply(
            state.params, grads, state.opt, opt_cfg)
        metrics = {**metrics, **opt_metrics}
        return TrainState(new_params, new_opt, new_err), metrics

    if telemetry is None:
        return train_step

    def traced_step(state: TrainState, batch):
        # Ambient tracer installed for the duration of the body: under
        # jit this is trace time, so every matmul the step dispatches is
        # observed exactly once per specialization.
        from repro.runtime import telemetry as tm
        prev = tm.set_tracer(telemetry)
        try:
            return train_step(state, batch)
        finally:
            tm.set_tracer(prev)

    return traced_step


def state_shape(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                params_shape_tree, grad_compress: str = "none") -> TrainState:
    return jax.eval_shape(
        lambda p: init_state(p, opt_cfg, grad_compress), params_shape_tree)
