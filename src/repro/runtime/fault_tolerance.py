"""Fault tolerance: heartbeats, straggler detection, supervised restart.

At 1000+ nodes, three failure modes dominate; each maps to a mechanism here:

* hard node failure      → supervisor (launch/train.py --supervise) re-execs
                           the job; restart resumes from the last committed
                           checkpoint + data cursor (bitwise replay).
* straggling node        → StepMonitor flags steps slower than mean + k·σ
                           (EWMA); the launcher logs/exports the signal so a
                           cluster scheduler can drain-and-replace the host.
* hung collective        → watchdog thread aborts the process if no step
                           completes within ``hang_timeout_s`` — turning a
                           silent hang into a supervised restart.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class StepStats:
    step: int
    duration_s: float
    is_straggler: bool
    ewma_s: float


class StepMonitor:
    """EWMA step-time tracker with straggler flagging."""

    def __init__(self, alpha: float = 0.1, k_sigma: float = 3.0,
                 warmup_steps: int = 5):
        self.alpha = alpha
        self.k = k_sigma
        self.warmup = warmup_steps
        self.ewma: Optional[float] = None
        self.ewvar: float = 0.0
        self.n = 0
        self.history: List[StepStats] = []

    def record(self, step: int, duration_s: float) -> StepStats:
        self.n += 1
        if self.ewma is None:
            self.ewma = duration_s
        delta = duration_s - self.ewma
        straggler = False
        if self.n > self.warmup:
            sigma = max(self.ewvar, 1e-12) ** 0.5
            straggler = delta > self.k * sigma and delta > 0.05 * self.ewma
        self.ewma += self.alpha * delta
        self.ewvar = (1 - self.alpha) * (self.ewvar
                                         + self.alpha * delta * delta)
        st = StepStats(step, duration_s, straggler, self.ewma)
        self.history.append(st)
        return st


class Heartbeat:
    """Periodic liveness file for external supervisors; also an in-process
    watchdog that aborts on hang (no `beat()` within hang_timeout_s)."""

    def __init__(self, path: str, interval_s: float = 10.0,
                 hang_timeout_s: float = 0.0,
                 on_hang: Optional[Callable[[], None]] = None):
        self.path = path
        self.interval = interval_s
        self.hang_timeout = hang_timeout_s
        self.on_hang = on_hang or (lambda: os._exit(42))
        self._last_beat = time.monotonic()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def beat(self, step: int = -1):
        self._last_beat = time.monotonic()
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"time": time.time(), "step": step,
                       "pid": os.getpid()}, f)
        os.replace(tmp, self.path)

    def _worker(self):
        while not self._stop.wait(self.interval):
            if (self.hang_timeout
                    and time.monotonic() - self._last_beat > self.hang_timeout):
                self.on_hang()

    def close(self):
        self._stop.set()


def supervise(run_fn: Callable[[], int], max_restarts: int = 100,
              backoff_s: float = 5.0, log=print) -> int:
    """In-process supervisor: call ``run_fn`` until it returns 0 or the
    restart budget is exhausted. ``run_fn`` is expected to resume from the
    latest checkpoint on re-entry."""
    for attempt in range(max_restarts + 1):
        try:
            rc = run_fn()
        except Exception as e:  # noqa: BLE001 — any crash triggers restart
            log(f"[supervisor] run crashed ({type(e).__name__}: {e}); "
                f"attempt {attempt + 1}/{max_restarts}")
            rc = 1
        if rc == 0:
            return 0
        if attempt == max_restarts:
            break
        time.sleep(backoff_s)
        log(f"[supervisor] restarting (attempt {attempt + 1})")
    return 1
