"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent: for each cell it
jits the real train/prefill/decode step with production shardings over the
16×16 (single-pod) and 2×16×16 (multi-pod) meshes, compiles, and records
``memory_analysis()`` (fits?) + ``cost_analysis()`` + the collective
schedule (roofline terms). It also lowers ONE super-layer standalone so
scan-body costs can be scaled by depth (launch/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all --out benchmarks/artifacts/dryrun.jsonl
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices — set
# before ANY other import; jax locks the device count on first init.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# persistent compilation cache: sweep re-runs and hillclimb iterations skip
# recompiles of unchanged cells
jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 5.0)

from repro.configs import (
    ARCH_NAMES, ARCHS, applicable_shapes, get_arch, get_shape)
from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import (
    cache_shape, decode_step, forward, params_shape, prefill)
from repro.models import transformer as tf
from repro.models.layers import RuntimeCfg
from repro.optim import adamw
from repro.runtime import sharding as sh
from repro.runtime import train_loop as tl


# ---------------------------------------------------------------------------
# Runtime config for lowering
# ---------------------------------------------------------------------------

def make_rt(cfg: ArchConfig, mesh, shape: ShapeConfig,
            seq_shard_acts: bool = True) -> RuntimeCfg:
    chunk = 2048 if shape.seq_len >= 32768 else 1024
    chunk_q = chunk
    if cfg.attn_strategy == "seq_tp" and not shape.is_decode:
        # context parallelism: q stays seq-sharded — process all q rows per
        # kv block (slicing a sharded dim would force gathers). Costs the
        # causal-skip FLOPs; documented in EXPERIMENTS.md.
        chunk_q = shape.seq_len
    return RuntimeCfg(
        chunk_q=chunk_q, chunk_kv=chunk,
        static_loops=True,             # exact HLO cost, no hidden scan bodies
        f32_batched_dots=False,        # TPU contract: bf16 operands, f32 acc
        shard_fn=sh.make_shard_fn(cfg, mesh, shape,
                                  seq_shard_acts=seq_shard_acts),
    )


def input_struct(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.input_mode == "embeddings" and not shape.is_decode:
        inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        return {"inputs": inputs,
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return {"inputs": inputs}


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _cost_of(compiled) -> rl.CellCost:
    ca = compiled.cost_analysis()
    txt = compiled.as_text()
    return rl.CellCost(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        wire_bytes=rl.collective_wire_bytes(txt),
        collectives=rl.collective_summary(txt),
        wire_bytes_bf16=rl.collective_wire_bytes_bf16(txt),
    )


def _mem_of(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    per_dev = (ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    return {
        "argument": ma.argument_size_in_bytes,
        "output": ma.output_size_in_bytes,
        "temp": ma.temp_size_in_bytes,
        "alias": ma.alias_size_in_bytes,
        "per_device_total": per_dev,
    }


def lower_train(cfg: ArchConfig, shape: ShapeConfig, mesh, rt: RuntimeCfg,
                with_layer: bool = True, grad_compress: str = "none",
                policy: str = "tp_fsdp"):
    opt_cfg = adamw.AdamWConfig()
    pshape = params_shape(cfg)
    st_shape = tl.state_shape(cfg, opt_cfg, pshape)
    pspecs = sh.param_specs(cfg, mesh, pshape, policy)
    st_specs = tl.TrainState(
        params=pspecs,
        opt=adamw.AdamWState(step=P(), mu=pspecs, nu=pspecs, master=pspecs),
        grad_error=None)
    bspec = sh.input_spec(cfg, shape, mesh)
    if policy == "fsdp_only":
        ball = ("pod", "data", "model") if "pod" in mesh.axis_names \
            else ("data", "model")
        if shape.global_batch % sh.axis_size(mesh, ball) == 0:
            bspec = P(ball, *tuple(bspec)[1:])
    batch_specs = {"inputs": bspec, "labels": P(bspec[0], None)}
    batch_shape = input_struct(cfg, shape)

    step = tl.make_train_step(cfg, opt_cfg, rt, grad_compress=grad_compress)
    jf = jax.jit(step,
                 in_shardings=(_ns(mesh, st_specs), _ns(mesh, batch_specs)),
                 out_shardings=(_ns(mesh, st_specs), None),
                 donate_argnums=(0,))
    with jax.set_mesh(mesh):
        lowered = jf.lower(st_shape, batch_shape)
        compiled = lowered.compile()

        layer_cost = None
        if with_layer:
            layer_cost = _lower_train_layer(cfg, shape, mesh, rt, pshape,
                                            pspecs, bspec, policy)
    return compiled, layer_cost


def _act_spec(cfg, shape, mesh, bspec, policy="tp_fsdp"):
    """Residual-stream spec matching the act_btd anchor (seq on model)."""
    sx = "model" if shape.seq_len % sh.axis_size(mesh, "model") == 0 else None
    if shape.is_decode or policy == "fsdp_only":
        sx = None
    return P(bspec[0], sx, None)


def _lower_train_layer(cfg, shape, mesh, rt, pshape, pspecs, bspec,
                       policy="tp_fsdp"):
    B, S = shape.global_batch, shape.seq_len
    x = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    p_super = tf.superlayer_params_slice(pshape)
    ps_specs = sh.param_specs(cfg, mesh, p_super, policy)
    shared = pshape.get("shared_attn")
    sh_specs = sh.param_specs(cfg, mesh, shared, policy) if shared else None
    xspec = _act_spec(cfg, shape, mesh, bspec, policy)

    def fn(x, ct, p_super, shared):
        return tf.superlayer_train_cost(x, ct, p_super, shared, cfg, rt)

    in_sh = (_ns(mesh, xspec), _ns(mesh, xspec), _ns(mesh, ps_specs),
             _ns(mesh, sh_specs) if shared else None)
    out_sh = (_ns(mesh, xspec), _ns(mesh, ps_specs),
              _ns(mesh, sh_specs) if shared else None)
    if shared is None:
        out_sh = out_sh[:2]
    jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    compiled = jf.lower(x, x, p_super, shared).compile()
    return _cost_of(compiled)


def _add_cost(a: rl.CellCost, b: rl.CellCost, mult: float) -> rl.CellCost:
    colls = dict(a.collectives)
    for k, v in b.collectives.items():
        e = colls.setdefault(k, {"count": 0, "wire_bytes": 0.0})
        e["count"] += v["count"] * mult
        e["wire_bytes"] += v["wire_bytes"] * mult
    return rl.CellCost(
        flops=a.flops + mult * b.flops,
        bytes_accessed=a.bytes_accessed + mult * b.bytes_accessed,
        wire_bytes=a.wire_bytes + mult * b.wire_bytes,
        collectives=colls,
        wire_bytes_bf16=a.wire_bytes_bf16 + mult * b.wire_bytes_bf16)


def _lower_ssm_chunk_probe(cfg, shape, mesh, rt, bspec):
    """Per-chunk cost for SSM stacks when the layer probe falls back to
    lax.scan (nchunks > max_static_chunks): cost_analysis counts the chunk
    body once, so the probe lowers ONE chunk standalone and the caller adds
    (nchunks-1) × chunk × blocks_per_superlayer."""
    B = shape.global_batch
    ba = bspec[0]
    Lc = min(rt.ssm_chunk, cfg.ssm_chunk, shape.seq_len)
    if cfg.ssm_kind == "mamba2":
        from repro.models.mamba2 import _ssd_chunk
        nh, hp, N = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
        args = (jax.ShapeDtypeStruct((B, Lc, nh, hp), jnp.float32),
                jax.ShapeDtypeStruct((B, Lc, nh), jnp.float32),
                jax.ShapeDtypeStruct((B, Lc, nh), jnp.float32),
                jax.ShapeDtypeStruct((B, Lc, N), jnp.float32),
                jax.ShapeDtypeStruct((B, Lc, N), jnp.float32),
                jax.ShapeDtypeStruct((B, nh, hp, N), jnp.float32))
        specs = (P(ba, None, "model", None), P(ba, None, None),
                 P(ba, None, None), P(ba, None, None), P(ba, None, None),
                 P(ba, "model", None, None))
        fn = _ssd_chunk
    else:
        from repro.models.rwkv6 import _wkv_chunk
        nh = cfg.d_model // cfg.ssm_head_dim
        hd = cfg.ssm_head_dim
        args = (jax.ShapeDtypeStruct((B, Lc, nh, hd), jnp.float32),) * 4 + (
            jax.ShapeDtypeStruct((nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nh, hd, hd), jnp.float32))
        specs = (P(ba, None, None, None), P(ba, None, None, None),
                 P(ba, None, None, "model"), P(ba, None, None, None),
                 P(None, None), P(ba, None, None, "model"))
        fn = _wkv_chunk
    jf = jax.jit(fn, in_shardings=tuple(_ns(mesh, s) for s in specs))
    compiled = jf.lower(*args).compile()
    nchunks = shape.seq_len // Lc
    return _cost_of(compiled), nchunks


def lower_prefill(cfg: ArchConfig, shape: ShapeConfig, mesh, rt: RuntimeCfg,
                  with_layer: bool = True):
    pshape = params_shape(cfg)
    pspecs = sh.param_specs(cfg, mesh, pshape)
    bspec = sh.input_spec(cfg, shape, mesh)
    batch_shape = input_struct(cfg, shape)["inputs"]

    def fn(params, inputs):
        return prefill(params, inputs, cfg, rt)

    jf = jax.jit(fn, in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspec)),
                 out_shardings=None)
    with jax.set_mesh(mesh):
        lowered = jf.lower(pshape, batch_shape)
        compiled = lowered.compile()

        layer_cost = None
        if with_layer:
            B, S = shape.global_batch, shape.seq_len
            x = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
            p_super = tf.superlayer_params_slice(pshape)
            ps_specs = sh.param_specs(cfg, mesh, p_super)
            shared = pshape.get("shared_attn")
            sh_specs = sh.param_specs(cfg, mesh, shared) if shared else None
            xspec = _act_spec(cfg, shape, mesh, bspec)

            def lfn(x, p_super, shared):
                return tf.superlayer_forward(x, p_super, shared, cfg, rt)
            in_sh = (_ns(mesh, xspec), _ns(mesh, ps_specs),
                     _ns(mesh, sh_specs) if shared else None)
            ljf = jax.jit(lfn, in_shardings=in_sh,
                          out_shardings=(_ns(mesh, xspec), None))
            layer_cost = _cost_of(ljf.lower(x, p_super, shared).compile())
            # SSM chunk scans fall back to lax.scan at this seq len — add
            # the per-chunk correction (body counted once otherwise)
            if cfg.ssm_kind:
                Lc = min(rt.ssm_chunk, cfg.ssm_chunk, shape.seq_len)
                if shape.seq_len // Lc > rt.max_static_chunks:
                    chunk_cost, nchunks = _lower_ssm_chunk_probe(
                        cfg, shape, mesh, rt, bspec)
                    blocks = sum(1 for k in cfg.superlayer_pattern
                                 if k in ("mamba2", "rwkv6"))
                    layer_cost = _add_cost(layer_cost, chunk_cost,
                                           (nchunks - 1) * blocks)
    return compiled, layer_cost


def lower_decode(cfg: ArchConfig, shape: ShapeConfig, mesh, rt: RuntimeCfg,
                 with_layer: bool = True):
    B, S = shape.global_batch, shape.seq_len
    pshape = params_shape(cfg)
    pspecs = sh.param_specs(cfg, mesh, pshape)
    cshape = cache_shape(cfg, B, S)
    cspecs = sh.cache_specs(cfg, shape, mesh, cshape)
    ba = sh.batch_axes(mesh)
    baxes = ba if B % sh.axis_size(mesh, ba) == 0 else None
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(params, tokens, caches, pos):
        return decode_step(params, tokens, caches, pos, cfg, rt)

    jf = jax.jit(
        fn,
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, P(baxes, None)),
                      _ns(mesh, cspecs), _ns(mesh, P())),
        out_shardings=(_ns(mesh, sh.logits_spec(cfg, shape, mesh)),
                       _ns(mesh, cspecs)),
        donate_argnums=(2,))
    with jax.set_mesh(mesh):
        lowered = jf.lower(pshape, tok, cshape, pos)
        compiled = lowered.compile()

        layer_cost = None
        if with_layer:
            x = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
            p_super = tf.superlayer_params_slice(pshape)
            ps_specs = sh.param_specs(cfg, mesh, p_super)
            c_super = tf.superlayer_cache_slice(cshape)
            cs_specs = jax.tree.map(
                lambda p: P(*tuple(p)[1:]), cspecs["layers"],
                is_leaf=lambda t: isinstance(t, P))
            shared = pshape.get("shared_attn")
            sh_specs = sh.param_specs(cfg, mesh, shared) if shared else None

            def lfn(x, p_super, cache, shared):
                return tf.superlayer_decode(x, p_super, cache, S - 1, shared,
                                            cfg, rt)
            in_sh = (_ns(mesh, P(baxes, None, None)), _ns(mesh, ps_specs),
                     _ns(mesh, cs_specs),
                     _ns(mesh, sh_specs) if shared else None)
            ljf = jax.jit(lfn, in_shardings=in_sh, out_shardings=None)
            layer_cost = _cost_of(ljf.lower(x, p_super, c_super,
                                            shared).compile())
    return compiled, layer_cost


# ---------------------------------------------------------------------------
# One cell end-to-end
# ---------------------------------------------------------------------------

def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             with_layer: bool = True, verbose: bool = True) -> Dict:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rt = make_rt(cfg, mesh, shape)
    rec: Dict[str, Any] = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single", "chips": chips,
    }
    t0 = time.time()
    try:
        lower = {"train": lower_train, "prefill": lower_prefill}.get(
            shape.kind, lower_decode)
        compiled, layer = lower(cfg, shape, mesh, rt, with_layer)
        rec["ok"] = True
        rec["compile_s"] = time.time() - t0
        # XLA:CPU buffer assignment keeps every unrolled block's temps live
        # (scheduling artifact — TPU's memory-aware scheduler serializes), so
        # the authoritative memory probe lowers the scan-based variant of the
        # same step: one block body in HLO => bounded liveness.
        rt_mem = dataclasses.replace(rt, static_loops=False)
        mem_compiled, _ = lower(cfg, shape, mesh, rt_mem, False)
        rec["memory"] = _mem_of(mem_compiled)
        rec["memory_static_sched"] = _mem_of(compiled)
        full = _cost_of(compiled)
        rec["full"] = dataclasses.asdict(full)
        rec["layer"] = dataclasses.asdict(layer) if layer else None
        rec["n_bodies"] = cfg.num_superlayers
        rec["model_flops"] = rl.model_flops_estimate(cfg, shape)
        rec["min_bytes"] = rl.min_bytes_estimate(cfg, shape)
        if not multi_pod:
            roof = rl.assemble(arch_name, shape_name, chips, full, layer,
                               cfg.num_superlayers, rec["model_flops"],
                               min_bytes=rec["min_bytes"], kind=shape.kind)
            rec["roofline"] = roof.to_dict()
        if verbose:
            print(f"[{arch_name} × {shape_name} × {rec['mesh']}] OK "
                  f"compile={rec['compile_s']:.1f}s "
                  f"mem/dev={rec['memory']['per_device_total']/2**30:.2f}GiB")
            print("  memory_analysis:", rec["memory"])
            print("  cost_analysis: flops=%.3e bytes=%.3e wire=%.3e"
                  % (full.flops, full.bytes_accessed, full.wire_bytes))
            if "roofline" in rec:
                r = rec["roofline"]
                print("  roofline: compute=%.4fs memory=%.4fs coll=%.4fs "
                      "bottleneck=%s frac=%.3f"
                      % (r["compute_s"], r["memory_s"], r["collective_s"],
                         r["bottleneck"], r["roofline_fraction"]))
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["ok"] = False
        rec["compile_s"] = time.time() - t0
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{arch_name} × {shape_name} × {rec['mesh']}] FAIL "
                  f"{rec['error'][:200]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-layer", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    done = set()
    if args.out and args.skip_done and os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                pass

    cells = []
    if args.all:
        for name in ARCH_NAMES:
            for shp in applicable_shapes(ARCHS[name]):
                cells.append((name, shp.name, False))
                cells.append((name, shp.name, True))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, args.multi_pod)]

    n_ok = 0
    for arch, shp, multi in cells:
        key = (arch, shp, "multi" if multi else "single")
        if key in done:
            print(f"[{arch} × {shp} × {key[2]}] cached, skipping")
            n_ok += 1
            continue
        rec = run_cell(arch, shp, multi,
                       with_layer=(not args.no_layer) and not multi)
        n_ok += bool(rec["ok"])
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    print(f"dry-run: {n_ok}/{len(cells)} cells OK")
    return 0 if n_ok == len(cells) else 1


if __name__ == "__main__":
    raise SystemExit(main())
