"""Traffic generator CLI: synthesize, save, and replay serving workloads.

The workload plane's front door (``python -m repro.launch.loadgen``):

* **generate + run** — build a seed-deterministic
  :class:`~repro.runtime.workload.WorkloadSpec` from flags (Zipf tenant
  popularity, poisson/bursty/diurnal arrivals, mixed length
  distributions), drive it through a :class:`ServingRuntime`, and print
  the per-tenant report plus a token checksum.
* **record** — ``--save-trace PATH`` writes the generated
  :class:`WorkloadTrace` as JSON (``--gen-only`` skips the run).
* **replay** — ``--replay PATH`` loads a saved trace and drives it
  through a fresh runtime. Traces are self-contained (prompts and
  output budgets inline), so a replay reproduces the generating run's
  committed tokens bit-for-bit — the printed
  ``tokens_checksum`` line is the equality witness CI greps for.

Examples::

    python -m repro.launch.loadgen --arch llama3-8b --reduced \
        --tenants 3 --arrival bursty --rate 1.0 --steps 40 \
        --slos batch,batch,latency:20 --controller --save-trace /tmp/w.json
    python -m repro.launch.loadgen --arch llama3-8b --reduced \
        --replay /tmp/w.json
"""
import argparse
import sys
import time


def _lengths(lo: int, hi: int, long_lo: int, long_hi: int,
             long_frac: float):
    from repro.runtime.workload import LengthDist
    if long_frac > 0:
        return LengthDist(lo=lo, hi=hi, long_lo=long_lo, long_hi=long_hi,
                          long_frac=long_frac)
    return LengthDist(lo=lo, hi=hi)


def build_workload(args):
    from repro.runtime.workload import WorkloadSpec
    slos = None
    if args.slos:
        slos = tuple(s.strip() or None for s in args.slos.split(","))
    weights = ()
    if args.weights:
        weights = tuple(float(w) for w in args.weights.split(","))
    overrides = ()
    if args.latency_max_new:
        # shorthand: every latency-class rank answers short
        lo, _, hi = args.latency_max_new.partition(":")
        dist = (int(lo), int(hi or lo))
        overrides = tuple(
            dist if slos and slos[i] and slos[i].startswith("latency")
            else None for i in range(args.tenants))
    return WorkloadSpec(
        tenants=args.tenants, zipf_s=args.zipf_s, arrival=args.arrival,
        rate=args.rate, burst_factor=args.burst_factor,
        burst_len=args.burst_len, period=args.period,
        amplitude=args.amplitude, steps=args.steps,
        prompt_len=_lengths(args.prompt_lo, args.prompt_hi, args.long_lo,
                            args.long_hi, 0.0),
        max_new=_lengths(args.new_lo, args.new_hi, args.long_lo,
                         args.long_hi, args.long_frac),
        max_new_overrides=overrides, vocab=args.vocab,
        slos=slos or (), weights=weights, seed=args.seed)


def main():
    ap = argparse.ArgumentParser(
        description="workload generator / trace replay for the serving "
                    "runtime")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    # -- workload shape ------------------------------------------------------
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--zipf-s", type=float, default=1.1,
                    help="tenant popularity skew (0: uniform)")
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty", "diurnal"])
    ap.add_argument("--rate", type=float, default=1.0,
                    help="aggregate mean arrivals per scheduler step — "
                         "the millions-of-users knob")
    ap.add_argument("--burst-factor", type=float, default=4.0)
    ap.add_argument("--burst-len", type=int, default=8)
    ap.add_argument("--period", type=int, default=64)
    ap.add_argument("--amplitude", type=float, default=0.8)
    ap.add_argument("--steps", type=int, default=64,
                    help="arrival horizon in scheduler steps")
    ap.add_argument("--prompt-lo", type=int, default=4)
    ap.add_argument("--prompt-hi", type=int, default=8)
    ap.add_argument("--new-lo", type=int, default=4)
    ap.add_argument("--new-hi", type=int, default=8)
    ap.add_argument("--long-lo", type=int, default=12)
    ap.add_argument("--long-hi", type=int, default=16)
    ap.add_argument("--long-frac", type=float, default=0.0,
                    help="long-output mixture weight for max_new")
    ap.add_argument("--latency-max-new", default=None, metavar="LO:HI",
                    help="max_new override for latency-class ranks "
                         "(interactive tenants answer short)")
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--slos", default=None,
                    help="comma list per tenant rank, e.g. "
                         "'batch,batch,latency:20' (empty entry: none)")
    ap.add_argument("--weights", default=None,
                    help="comma list of per-rank scheduler weights")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed (traffic only — model weights "
                         "come from --model-seed so a replay reproduces "
                         "regardless of the generating seed)")
    # -- runtime -------------------------------------------------------------
    ap.add_argument("--model-seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--partitions", type=int, default=1)
    ap.add_argument("--admission", default="fifo",
                    choices=["fifo", "round_robin", "fair_quantum"])
    ap.add_argument("--controller", default=None, nargs="?", const="on",
                    metavar="SPEC",
                    help="enable the SLO closed loop (bare flag for "
                         "defaults, or 'interval=2,low=0.85' knobs)")
    # -- record / replay -----------------------------------------------------
    ap.add_argument("--save-trace", default=None, metavar="PATH",
                    help="write the generated WorkloadTrace JSON")
    ap.add_argument("--replay", default=None, metavar="PATH",
                    help="load a trace instead of generating one")
    ap.add_argument("--gen-only", action="store_true",
                    help="generate + save, skip the runtime run")
    args = ap.parse_args()

    from repro.runtime import workload as wl
    from repro.runtime.controller import ControllerSpec

    if args.replay:
        trace = wl.WorkloadTrace.load(args.replay)
        print(f"[loadgen] trace loaded: {args.replay}")
    else:
        trace = wl.generate(build_workload(args))
    per = trace.arrivals_per_tenant()
    print(f"[loadgen] {len(trace.events)} arrivals over {trace.steps} "
          f"steps · " + ", ".join(f"{t}:{n}" for t, n in per.items()))
    if args.save_trace:
        trace.save(args.save_trace)
        print(f"[loadgen] trace written: {args.save_trace}")
    if args.gen_only:
        return 0

    import jax
    from repro.configs import get_arch, get_reduced
    from repro.models import init_params
    from repro.models.layers import RuntimeCfg
    from repro.runtime.server import (
        PartitionSpec, ServingRuntime, ServingSpec)

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    if trace.spec is not None and trace.spec.vocab > cfg.vocab_size:
        raise SystemExit(f"trace vocab {trace.spec.vocab} exceeds model "
                         f"vocab {cfg.vocab_size}")
    params = init_params(jax.random.PRNGKey(args.model_seed), cfg)
    spec = ServingSpec(
        partitions=tuple(PartitionSpec(admission=args.admission)
                         for _ in range(max(1, args.partitions))),
        batch_slots=args.slots, max_len=args.max_len,
        controller=ControllerSpec.parse(args.controller))
    runtime = ServingRuntime(params, cfg, spec,
                             rt=RuntimeCfg(ssm_chunk=16))
    t0 = time.time()
    done = wl.run_trace(runtime, trace)
    dt = time.time() - t0
    print(runtime.report().summary())
    if runtime.controller is not None:
        counts = runtime.controller.counts()
        print(f"[loadgen] controller: checks "
              f"{runtime.controller.checks} · "
              + ", ".join(f"{a}:{n}" for a, n in counts.items()))
    total = sum(len(r.out) for r in done)
    print(f"[loadgen] {len(done)} requests, {total} tokens, "
          f"{runtime.step_count} steps in {dt:.1f}s")
    print(f"[loadgen] tokens_checksum={wl.token_checksum(done)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
