"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), per the assignment:

  compute    = HLO_FLOPs / (chips × 197e12  bf16 FLOP/s)
  memory     = HLO_bytes / (chips × 819e9   HBM B/s)
  collective = wire_bytes / (chips × 50e9   ICI B/s per link)

``cost_analysis`` counts ``lax.scan`` bodies once (measured), so totals are
assembled as ``full_model_cost + (L-1) × per_superlayer_cost`` where the
superlayer is lowered standalone under the same mesh/shardings with fully
static loops (launch/dryrun.py builds both).

Collective wire bytes come from parsing the compiled HLO: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op's result shape × ring factor for its replica-group size.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e-class target (constants fixed by the assignment)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([\d,]*)\][^a-z]*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class Collective:
    kind: str
    dtype: str
    shape: Tuple[int, ...]
    group_size: int

    @property
    def result_bytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * _DTYPE_BYTES.get(self.dtype, 4)

    @property
    def wire_bytes(self) -> float:
        """Per-device bytes crossing links (ring algorithms)."""
        return self._wire(self.result_bytes)

    @property
    def wire_bytes_bf16(self) -> float:
        """Wire bytes with element size capped at 2 B. XLA:CPU upconverts
        bf16 dot operands to f32 *before* the partitioner inserts the
        collective (no bf16 FMA on CPU), inflating f32 wire 2× vs a TPU
        compile where the dot is native-bf16. This is the TPU-wire metric;
        the raw f32 number is kept alongside."""
        n = 1
        for d in self.shape:
            n *= d
        return self._wire(n * min(_DTYPE_BYTES.get(self.dtype, 4), 2))

    def _wire(self, b: float) -> float:
        g = max(self.group_size, 2)
        if self.kind == "all-reduce":
            return 2.0 * (g - 1) / g * b
        if self.kind == "all-gather":          # result = gathered (full)
            return (g - 1) / g * b
        if self.kind == "reduce-scatter":      # result = scattered (1/g)
            return (g - 1) * b
        if self.kind == "all-to-all":
            return (g - 1) / g * b
        if self.kind == "collective-permute":
            return float(b)
        return float(b)


def parse_collectives(hlo_text: str) -> List[Collective]:
    out: List[Collective] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if "-done" in line:
            continue
        shape = tuple(int(x) for x in dims.split(",") if x) if dims else ()
        g = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            g = int(mg.group(2))
        else:
            ml = _GROUPS_LIST_RE.search(line)
            if ml:
                g = len([t for t in ml.group(1).split(",") if t.strip()])
        out.append(Collective(kind, dtype, shape, g))
    return out


def collective_wire_bytes(hlo_text: str) -> float:
    return sum(c.wire_bytes for c in parse_collectives(hlo_text))


def collective_wire_bytes_bf16(hlo_text: str) -> float:
    return sum(c.wire_bytes_bf16 for c in parse_collectives(hlo_text))


def collective_summary(hlo_text: str) -> Dict[str, Dict[str, float]]:
    summ: Dict[str, Dict[str, float]] = {}
    for c in parse_collectives(hlo_text):
        e = summ.setdefault(c.kind, {"count": 0, "wire_bytes": 0.0})
        e["count"] += 1
        e["wire_bytes"] += c.wire_bytes
    return summ


# ---------------------------------------------------------------------------
# Term assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CellCost:
    """Costs for one lowering (full model counts scan body once)."""
    flops: float                 # whole-program HLO flops
    bytes_accessed: float
    wire_bytes: float
    collectives: Dict[str, Dict[str, float]]
    wire_bytes_bf16: float = 0.0  # dtype-capped (TPU-native-bf16 wire)


@dataclasses.dataclass
class Roofline:
    """``flops``/``bytes_accessed``/``wire_bytes`` are the *per-device* SPMD
    program costs (XLA partitions before cost analysis); the spec formula
    HLO_FLOPs/(chips × peak) is applied with HLO_FLOPs = per-device × chips,
    which reduces to per-device / peak."""
    arch: str
    shape: str
    chips: int
    flops: float                 # per-device, assembled (per step)
    bytes_accessed: float
    wire_bytes: float
    model_flops: float           # 6·N_active·D analytic (GLOBAL)
    wire_bytes_bf16: float = 0.0
    min_bytes: float = 0.0       # analytic min HBM traffic (GLOBAL; decode)
    kind: str = "train"
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    collective_bf16_s: float = 0.0

    def __post_init__(self):
        self.compute_s = (self.flops * self.chips) / (self.chips * PEAK_FLOPS)
        self.memory_s = (self.bytes_accessed * self.chips) / (self.chips * HBM_BW)
        self.collective_s = (self.wire_bytes * self.chips) / (self.chips * ICI_BW)
        self.collective_bf16_s = ((self.wire_bytes_bf16 or self.wire_bytes)
                                  * self.chips) / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time lower bound = max of overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO_FLOPs — catches remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Ideal-step time over dominant-term time (1.0 = at the roofline).

        train/prefill (compute-dominated ideals): ideal = MODEL_FLOPS at
        peak. decode (inherently bandwidth-bound): ideal = minimum HBM
        traffic (params + KV/state read) at full HBM bandwidth."""
        if self.kind == "decode":
            ideal = self.min_bytes / (self.chips * HBM_BW)
        else:
            ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.step_s if self.step_s else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "chips": self.chips,
            "flops": self.flops, "bytes": self.bytes_accessed,
            "wire_bytes": self.wire_bytes, "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "collective_bf16_s": self.collective_bf16_s,
            "bottleneck": self.bottleneck,
            "step_s": self.step_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def assemble(arch: str, shape, chips: int,
             full: CellCost, layer: Optional[CellCost],
             n_bodies: int, model_flops: float,
             min_bytes: float = 0.0, kind: str = "train") -> Roofline:
    """total = full (scan body counted once) + (n_bodies-1) × layer."""
    extra = max(n_bodies - 1, 0)
    if layer is None:
        extra = 0
        layer = CellCost(0, 0, 0, {})
    return Roofline(
        arch=arch, shape=shape, chips=chips,
        flops=full.flops + extra * layer.flops,
        bytes_accessed=full.bytes_accessed + extra * layer.bytes_accessed,
        wire_bytes=full.wire_bytes + extra * layer.wire_bytes,
        wire_bytes_bf16=(full.wire_bytes_bf16
                         + extra * layer.wire_bytes_bf16),
        model_flops=model_flops, min_bytes=min_bytes, kind=kind,
    )


def model_flops_estimate(cfg, shape) -> float:
    """6·N_active·D for training; 2·N_active·D for inference (per step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def report(jsonl_path: str) -> str:
    """Markdown §Roofline table from the dry-run artifacts."""
    cells = {}
    mems = {}
    for line in open(jsonl_path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if not r.get("ok"):
            continue
        key = (r["arch"], r["shape"])
        if r["mesh"] == "single" and "roofline" in r:
            cells[key] = r
        mems[(r["arch"], r["shape"], r["mesh"])] = \
            r["memory"]["per_device_total"] / 2 ** 30

    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | roofline frac | useful FLOPs | GiB/dev (1 pod) | "
           "GiB/dev (2 pod) |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape), r in sorted(cells.items()):
        ro = r["roofline"]
        m1 = mems.get((arch, shape, "single"), float("nan"))
        m2 = mems.get((arch, shape, "multi"), float("nan"))
        out.append(
            f"| {arch} | {shape} | {ro['compute_s']:.4f} | "
            f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
            f"{ro['bottleneck']} | {ro['roofline_fraction']:.3f} | "
            f"{ro['useful_flops_ratio']:.3f} | {m1:.1f} | {m2:.1f} |")
    return "\n".join(out)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="benchmarks/artifacts/dryrun.jsonl")
    args = ap.parse_args()
    print(report(args.artifacts))


def min_bytes_estimate(cfg, shape) -> float:
    """Analytic minimum GLOBAL HBM traffic for one decode step: every active
    parameter read once (bf16) + the KV/state cache read once."""
    pbytes = 2.0 * cfg.active_param_count()
    cache = 0.0
    B, S = shape.global_batch, shape.seq_len
    pat = cfg.superlayer_pattern
    n_attn_layers = 0
    for kind in pat:
        if kind.startswith("attn") or kind == "shared_attn":
            n_attn_layers += 1
    n_attn = cfg.num_superlayers * n_attn_layers
    if cfg.num_heads:
        w = cfg.window_size or S
        # local layers read only the window
        if cfg.attn_kind == "local_global" and cfg.local_per_global:
            n_local = cfg.num_superlayers * cfg.local_per_global
            n_global = cfg.num_superlayers
            cache += n_local * B * min(w, S) * cfg.kv_dim * 2 * 2
            cache += n_global * B * S * cfg.kv_dim * 2 * 2
        else:
            cache += n_attn * B * S * cfg.kv_dim * 2 * 2
    if cfg.ssm_kind == "mamba2":
        n_ssm = cfg.num_layers
        cache += (n_ssm * B * cfg.ssm_nheads * cfg.ssm_head_dim
                  * cfg.ssm_state * 4)
    if cfg.ssm_kind == "rwkv6":
        nh = cfg.d_model // cfg.ssm_head_dim
        cache += cfg.num_layers * B * nh * cfg.ssm_head_dim ** 2 * 4
    return pbytes + cache


if __name__ == "__main__":
    main()
