"""Serving driver: batched requests, continuous batching, technique switches.

CPU-runnable with ``--reduced``; demonstrates the paper-§9.2 serving levers:
FP8 weights, 2:4-packed weights (bandwidth win in the memory-bound decode
regime), batch-slot occupancy — and, with ``--tenants N``, the fairness-
aware multi-tenant scheduler (runtime/scheduler.py) with its per-tenant
fairness/CV/p50/p99 report.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --requests 8 --max-new 16 --precision fp8
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --requests 8 --tenants 4 --admission fair_quantum
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --requests 8 --tenants 4 --partitions 2 --placement load_aware \
      --adaptive-quota
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--precision", default=None, choices=[None, "bf16", "fp8"])
    ap.add_argument("--backend", default=None,
                    choices=[None, "ref", "jnp", "pallas", "pallas_sparse24"],
                    help="matmul backend (kernels/registry.py)")
    ap.add_argument("--policy", default=None,
                    help="execution-policy spec ('fp8:sparse24:pallas'), or "
                         "'auto' to resolve via the occupancy advisor "
                         "(paper §9.2) from slots/d_model/d_ff")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tenants", type=int, default=1,
                    help="number of tenant queues; >1 routes through the "
                         "fairness-aware StreamScheduler "
                         "(runtime/scheduler.py)")
    ap.add_argument("--admission", default="fair_quantum",
                    choices=["fifo", "round_robin", "fair_quantum"],
                    help="multi-tenant admission policy (with --tenants)")
    ap.add_argument("--partitions", type=int, default=1,
                    help="spatial sub-mesh partitions; >1 serves tenants "
                         "through the PartitionedServer "
                         "(runtime/partition.py): one session+scheduler "
                         "per partition, fused report")
    ap.add_argument("--placement", default="spread",
                    choices=["packed", "spread", "load_aware"],
                    help="tenant->partition routing policy "
                         "(with --partitions)")
    ap.add_argument("--adaptive-quota", action="store_true",
                    help="re-derive per-tenant fair_quantum slot caps "
                         "online from Tracer.tenant_percentiles() instead "
                         "of static stream budgets")
    ap.add_argument("--telemetry", action="store_true",
                    help="record per-op/per-tenant events to a Tracer and "
                         "print the observatory summary at exit")
    ap.add_argument("--autotune", action="store_true",
                    help="load the persistent autotune artifact "
                         "(launch/profile.py) and resolve policies from "
                         "calibrated thresholds")
    args = ap.parse_args()

    from repro.configs import get_arch, get_reduced
    from repro.core import autotune, execution as ex
    from repro.models import init_params
    from repro.models.layers import RuntimeCfg
    from repro.runtime import telemetry
    from repro.runtime.serve_loop import Request, ServeSession
    from repro.runtime.scheduler import StreamScheduler

    if args.autotune:
        store = autotune.install()
        print(f"[serve] autotune artifact "
              f"{'loaded: ' + store.path if store else 'not found'}")
    tracer = telemetry.Tracer() if args.telemetry else None
    if tracer is not None:
        telemetry.set_tracer(tracer)    # observe trace-time matmul events

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    if args.precision:
        cfg = dataclasses.replace(cfg, precision=args.precision)

    policy = None
    if args.policy == "auto":
        policy = "auto"        # ServeSession resolves, honoring auto_backend
    elif args.policy or args.backend:
        base = ex.ExecutionPolicy(
            precision=cfg.precision,
            sparsity="sparse24" if cfg.sparsity_24 else "dense")
        policy = ex.parse_policy(args.policy or "", base=base)
        if args.backend:
            policy = dataclasses.replace(policy, backend=args.backend)

    rt = RuntimeCfg(ssm_chunk=32)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)

    rng = np.random.default_rng(args.seed)
    requests = []
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=(args.prompt_len,)).astype(np.int32)
        requests.append(Request(uid=uid, prompt=prompt,
                                max_new=args.max_new))

    quota = "adaptive" if args.adaptive_quota else None
    if args.partitions > 1:
        # partitioned serving runtime: one session+scheduler per spatial
        # partition, tenants routed by --placement, fused report
        from repro.runtime.partition import PartitionedServer
        server = PartitionedServer(
            params, cfg, n_partitions=args.partitions,
            batch_slots=args.slots, max_len=args.max_len, rt=rt,
            placement=args.placement, admission=args.admission,
            quota=quota, temperature=args.temperature, seed=args.seed,
            policy=policy,
            session_kw={"auto_backend": args.backend,
                        "verbose_policy": True})
        # timed region starts AFTER construction: session setup (policy
        # resolution, sparse24 pre-pack, cache alloc) must not pollute
        # the reported serving tok/s
        t0 = time.time()
        n_tenants = max(args.tenants, 1)
        for i in range(n_tenants):
            part = server.add_tenant(f"tenant{i}")
            print(f"[serve] tenant{i} -> partition {part} "
                  f"({args.placement})")
        for uid, req in enumerate(requests):
            server.submit(f"tenant{uid % n_tenants}", req)
        done = server.run()
        print(server.report().summary())
        if tracer is not None:
            print(server.merged_tracer().summary())
            # the ambient tracer holds the trace-time per-op events
            # (matmul/resolve) the per-partition tracers don't see
            print(tracer.summary())
        dt = time.time() - t0
        total_new = sum(len(r.out) for r in done)
        print(f"[serve] {len(done)}/{args.requests} requests, "
              f"{total_new} tokens in {dt:.1f}s "
              f"({total_new / max(dt, 1e-9):.1f} tok/s aggregate)")
        return 0

    sess = ServeSession(params, cfg, batch_slots=args.slots,
                        max_len=args.max_len, rt=rt,
                        temperature=args.temperature, seed=args.seed,
                        policy=policy, auto_backend=args.backend,
                        verbose_policy=True, telemetry=tracer)
    t0 = time.time()

    if args.tenants > 1:
        # multi-tenant: requests dealt round-robin over tenant queues. The
        # session policy becomes each tenant's slot quota only when its
        # stream budget was actually chosen (advisor-resolved via 'auto',
        # or an explicit streams= token) — a policy built just to pick a
        # backend carries the default streams=1 and would silently cap
        # every tenant to one slot.
        sched = StreamScheduler(sess, admission=args.admission,
                                tracer=tracer, quota=quota)
        tpol = None
        if isinstance(sess.policy, ex.ExecutionPolicy) and (
                args.policy == "auto" or "streams=" in (args.policy or "")):
            tpol = sess.policy
        for i in range(args.tenants):
            sched.add_tenant(f"tenant{i}", policy=tpol)
        for uid, req in enumerate(requests):
            sched.submit(f"tenant{uid % args.tenants}", req)
        done = sched.run()
        print(sched.report().summary())
    else:
        for req in requests:
            sess.submit(req)
        done = sess.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)}/{args.requests} requests, "
          f"{total_new} tokens in {dt:.1f}s "
          f"({total_new / max(dt, 1e-9):.1f} tok/s aggregate)")
    for r in done[:4]:
        print(f"  req {r.uid}: {len(r.out)} new tokens, first 8: {r.out[:8]}")
    if tracer is not None:
        print(tracer.summary())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
