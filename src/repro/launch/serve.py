"""Serving driver: batched requests, continuous batching, technique switches.

CPU-runnable with ``--reduced``; demonstrates the paper-§9.2 serving levers:
FP8 weights, 2:4-packed weights (bandwidth win in the memory-bound decode
regime), batch-slot occupancy — and the serving control plane
(runtime/server.py): multi-tenant admission, spatial partitions with
per-partition execution policies, and live tenant migration.

The canonical way to configure the control plane is a serialized
``ServingSpec`` (``--spec spec.json``). The legacy flag cluster
(``--partitions/--placement/--adaptive-quota/--admission/…``) is kept as
shorthand that *builds* a spec — ``--save-spec out.json`` writes the
effective spec so a flag invocation can be promoted to a declarative one.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --requests 8 --max-new 16 --precision fp8
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --requests 8 --tenants 4 --admission fair_quantum
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --requests 8 --tenants 4 --partitions 2 --placement load_aware \
      --adaptive-quota --migrate
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --requests 8 --tenants 4 --spec myspec.json
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np


def build_spec(args, policy):
    """The legacy flag cluster as a :class:`ServingSpec` (the shorthand
    path; ``--spec`` supersedes it)."""
    from repro.runtime.server import (
        MigrationSpec, PartitionSpec, ServingSpec)
    quota = "adaptive" if args.adaptive_quota else None
    return ServingSpec(
        partitions=tuple(
            PartitionSpec(admission=args.admission, quota=quota)
            for _ in range(max(1, args.partitions))),
        placement=args.placement,
        batch_slots=args.slots,
        max_len=args.max_len,
        temperature=args.temperature,
        seed=args.seed,
        policy=policy,
        migration=MigrationSpec(enabled=args.migrate),
        # paged/overlap flags default for callers driving build_spec with
        # a legacy (pre-paging / pre-lane) namespace
        paged=getattr(args, "paged", False),
        page_size=getattr(args, "page_size", 16),
        pages=getattr(args, "pages", None),
        overlap=not getattr(args, "no_overlap", False),
        metrics=getattr(args, "metrics_out", None) is not None,
        controller=_parse_controller(getattr(args, "controller", None)))


def _parse_controller(arg):
    from repro.runtime.controller import ControllerSpec
    return ControllerSpec.parse(arg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--precision", default=None, choices=[None, "bf16", "fp8"])
    ap.add_argument("--backend", default=None,
                    choices=[None, "ref", "jnp", "pallas", "pallas_sparse24"],
                    help="matmul backend (kernels/registry.py)")
    ap.add_argument("--policy", default=None,
                    help="execution-policy spec ('fp8:sparse24:pallas'), or "
                         "'auto' to resolve via the occupancy advisor "
                         "(paper §9.2) from slots/d_model/d_ff")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tenants", type=int, default=1,
                    help="number of tenant queues; >1 routes through the "
                         "serving control plane / StreamScheduler")
    ap.add_argument("--spec", default=None, metavar="PATH",
                    help="serialized ServingSpec (runtime/server.py); "
                         "supersedes the partition/placement/admission/"
                         "quota shorthand flags")
    ap.add_argument("--save-spec", default=None, metavar="PATH",
                    help="write the effective ServingSpec as JSON (promote "
                         "a flag invocation to a declarative spec)")
    ap.add_argument("--admission", default="fair_quantum",
                    choices=["fifo", "round_robin", "fair_quantum"],
                    help="[shorthand] multi-tenant admission policy")
    ap.add_argument("--partitions", type=int, default=1,
                    help="[shorthand] spatial sub-mesh partitions; >1 "
                         "serves tenants through the ServingRuntime "
                         "control plane (runtime/server.py)")
    ap.add_argument("--placement", default="spread",
                    choices=["packed", "spread", "load_aware"],
                    help="[shorthand] tenant->partition routing policy")
    ap.add_argument("--adaptive-quota", action="store_true",
                    help="[shorthand] re-derive per-tenant fair_quantum "
                         "slot caps online from Tracer.tenant_percentiles()")
    ap.add_argument("--migrate", action="store_true",
                    help="[shorthand] enable live tenant migration (the "
                         "load_aware re-route path; see MigrationSpec)")
    ap.add_argument("--paged", action="store_true",
                    help="paged serving cache (core/paging.py): per-slot "
                         "page tables over a shared pool + fused paged "
                         "flash-decode; greedy output is token-identical "
                         "to the dense path")
    ap.add_argument("--page-size", type=int, default=16,
                    help="token positions per cache page (must divide "
                         "--max-len)")
    ap.add_argument("--pages", type=int, default=None,
                    help="physical pool size in pages (default: dense-"
                         "equivalent capacity, slots * max_len/page_size)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable lane overlap: partitions step through "
                         "the serial loop instead of OverlapPlanner-paired "
                         "concurrent dispatch (token streams are identical "
                         "either way)")
    ap.add_argument("--telemetry", action="store_true",
                    help="record per-op/per-tenant events to a Tracer and "
                         "print the observatory summary at exit")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics-registry snapshot at exit "
                         "(.json, or Prometheus text for .prom/.txt); "
                         "implies the metrics plane (runtime/metrics.py)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the run's telemetry as Chrome trace_event "
                         "JSON (runtime/traceview.py) — open in "
                         "chrome://tracing or https://ui.perfetto.dev")
    ap.add_argument("--slo", default=None,
                    help="SLO class for every shorthand tenant "
                         "('latency:12', 'latency:0.05@wall_s', "
                         "'throughput:1.5', 'batch:0.9'); reports and "
                         "metrics surface per-tenant attainment")
    ap.add_argument("--autotune", action="store_true",
                    help="load the persistent autotune artifact "
                         "(launch/profile.py) and resolve policies from "
                         "calibrated thresholds")
    ap.add_argument("--controller", default=None, nargs="?", const="on",
                    metavar="SPEC",
                    help="SLO closed loop (runtime/controller.py): bare "
                         "flag for defaults, or 'interval=2,low=0.85,"
                         "hold=4' knobs; freezes batch-class tenants / "
                         "boosts slot caps while a latency-class tenant "
                         "misses its SLO")
    ap.add_argument("--workload", default=None, metavar="TRACE",
                    help="replay a WorkloadTrace JSON (launch/loadgen.py "
                         "--save-trace) through the runtime instead of "
                         "the synthetic --requests stream; tenants and "
                         "SLOs come from the trace spec")
    args = ap.parse_args()

    from repro.configs import get_arch, get_reduced
    from repro.core import autotune, execution as ex
    from repro.models import init_params
    from repro.models.layers import RuntimeCfg
    from repro.runtime import telemetry
    from repro.runtime.serve_loop import Request, ServeSession
    from repro.runtime.scheduler import StreamScheduler
    from repro.runtime.server import ServingRuntime, ServingSpec

    if args.autotune:
        store = autotune.install()
        print(f"[serve] autotune artifact "
              f"{'loaded: ' + store.path if store else 'not found'}")
    # --metrics-out / --trace-out need an event stream even without
    # --telemetry's summary printing
    want_tracer = args.telemetry or args.metrics_out or args.trace_out
    tracer = telemetry.Tracer() if want_tracer else None
    if tracer is not None:
        telemetry.set_tracer(tracer)    # observe trace-time matmul events

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    if args.precision:
        cfg = dataclasses.replace(cfg, precision=args.precision)

    policy = None
    if args.policy == "auto":
        policy = "auto"        # ServeSession resolves, honoring auto_backend
    elif args.policy or args.backend:
        base = ex.ExecutionPolicy(
            precision=cfg.precision,
            sparsity="sparse24" if cfg.sparsity_24 else "dense")
        policy = ex.parse_policy(args.policy or "", base=base)
        if args.backend:
            policy = dataclasses.replace(policy, backend=args.backend)

    if args.spec:
        spec = ServingSpec.load(args.spec)
        print(f"[serve] spec loaded: {args.spec} "
              f"({spec.n_partitions} partitions, {spec.placement}, "
              f"migration={'on' if spec.migration.enabled else 'off'})")
        if args.metrics_out and not spec.metrics:
            spec = dataclasses.replace(spec, metrics=True)
        if args.controller:
            spec = dataclasses.replace(
                spec, controller=_parse_controller(args.controller))
    else:
        spec = build_spec(args, policy)
    if args.save_spec:
        print(f"[serve] spec written: {spec.save(args.save_spec)}")

    rt = RuntimeCfg(ssm_chunk=32)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)

    rng = np.random.default_rng(args.seed)
    requests = []
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=(args.prompt_len,)).astype(np.int32)
        requests.append(Request(uid=uid, prompt=prompt,
                                max_new=args.max_new))

    use_runtime = (args.spec is not None or spec.n_partitions > 1
                   or spec.migration.enabled or args.workload is not None
                   or args.controller is not None)
    if use_runtime:
        # the serving control plane: one runtime from one spec — per-
        # partition policies, routed tenants, optional live migration
        runtime = ServingRuntime(
            params, cfg, spec, rt=rt,
            session_kw={"auto_backend": args.backend,
                        "verbose_policy": True})
        # timed region starts AFTER construction: session setup (policy
        # resolution, sparse24 pre-pack, cache alloc) must not pollute
        # the reported serving tok/s
        t0 = time.time()
        if args.workload:
            from repro.runtime.workload import WorkloadTrace, run_trace
            wtrace = WorkloadTrace.load(args.workload)
            print(f"[serve] workload trace: {args.workload} "
                  f"({len(wtrace.events)} arrivals / "
                  f"{len(wtrace.tenant_ids())} tenants over "
                  f"{wtrace.steps} steps)")
            done = run_trace(runtime, wtrace)
            args.requests = len(wtrace.events)
        else:
            tenant_ids = [t.id for t in spec.tenants]
            if not tenant_ids:
                tenant_ids = [f"tenant{i}"
                              for i in range(max(args.tenants, 1))]
                for tid in tenant_ids:
                    part = runtime.add_tenant(tid, slo=args.slo)
                    print(f"[serve] {tid} -> partition {part} "
                          f"({spec.placement})")
            for uid, req in enumerate(requests):
                runtime.submit(tenant_ids[uid % len(tenant_ids)], req)
            done = runtime.drain()
        if runtime.controller is not None:
            counts = runtime.controller.counts()
            print(f"[serve] controller: checks "
                  f"{runtime.controller.checks} · "
                  + ", ".join(f"{a}:{n}" for a, n in counts.items()))
        print(runtime.report().summary())
        if args.telemetry:
            print(runtime.merged_tracer().summary())
            # the ambient tracer holds the trace-time per-op events
            # (matmul/resolve) the per-partition tracers don't see
            print(tracer.summary())
        if args.metrics_out and runtime.metrics is not None:
            print(f"[serve] metrics written: "
                  f"{runtime.metrics.save(args.metrics_out)}")
        if args.trace_out:
            from repro.runtime import traceview
            merged = telemetry.Tracer.merge(*runtime.tracers, tracer)
            print(f"[serve] trace written: "
                  f"{traceview.export_chrome_trace(merged, args.trace_out)}"
                  " (open in chrome://tracing or ui.perfetto.dev)")
        dt = time.time() - t0
        total_new = sum(len(r.out) for r in done)
        print(f"[serve] {len(done)}/{args.requests} requests, "
              f"{total_new} tokens in {dt:.1f}s "
              f"({total_new / max(dt, 1e-9):.1f} tok/s aggregate)")
        return 0

    sess = ServeSession(params, cfg, batch_slots=args.slots,
                        max_len=args.max_len, rt=rt,
                        temperature=args.temperature, seed=args.seed,
                        policy=policy, auto_backend=args.backend,
                        verbose_policy=True, telemetry=tracer,
                        paged=args.paged, page_size=args.page_size,
                        pages=args.pages)
    registry = None
    if args.metrics_out:
        from repro.runtime.metrics import MetricsSink
        registry = MetricsSink().attach(tracer).registry
    if args.paged:
        print(f"[serve] paged cache: page_size={sess.page_size} "
              f"pages={sess.pages}")
    t0 = time.time()

    if args.tenants > 1:
        # multi-tenant: requests dealt round-robin over tenant queues. The
        # session policy becomes each tenant's slot quota only when its
        # stream budget was actually chosen (advisor-resolved via 'auto',
        # or an explicit streams= token) — a policy built just to pick a
        # backend carries the default streams=1 and would silently cap
        # every tenant to one slot.
        quota = "adaptive" if args.adaptive_quota else None
        sched = StreamScheduler(sess, admission=args.admission,
                                tracer=tracer, quota=quota)
        tpol = None
        if isinstance(sess.policy, ex.ExecutionPolicy) and (
                args.policy == "auto" or "streams=" in (args.policy or "")):
            tpol = sess.policy
        for i in range(args.tenants):
            sched.add_tenant(f"tenant{i}", policy=tpol, slo=args.slo)
        for uid, req in enumerate(requests):
            sched.submit(f"tenant{uid % args.tenants}", req)
        done = sched.run()
        print(sched.report().summary())
    else:
        for req in requests:
            sess.submit(req)
        done = sess.run()
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)}/{args.requests} requests, "
          f"{total_new} tokens in {dt:.1f}s "
          f"({total_new / max(dt, 1e-9):.1f} tok/s aggregate)")
    for r in done[:4]:
        print(f"  req {r.uid}: {len(r.out)} new tokens, first 8: {r.out[:8]}")
    if args.telemetry and tracer is not None:
        print(tracer.summary())
    if registry is not None:
        print(f"[serve] metrics written: {registry.save(args.metrics_out)}")
    if args.trace_out and tracer is not None:
        from repro.runtime import traceview
        print(f"[serve] trace written: "
              f"{traceview.export_chrome_trace(tracer, args.trace_out)}"
              " (open in chrome://tracing or ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
