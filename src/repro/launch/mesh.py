"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run must set XLA_FLAGS before any
jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Generic helper for tests / sub-mesh experiments."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
