"""End-to-end training driver.

CPU-runnable with ``--reduced`` (smoke-scale config of the same family);
on a TPU pod the same driver shards over the production mesh. Wires every
substrate together: data pipeline (+cursor checkpointing), AdamW with FP32
masters, FP8/2:4 technique switches, async checkpointing, straggler
monitoring, heartbeat watchdog, supervised restart.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 200 --checkpoint-dir /tmp/ckpt --precision fp8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def build_argparser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--total-steps", type=int, default=1000,
                    help="LR-schedule horizon (fixed so resumed runs see "
                         "the identical schedule regardless of --steps)")
    ap.add_argument("--precision", default=None, choices=[None, "bf16", "fp8"])
    ap.add_argument("--sparsity-24", action="store_true")
    ap.add_argument("--backend", default=None,
                    choices=[None, "ref", "jnp", "pallas", "pallas_sparse24"],
                    help="matmul backend (kernels/registry.py); default jnp")
    ap.add_argument("--policy", default=None,
                    help="full execution-policy spec, e.g. 'fp8:sparse24:"
                         "pallas:256x256x128' (overrides --precision/"
                         "--sparsity-24/--backend pieces it names)")
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "bf16", "int8_ef"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--supervise", action="store_true")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at-step", type=int, default=0,
                    help="(testing) crash at this step to exercise restart")
    ap.add_argument("--telemetry", action="store_true",
                    help="record per-step wall times + trace-time matmul "
                         "events; print the observatory summary at exit")
    ap.add_argument("--autotune", action="store_true",
                    help="load the persistent autotune artifact "
                         "(launch/profile.py) so policy resolution uses "
                         "calibrated thresholds")
    return ap


def run_once(args) -> int:
    from repro.configs import get_arch, get_reduced
    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import SyntheticLM, Prefetcher
    from repro.models import init_params
    from repro.models.layers import RuntimeCfg
    from repro.optim import adamw
    from repro.runtime import train_loop as tl
    from repro.runtime.fault_tolerance import Heartbeat, StepMonitor

    from repro.core import autotune, execution as ex
    from repro.runtime import telemetry

    if args.autotune:
        store = autotune.install()
        print(f"[train] autotune artifact "
              f"{'loaded: ' + store.path if store else 'not found'}")
    tracer = telemetry.Tracer() if args.telemetry else None

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    if args.precision:
        cfg = dataclasses.replace(cfg, precision=args.precision)
    if args.sparsity_24:
        cfg = dataclasses.replace(cfg, sparsity_24=True)

    policy = None
    if args.policy or args.backend:
        base = ex.ExecutionPolicy(
            precision=cfg.precision,
            sparsity="sparse24" if cfg.sparsity_24 else "dense")
        policy = ex.parse_policy(args.policy or "", base=base)
        if args.backend:
            policy = dataclasses.replace(policy, backend=args.backend)
        print(f"[train] execution policy: {policy.spec()}")

    rt = RuntimeCfg(chunk_q=min(64, args.seq), chunk_kv=min(64, args.seq),
                    ssm_chunk=32, static_loops=True)
    # schedule derives only from --total-steps: a resumed run must see the
    # exact same lr curve as an uninterrupted one (bitwise-replay guarantee)
    opt_cfg = adamw.AdamWConfig(learning_rate=args.lr,
                                total_steps=args.total_steps,
                                warmup_steps=min(20, args.total_steps // 50))

    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    state = tl.init_state(params, opt_cfg, args.grad_compress)
    step0 = 0

    ckpt = None
    if args.checkpoint_dir:
        ckpt = CheckpointManager(args.checkpoint_dir)
        if args.resume:
            restored = ckpt.restore_latest(state)
            if restored is not None:
                step0, state, extra = restored
                data.cursor.step = int(extra.get("data_step", step0))
                print(f"[train] resumed from step {step0}")

    train_step = jax.jit(tl.make_train_step(
        cfg, opt_cfg, rt, grad_compress=args.grad_compress,
        microbatch=args.microbatch, policy=policy, telemetry=tracer))

    monitor = StepMonitor()
    hb = None
    if args.checkpoint_dir:
        hb = Heartbeat(args.checkpoint_dir + "/heartbeat.json",
                       hang_timeout_s=0)

    data.cursor.step = step0
    prefetch = Prefetcher(data, depth=2)
    t_start = time.time()
    losses = []
    try:
        for step in range(step0, args.steps):
            if args.fail_at_step and step == args.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            batch = next(prefetch)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.time()
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            st = monitor.record(step, time.time() - t0)
            if tracer is not None:
                tracer.record("train_step", step=step,
                              wall_s=st.duration_s,
                              meta={"loss": loss})
            losses.append(loss)
            if hb:
                hb.beat(step)
            if step % args.log_every == 0 or step == args.steps - 1:
                flag = " STRAGGLER" if st.is_straggler else ""
                print(f"[train] step={step} loss={loss:.4f} "
                      f"dt={st.duration_s*1e3:.1f}ms "
                      f"ewma={st.ewma_s*1e3:.1f}ms{flag}")
            if not np.isfinite(loss):
                print("[train] non-finite loss; aborting")
                return 1
            if ckpt and step > 0 and step % args.checkpoint_every == 0:
                ckpt.save(step, state, extra={"data_step": step})
    finally:
        prefetch.close()
        if hb:
            hb.close()
        if ckpt:
            ckpt.wait()
    if ckpt:
        ckpt.save(args.steps, state, extra={"data_step": args.steps},
                  blocking=True)
    dt = time.time() - t_start
    print(f"[train] done: {args.steps - step0} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if tracer is not None:
        print(tracer.summary())
    return 0


def main():
    args = build_argparser().parse_args()
    if args.supervise:
        from repro.runtime.fault_tolerance import supervise

        def attempt():
            a = argparse.Namespace(**vars(args))
            a.resume = True
            a.supervise = False
            a.fail_at_step = 0 if args.resume else args.fail_at_step
            rc = run_once(a)
            args.resume = True
            return rc
        return supervise(attempt, max_restarts=args.max_restarts)
    return run_once(args)


if __name__ == "__main__":
    raise SystemExit(main())
