"""Live serving dashboard: ``top`` for the ServingRuntime.

A refresh-loop terminal view over a running
:class:`~repro.runtime.server.ServingRuntime` — per-partition occupancy
and backlog, page-pool utilization, per-tenant progress / fairness / SLO
attainment, and the metrics-registry counters, re-rendered in place
every interval. :func:`render` is a pure report→text function (the tests
drive it headless); :func:`watch` owns the ANSI refresh loop; ``main``
builds a reduced-model runtime with synthetic staggered tenant traffic
so the dashboard has something live to show:

  PYTHONPATH=src python -m repro.launch.top --arch llama3-8b --reduced \\
      --partitions 2 --tenants 3 --requests 12 --paged --slo latency:12

Non-interactive consumers (CI, logs) pass ``--once`` to print a single
frame per drain instead of cursor control.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import List, Optional

BAR_FILL = "█"
BAR_EMPTY = "·"


def _bar(frac: float, width: int = 16) -> str:
    frac = max(0.0, min(1.0, float(frac)))
    n = int(round(frac * width))
    return BAR_FILL * n + BAR_EMPTY * (width - n)


def _fmt_att(att: Optional[float]) -> str:
    return " n/a" if att is None else f"{att * 100:3.0f}%"


def render(runtime, report=None, *, clock: Optional[float] = None) -> str:
    """One dashboard frame as text (no cursor control — the caller owns
    the terminal). Folds the current report into the runtime's metrics
    registry when one is attached (``ServingSpec(metrics=True)``)."""
    rep = report if report is not None else runtime.report()
    lines: List[str] = []
    tick = f" t={clock:.1f}s" if clock is not None else ""
    lines.append(
        f"repro-top · {rep.n_partitions} partition(s) "
        f"({rep.placement}, {rep.admission}/{rep.quota}) · "
        f"step {rep.steps}{tick}")
    lines.append(
        f"  tokens {rep.tokens_out} · pending {runtime.pending()} · "
        f"active {runtime.n_active} · fairness {rep.fairness:.3f} "
        f"[{_bar(rep.fairness)}] · migrations {rep.migrations}")
    lines.append("")

    # -- partitions ---------------------------------------------------------
    lines.append("  PART  POLICY            TEN  BACKLOG  SLOTS  FILL"
                 "              PAGES")
    for i, sched in enumerate(runtime.schedulers):
        sess = runtime.sessions[i]
        pol = rep.policies[i] if i < len(rep.policies) else ""
        backlog = sched.pending()
        active = sess.n_active
        fill = runtime.tracers[i].mean_fill()
        fill_s = f"{fill:5.1f}x" if fill is not None else "  n/a "
        slot_frac = active / max(1, sess.batch_slots)
        if getattr(sess, "pager", None) is not None:
            st = sess.pager.stats()
            pages = (f"{st['pages_in_use']}/{st['pages']} "
                     f"util {st['utilization'] * 100:3.0f}% "
                     f"frag {st['fragmentation'] * 100:3.0f}%")
        else:
            pages = "dense"
        lines.append(
            f"  p{i:<4} {(pol or 'ambient'):<17} "
            f"{len(sched.tenants):>3}  {backlog:>7}  "
            f"{active}/{sess.batch_slots:<3}  "
            f"{fill_s} [{_bar(slot_frac, 8)}]  {pages}")
    lines.append("")

    # -- tenants ------------------------------------------------------------
    ctrl = getattr(runtime, "controller", None)
    lines.append("  TENANT      P   DONE/SUB    TOK   TURN   SPEC"
                 "          SLO                    ATTAIN       CTRL")
    for t in rep.tenants:
        slo = t.slo or "-"
        att_bar = _bar(t.slo_attainment or 0.0, 10) if t.slo else "-" * 10
        mig = f" *m{t.migrations}" if t.migrations else ""
        if t.effective_tokens_per_step is not None:
            acc = f"{t.acceptance_rate * 100:3.0f}%" \
                if t.acceptance_rate is not None else " n/a"
            spec = f"{t.effective_tokens_per_step:4.2f}x/{acc}"
        else:
            spec = "-"
        # SLO trend arrow from the controller's recent-attainment delta:
        # ^ improving, v degrading, = steady, blank when untracked.
        trend = ctrl.trend_arrow(t.tenant_id) if ctrl is not None else ""
        lines.append(
            f"  {t.tenant_id:<11} {t.partition:>1}  "
            f"{t.completed:>4}/{t.submitted:<4}  {t.tokens_out:>5}  "
            f"{t.mean_turnaround_steps:5.1f}   {spec:<12}  {slo:<21} "
            f"{_fmt_att(t.slo_attainment)} [{att_bar}] {trend:<2}{mig}")

    # -- SLO controller ------------------------------------------------------
    if ctrl is not None:
        counts = ctrl.counts()
        acted = ", ".join(f"{a}:{n}" for a, n in counts.items())
        lines.append("")
        lines.append(f"  CTRL  checks {ctrl.checks} · frozen now "
                     f"{ctrl.frozen_now()} · {acted}")

    # -- metrics registry ---------------------------------------------------
    if runtime.metrics is not None:
        snap = runtime.metrics.snapshot()
        ev = snap.get("repro_events_total", {}).get("series", {})
        if ev:
            strip = "{}\"'"
            parts = [(k.split("=")[-1].strip(strip), v)
                     for k, v in sorted(ev.items())]
            tot = ", ".join(f"{name}:{int(v)}" for name, v in parts)
            lines.append("")
            lines.append(f"  events: {tot}")
        drop = snap.get("repro_events_dropped_total", {}).get("series", {})
        if drop:
            lines.append(f"  dropped: {sum(drop.values()):.0f} "
                         "(tracer ring evictions — raise tracer_capacity)")
    return "\n".join(lines)


def watch(runtime, *, interval_s: float = 0.5, max_steps: int = 100_000,
          out=sys.stdout, once: bool = False,
          on_tick=None) -> int:
    """Drive the runtime to drain, re-rendering the dashboard every
    ``interval_s`` of wall time (ANSI in-place refresh unless ``once``).
    ``on_tick(runtime, step)`` runs before each refresh — the demo uses
    it to stagger synthetic arrivals. Returns total steps driven."""
    t0 = time.perf_counter()
    last = 0.0
    steps = 0

    def refresh():
        frame = render(runtime, clock=time.perf_counter() - t0)
        if once:
            print(frame, file=out)
        else:
            # home + clear-below keeps the frame flicker-free
            print("\x1b[H\x1b[J" + frame, file=out, flush=True)

    if not once:
        print("\x1b[2J", end="", file=out)      # initial clear
    while (runtime.pending() or runtime.n_active
           or runtime._draining) and steps < max_steps:
        if on_tick is not None:
            on_tick(runtime, steps)
        runtime.step()
        steps += 1
        now = time.perf_counter() - t0
        if now - last >= interval_s:
            last = now
            refresh()
    refresh()
    return steps


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="live ServingRuntime dashboard (synthetic traffic)")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--partitions", type=int, default=2)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--slo", default=None,
                    help="SLO class for every synthetic tenant "
                         "(e.g. 'latency:12', 'throughput:1.5', 'batch')")
    ap.add_argument("--interval", type=float, default=0.5,
                    help="refresh interval in seconds")
    ap.add_argument("--once", action="store_true",
                    help="no cursor control: print one frame per refresh "
                         "(logs / CI)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs import get_arch, get_reduced
    from repro.models import init_params
    from repro.models.layers import RuntimeCfg
    from repro.runtime.serve_loop import Request
    from repro.runtime.server import ServingRuntime, ServingSpec

    cfg = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    spec = ServingSpec.from_dict({
        "partitions": max(1, args.partitions),
        "batch_slots": args.slots, "max_len": args.max_len,
        "paged": args.paged, "page_size": args.page_size,
        "metrics": True,
        "tenants": [{"id": f"tenant{i}", "slo": args.slo}
                    for i in range(max(1, args.tenants))],
    })
    runtime = ServingRuntime(params, cfg, spec,
                             rt=RuntimeCfg(ssm_chunk=16))

    rng = np.random.default_rng(args.seed)
    backlog = [Request(uid=uid,
                       prompt=rng.integers(
                           0, cfg.vocab_size,
                           size=(args.prompt_len,)).astype(np.int32),
                       max_new=args.max_new)
               for uid in range(args.requests)]
    tenant_ids = [t.id for t in spec.tenants]
    # staggered arrivals: a couple of requests every few steps, so the
    # dashboard shows queues moving instead of one pre-loaded burst
    arrivals = {uid: (uid // 2) * 2 for uid in range(len(backlog))}

    def on_tick(rt_, step):
        for req in list(backlog):
            if arrivals[req.uid] <= step:
                rt_.submit(tenant_ids[req.uid % len(tenant_ids)], req)
                backlog.remove(req)

    # seed the first arrivals so the drain loop has pending work
    on_tick(runtime, 0)
    steps = watch(runtime, interval_s=args.interval, once=args.once,
                  on_tick=on_tick)
    print(f"\n[top] drained in {steps} steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
