"""Calibration-sweep CLI: measure this machine's execution behavior,
persist the autotune artifact, print a characterization report.

Runs a short occupancy sweep (Fig-2 methodology) and tile-latency probe
(Table-3 methodology), folds the measurements into the persistent
:class:`repro.core.autotune.AutotuneStore`, re-derives the FP8-demotion
occupancy threshold from the samples, and shows how ``resolve_policy``'s
decisions change under the calibrated advisor.

  PYTHONPATH=src python -m repro.launch.profile --quick
  PYTHONPATH=src python -m repro.launch.profile --artifact-dir /tmp/cal
  PYTHONPATH=src python -m repro.launch.profile --reset --quick

The artifact (``autotune.json``) lives in ``$REPRO_AUTOTUNE_DIR`` or
``benchmarks/artifacts/autotune``; every later run that calls
``autotune.install()`` (or ``launch/{train,serve}.py --autotune``) picks
it up, so one calibration permanently informs policy resolution.
"""
from __future__ import annotations

import argparse
import time


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CPU-sized sweep (fewer shapes, 1 timing iter); "
                         "seconds instead of minutes")
    ap.add_argument("--artifact-dir", default=None,
                    help="override the autotune artifact directory "
                         "($REPRO_AUTOTUNE_DIR / benchmarks/artifacts/"
                         "autotune)")
    ap.add_argument("--reset", action="store_true",
                    help="discard any existing artifact before measuring")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations per point (default: 1 quick, "
                         "3 full)")
    ap.add_argument("--no-save", action="store_true",
                    help="measure and report only; leave the artifact "
                         "untouched")
    return ap


def main(argv=None) -> int:
    args = build_argparser().parse_args(argv)

    from repro.core import autotune, concurrency as cc, execution as ex
    from repro.core.characterization import (latency_probe, occupancy_sweep,
                                             occupancy_threshold)
    from repro.runtime import telemetry

    store = autotune.AutotuneStore(args.artifact_dir)
    if args.reset:
        store.reset()
        print(f"[profile] reset artifact at {store.path}")
    elif store.load():
        print(f"[profile] merged existing artifact "
              f"({len(store.blocks)} blocks, {len(store.samples)} samples)")

    tracer = telemetry.Tracer()
    prev = telemetry.set_tracer(tracer)
    iters = args.iters or (1 if args.quick else 3)
    n_cores = cc.detect_core_count()
    t0 = time.time()
    try:
        if args.quick:
            tile_counts, k = (1, 2, 4), 128
            precisions = ("bf16", "fp8")
            tile_shapes = ((128, 128, 128), (128, 128, 256))
            chain = 2
        else:
            tile_counts, k = (1, 2, 4, 8, 16), 256
            precisions = ("fp32", "bf16", "fp8")
            tile_shapes = ((128, 128, 128), (256, 256, 128),
                           (128, 128, 256), (256, 256, 256))
            chain = 8

        print(f"[profile] occupancy sweep: tiles={tile_counts} "
              f"precisions={precisions} iters={iters}")
        occ = occupancy_sweep(tile_counts=tile_counts, k=k, n=k,
                              precisions=precisions, iters=iters)
        store.add_records(occ)

        print(f"[profile] tile-latency probe: {len(tile_shapes)} shapes, "
              f"chain={chain}")
        lat = latency_probe(tile_shapes=tile_shapes, precisions=precisions,
                            chain=chain, iters=iters)
        ex.seed_cache_from_records(lat)      # refine this process too
        store.add_records(lat)
    finally:
        telemetry.set_tracer(prev)

    thresholds = store.calibrate(n_cores=n_cores)
    saved = None if args.no_save else store.save()

    # ---- report ----------------------------------------------------------
    print(f"\n[profile] characterization ({time.time() - t0:.1f}s, "
          f"n_cores={n_cores})")
    th90 = occupancy_threshold(occ, frac=0.9)
    print("  tiles to 90% of best throughput: " + ", ".join(
        f"{p}={t}" for p, t in sorted(th90.items())))
    if "knee_tiles" in thresholds:
        print(f"  measured FP8 knee: {thresholds['knee_tiles']:g} tiles "
              f"-> demote below fill {thresholds['demote_below_fill']:.4g}"
              f"x cores (prior: "
              f"{cc.OccupancyAdvisor.BF16_TILE_THRESHOLD}x)")
    else:
        print("  no comparable fp8/bf16 samples; thresholds keep priors")
    print(f"  store: {len(store.blocks)} block entries, "
          f"{len(store.samples)} samples")
    print("  " + tracer.summary(n_cores=n_cores).replace("\n", "\n  "))

    # resolve_policy before/after, at the largest measured occupancy step
    cal = store.make_advisor(n_cores=n_cores)
    prior = cc.OccupancyAdvisor(n_cores=n_cores)
    demo_tiles = int(thresholds.get("knee_tiles", n_cores))
    for label, tiles in (("below-knee", max(1, demo_tiles // 2)),
                         ("at-knee", demo_tiles)):
        m = 128 * max(1, tiles)
        p0 = ex.resolve_policy(m, 4096, 128, precision="fp8", advisor=prior)
        p1 = ex.resolve_policy(m, 4096, 128, precision="fp8", advisor=cal)
        flip = "  <-- calibration changed the decision" \
            if p0.precision != p1.precision else ""
        print(f"  resolve[{label}, {tiles} tiles]: prior={p0.spec()} "
              f"calibrated={p1.spec()}{flip}")
    if saved:
        print(f"[profile] artifact written: {saved}")
    else:
        print("[profile] --no-save: artifact not written")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
