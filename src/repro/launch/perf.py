"""§Perf hillclimb driver: relower a cell under a named variant, compare
roofline terms against the recorded baseline.

  python -m repro.launch.perf --arch llama3-405b --shape decode_32k \
      --variant decode_2d_tp --out benchmarks/artifacts/perf.jsonl

Variants (hypothesis → change; results in EXPERIMENTS.md §Perf):
  baseline         — recorded dry-run configuration
  fp8              — paper-faithful FP8 matmuls (E4M3 operands, f32 accum):
                     halves matmul operand bytes vs bf16
  fp8_sparse       — FP8 + 2:4 STE pruning (paper's two techniques together)
  decode_2d_tp     — decode activations replicate batch / shard d on "data";
                     matmuls contract against resident 2-D weight shards and
                     psum small activations instead of all-gathering weights
  moe_gather       — gather/scatter MoE dispatch (no one-hot dispatch FLOPs)
  moments_bf16     — bf16 AdamW moments (train-cell HBM fit)
  no_seq_shard     — ablation: disable Megatron-SP activation sharding
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import time
from typing import Any, Callable, Dict, Optional

import jax

jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 5.0)

from repro.configs import get_arch, get_shape
from repro.launch import dryrun as dr
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models.layers import RuntimeCfg
from repro.runtime import sharding as sh


@dataclasses.dataclass
class Variant:
    name: str
    cfg_fn: Callable = lambda c: c
    rt_fn: Callable = lambda r: r
    decode_2d_tp: bool = False
    opt_moments_bf16: bool = False


VARIANTS: Dict[str, Variant] = {
    "baseline": Variant("baseline"),
    "fp8": Variant(
        "fp8", cfg_fn=lambda c: dataclasses.replace(c, precision="fp8")),
    "fp8_sparse": Variant(
        "fp8_sparse", cfg_fn=lambda c: dataclasses.replace(
            c, precision="fp8", sparsity_24=True)),
    "decode_2d_tp": Variant("decode_2d_tp", decode_2d_tp=True),
    "moe_gather": Variant(
        "moe_gather",
        rt_fn=lambda r: dataclasses.replace(r, moe_gather_dispatch=True)),
    "moments_bf16": Variant("moments_bf16", opt_moments_bf16=True),
    "no_seq_shard": Variant("no_seq_shard"),
    "grad_bf16": Variant("grad_bf16"),       # bf16 gradient reduction
    "remat_dots": Variant(                   # save dot outputs: fwd weight
        "remat_dots", cfg_fn=lambda c: dataclasses.replace(c, remat="dots")),
    "fsdp_only": Variant("fsdp_only"),       # no TP: batch over both axes
    "fsdp_only_fp8": Variant(                # combo: ZeRO-3 + fp8 weights
        "fsdp_only_fp8",
        cfg_fn=lambda c: dataclasses.replace(c, precision="fp8")),
}


def run_variant(arch_name: str, shape_name: str, variant_name: str,
                with_layer: bool = True,
                backend: Optional[str] = None) -> Dict[str, Any]:
    var = VARIANTS[variant_name]
    cfg = var.cfg_fn(get_arch(arch_name))
    shape = get_shape(shape_name)
    mesh = make_production_mesh()
    seq_shard = variant_name != "no_seq_shard"
    rt = dr.make_rt(cfg, mesh, shape, seq_shard_acts=seq_shard)
    rt = var.rt_fn(rt)
    if backend:
        from repro.core import execution as ex
        rt = dataclasses.replace(rt, policy=ex.ExecutionPolicy(
            precision=cfg.precision,
            sparsity="sparse24" if cfg.sparsity_24 else "dense",
            backend=backend))
    if var.decode_2d_tp:
        rt = dataclasses.replace(rt, shard_fn=sh.make_shard_fn(
            cfg, mesh, shape, decode_2d_tp=True))

    rec: Dict[str, Any] = {"arch": arch_name, "shape": shape_name,
                           "variant": variant_name, "chips": mesh.size,
                           "backend": backend or "jnp"}
    t0 = time.time()
    lower = {"train": dr.lower_train, "prefill": dr.lower_prefill}.get(
        shape.kind, dr.lower_decode)
    if variant_name == "grad_bf16" and shape.kind == "train":
        import functools
        lower = functools.partial(dr.lower_train, grad_compress="bf16")
    if variant_name in ("fsdp_only", "fsdp_only_fp8"):
        import functools
        rt = dataclasses.replace(rt, shard_fn=sh.make_shard_fn(
            cfg, mesh, shape, policy="fsdp_only"))
        lower = functools.partial(lower, policy="fsdp_only")

    import repro.optim.adamw as adamw
    if var.opt_moments_bf16:
        import jax.numpy as jnp
        orig = adamw.AdamWConfig
        adamw.AdamWConfig = lambda **kw: orig(
            moments_dtype=jnp.bfloat16, **kw)
    try:
        compiled, layer = lower(cfg, shape, mesh, rt, with_layer)
        rt_mem = dataclasses.replace(rt, static_loops=False)
        mem_compiled, _ = lower(cfg, shape, mesh, rt_mem, False)
        rec["ok"] = True
        rec["compile_s"] = time.time() - t0
        rec["memory"] = dr._mem_of(mem_compiled)
        full = dr._cost_of(compiled)
        rec["full"] = dataclasses.asdict(full)
        rec["layer"] = dataclasses.asdict(layer) if layer else None
        rec["model_flops"] = rl.model_flops_estimate(cfg, shape)
        rec["min_bytes"] = rl.min_bytes_estimate(cfg, shape)
        roof = rl.assemble(arch_name, shape_name, mesh.size, full, layer,
                           cfg.num_superlayers, rec["model_flops"],
                           min_bytes=rec["min_bytes"], kind=shape.kind)
        rec["roofline"] = roof.to_dict()
        r = rec["roofline"]
        print(f"[{arch_name} × {shape_name} × {variant_name}] "
              f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"coll={r['collective_s']:.4f}s bottleneck={r['bottleneck']} "
              f"frac={r['roofline_fraction']:.4f} "
              f"mem/dev={rec['memory']['per_device_total']/2**30:.1f}GiB")
    except Exception as e:  # noqa: BLE001
        import traceback
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-1500:]
        print(f"[{arch_name} × {shape_name} × {variant_name}] FAIL "
              f"{rec['error'][:160]}")
    finally:
        if var.opt_moments_bf16:
            adamw.AdamWConfig = orig
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True,
                    help=",".join(VARIANTS))
    ap.add_argument("--backend", default=None,
                    choices=[None, "ref", "jnp", "pallas", "pallas_sparse24"],
                    help="route every matmul through this registry backend")
    ap.add_argument("--out", default="benchmarks/artifacts/perf.jsonl")
    args = ap.parse_args()
    for v in args.variant.split(","):
        rec = run_variant(args.arch, args.shape, v, backend=args.backend)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
