"""Token data pipeline: synthetic + file-backed, sharded, prefetching.

Production requirements covered:
* deterministic, seekable cursor (part of the checkpoint -> exact restart)
* per-host sharding (`host_id`/`host_count`) for multi-host launches
* background prefetch thread keeping `depth` batches in flight
* next-token LM batches: {"inputs": (B, S) int32, "labels": (B, S) int32}
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataCursor:
    """Checkpointable pipeline position."""
    step: int = 0

    def to_dict(self):
        return {"step": self.step}

    @staticmethod
    def from_dict(d):
        return DataCursor(step=int(d["step"]))


class SyntheticLM:
    """Deterministic synthetic token stream (counter-based PRNG: batch i is
    always the same regardless of order -> bitwise-reproducible restarts)."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, host_id: int = 0, host_count: int = 1):
        assert global_batch % host_count == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // host_count
        self.seed = seed
        self.host_id = host_id
        self.cursor = DataCursor()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.Philox(key=self.seed + (step << 16) + self.host_id)
        gen = np.random.Generator(rng)
        toks = gen.integers(0, self.vocab,
                            size=(self.local_batch, self.seq + 1),
                            dtype=np.int32)
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.batch_at(self.cursor.step)
            self.cursor.step += 1
            yield b


class TokenFileDataset:
    """Flat binary token file (int32/uint16), strided into sequences.

    The file is memory-mapped; batch n is a deterministic function of the
    cursor, so restart-from-checkpoint replays exactly.
    """

    def __init__(self, path: str, seq_len: int, global_batch: int,
                 dtype=np.int32, host_id: int = 0, host_count: int = 1,
                 vocab_size: Optional[int] = None):
        assert global_batch % host_count == 0
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.seq = seq_len
        self.local_batch = global_batch // host_count
        self.global_batch = global_batch
        self.host_id = host_id
        self.vocab = vocab_size
        self.n_seqs = (len(self.tokens) - 1) // seq_len
        if self.n_seqs < global_batch:
            raise ValueError(
                f"{path}: only {self.n_seqs} sequences of len {seq_len}; "
                f"need >= {global_batch}")
        self.cursor = DataCursor()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        out_in = np.empty((self.local_batch, self.seq), np.int32)
        out_lb = np.empty((self.local_batch, self.seq), np.int32)
        base = step * self.global_batch + self.host_id * self.local_batch
        for i in range(self.local_batch):
            s = ((base + i) % self.n_seqs) * self.seq
            chunk = self.tokens[s:s + self.seq + 1].astype(np.int32)
            out_in[i] = chunk[:-1]
            out_lb[i] = chunk[1:]
        if self.vocab:
            np.clip(out_in, 0, self.vocab - 1, out=out_in)
            np.clip(out_lb, 0, self.vocab - 1, out=out_lb)
        return {"inputs": out_in, "labels": out_lb}

    def __iter__(self):
        while True:
            b = self.batch_at(self.cursor.step)
            self.cursor.step += 1
            yield b


class Prefetcher:
    """Background-thread prefetch of `depth` batches ahead."""

    def __init__(self, dataset, depth: int = 2, put_fn=None):
        self.dataset = dataset
        self.depth = depth
        self.put_fn = put_fn or (lambda x: x)   # e.g. device_put w/ shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        it = iter(self.dataset)
        while not self._stop.is_set():
            try:
                batch = next(it)
            except StopIteration:
                self._q.put(None)
                return
            self._q.put(self.put_fn(batch))

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
