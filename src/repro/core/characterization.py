"""Execution-centric microbenchmark engine (paper §4–§7 methodology).

Each sweep isolates one execution behavior with minimal kernels, warmup,
repetition, and controlled scaling — the paper's methodology table (§4.2)
reproduced as a library. Wall-time numbers measured in this container are
CPU-XLA times (the harness is the deliverable; TPU-target numbers come from
the dry-run roofline) — every record carries enough metadata to re-run on a
TPU unchanged.

Sweeps:
  occupancy_sweep   — Fig 2: throughput vs grid parallelism per precision
  shape_sweep       — Fig 3: throughput vs aspect ratio at fixed FLOPs
  latency_probe     — Table 3: dependency-chained per-tile-shape latency
  contention_sweep  — Fig 6–8: per-stream dilation vs stream count/size
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import concurrency as cc

PRECISIONS: Dict[str, Any] = {
    "fp8": jnp.float8_e4m3fn,
    "bf16": jnp.bfloat16,
    "fp16": jnp.float16,
    "fp32": jnp.float32,
}


@dataclasses.dataclass
class Record:
    name: str
    us_per_call: float
    derived: Dict[str, Any]

    def csv(self) -> str:
        extra = ";".join(f"{k}={v}" for k, v in self.derived.items())
        return f"{self.name},{self.us_per_call:.2f},{extra}"


def _time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _matmul_fn(dtype):
    """GEMM under test, routed through the default execution-policy backend
    (``benchmarks/run.py --backend`` re-targets every sweep through here)."""
    from repro.core import execution

    def f(a, b):
        return execution.raw_matmul(a, b, out_dtype=jnp.float32)
    return jax.jit(f)


def _mk(shape, dtype, key=0):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return (x * 4).astype(dtype) if dtype == jnp.float8_e4m3fn \
        else x.astype(dtype)


# ---------------------------------------------------------------------------
# Fig 2 — occupancy (grid parallelism) sweep
# ---------------------------------------------------------------------------

def occupancy_sweep(tile_counts: Sequence[int] = (1, 2, 4, 8, 16, 32),
                    tile_m: int = 128, k: int = 256, n: int = 256,
                    precisions: Sequence[str] = ("fp32", "bf16", "fp8"),
                    iters: int = 5) -> List[Record]:
    """Throughput vs #tiles: M = tiles × tile_m at fixed (K, N).

    TPU adaptation of "active wavefronts": each 128-row M tile is one unit
    of grid parallelism for the MXU. Throughput is normalized per precision
    to its own best (exposes the occupancy *threshold*, the paper's Fig 2
    signature, independent of absolute hardware peak).
    """
    out: List[Record] = []
    for prec in precisions:
        dtype = PRECISIONS[prec]
        raw: List[Tuple[int, float]] = []
        for t in tile_counts:
            m = t * tile_m
            a, b = _mk((m, k), dtype), _mk((k, n), dtype, 1)
            dt = _time_fn(_matmul_fn(dtype), a, b, iters=iters)
            flops = 2.0 * m * k * n
            raw.append((t, flops / dt))
        best = max(r[1] for r in raw)
        for t, gf in raw:
            out.append(Record(
                name=f"occupancy/{prec}/tiles={t}",
                us_per_call=2.0 * t * tile_m * k * n / gf * 1e6,
                derived={"gflops": round(gf / 1e9, 2),
                         "norm_to_best": round(gf / best, 4),
                         "tiles": t, "precision": prec,
                         # full GEMM shape: lets consumers (the autotune
                         # store) convert M-tile counts into the M×N grid
                         # tiles the OccupancyAdvisor's fill is measured in
                         "m": t * tile_m, "k": k, "n": n}))
    return out


def occupancy_threshold(records: List[Record], frac: float = 0.9
                        ) -> Dict[str, int]:
    """Smallest tile count reaching ``frac`` of best throughput, per
    precision — the paper's '256+ wavefronts' statistic."""
    by_prec: Dict[str, List[Tuple[int, float]]] = {}
    for r in records:
        p = r.derived["precision"]
        by_prec.setdefault(p, []).append(
            (r.derived["tiles"], r.derived["norm_to_best"]))
    out = {}
    for p, pts in by_prec.items():
        pts.sort()
        out[p] = next((t for t, v in pts if v >= frac), pts[-1][0])
    return out


# ---------------------------------------------------------------------------
# Fig 3 — aspect-ratio (shape) sweep at fixed total work
# ---------------------------------------------------------------------------

def shape_sweep(total_mn: int = 512 * 512, k: int = 256,
                ratios: Sequence[float] = (0.25, 0.5, 1.0, 2.0, 4.0),
                precisions: Sequence[str] = ("fp32", "bf16", "fp8"),
                iters: int = 5) -> List[Record]:
    """Fixed M·N (total work), vary M/N. 128-alignment preserved."""
    out: List[Record] = []
    for prec in precisions:
        dtype = PRECISIONS[prec]
        for r in ratios:
            m = int(round((total_mn * r) ** 0.5 / 128)) * 128
            m = max(m, 128)
            n = max(total_mn // m // 128 * 128, 128)
            a, b = _mk((m, k), dtype), _mk((k, n), dtype, 1)
            dt = _time_fn(_matmul_fn(dtype), a, b, iters=iters)
            gf = 2.0 * m * k * n / dt / 1e9
            out.append(Record(
                name=f"shape/{prec}/ratio={r}",
                us_per_call=dt * 1e6,
                derived={"gflops": round(gf, 2), "m": m, "n": n,
                         "ratio": r, "precision": prec}))
    return out


# ---------------------------------------------------------------------------
# Table 3 — dependency-chained tile latency
# ---------------------------------------------------------------------------

def latency_probe(tile_shapes: Sequence[Tuple[int, int, int]] = (
        (128, 128, 128), (256, 256, 128), (128, 128, 256),
        (256, 256, 256), (512, 512, 128)),
        precisions: Sequence[str] = ("fp32", "bf16", "fp8"),
        chain: int = 16, iters: int = 5) -> List[Record]:
    """Chained matmuls (output feeds the next input) isolate per-tile-shape
    issue latency, the paper's Table-3 methodology at MXU granularity."""
    out: List[Record] = []
    for prec in precisions:
        dtype = PRECISIONS[prec]
        for (m, n, k) in tile_shapes:

            def chained(a, b):
                from repro.core import execution
                x = a
                for _ in range(chain):
                    y = execution.raw_matmul(x, b, out_dtype=jnp.float32)
                    # renormalize + recast: keeps the chain stable and the
                    # dependency real
                    x = (y / jnp.float32(k)).astype(dtype)[:, :k]
                return x

            a = _mk((m, k), dtype)
            b = _mk((k, max(n, k)), dtype, 1)
            dt = _time_fn(jax.jit(chained), a, b, iters=iters)
            out.append(Record(
                name=f"latency/{prec}/{m}x{n}x{k}",
                us_per_call=dt / chain * 1e6,
                derived={"per_tile_us": round(dt / chain * 1e6, 2),
                         "tile": f"{m}x{n}x{k}", "precision": prec}))
    return out


# ---------------------------------------------------------------------------
# Table 3 extension — block-shape *sweep* (alternative tilings per shape)
# ---------------------------------------------------------------------------

def block_candidates(m: int, n: int, k: int, precision: str,
                     max_candidates: int = 3
                     ) -> List[Tuple[int, int, int]]:
    """2–3 alternative (bm, bn, bk) tilings for one (m, n, k) GEMM:
    the precision-preferred Table-3 blocks, the square MXU-native tile,
    and the single-block (whole-problem) tiling — each clamped to the
    problem, deduplicated, deterministic order."""
    from repro.core import execution as ex
    pref = ex.BlockShapeCache.TABLE3_PREFERRED.get(
        precision, (128, 128, 128))
    raw = [pref, (128, 128, 128), (m, n, k)]
    out: List[Tuple[int, int, int]] = []
    for bm, bn, bk in raw:
        cand = (min(bm, m), min(bn, n), min(bk, k))
        if cand not in out:
            out.append(cand)
    return out[:max_candidates]


def block_sweep_probe(shapes: Sequence[Tuple[int, int, int]] = (
        (256, 256, 256), (128, 256, 512)),
        precisions: Sequence[str] = ("bf16", "fp8"),
        backend: str = "pallas", iters: int = 3) -> List[Record]:
    """Measure each shape under *alternative block tilings* (the ROADMAP
    "calibrate block shapes from real block sweeps" item — the plain
    :func:`latency_probe` measures shapes, never competing tilings).

    Routes through the policy dispatcher with the blocks pinned on an
    explicit :class:`~repro.core.execution.ExecutionPolicy`, so the sweep
    exercises exactly the path ``resolve_policy`` will later stamp the
    winning blocks onto. Record names are
    ``blocksweep/{prec}/{m}x{n}x{k}/{bm}x{bn}x{bk}``, the format
    :meth:`repro.core.autotune.AutotuneStore.add_records` ingests as block
    evidence (its per-key min keeps the winner); the fastest tiling per
    (shape, precision) is flagged ``winner=True``."""
    from repro.core import execution as ex
    bad = set(precisions) - set(ex.PRECISIONS)
    if bad:
        # a silent fallback would mislabel another precision's latency
        # as block evidence for this one in the autotune artifact
        raise ValueError(f"block_sweep_probe precisions {sorted(bad)} not "
                         f"in policy precisions {ex.PRECISIONS}")
    out: List[Record] = []
    for prec in precisions:
        for (m, n, k) in shapes:
            x = _mk((m, k), jnp.bfloat16)
            w = _mk((k, n), jnp.bfloat16, 1)
            group: List[Record] = []
            for (bm, bn, bk) in block_candidates(m, n, k, prec):
                pol = ex.ExecutionPolicy(
                    precision=prec, backend=backend,
                    block_m=bm, block_n=bn, block_k=bk)
                fn = jax.jit(lambda a, b, pol=pol: ex.matmul(
                    a, b, pol, out_dtype=jnp.float32))
                dt = _time_fn(fn, x, w, iters=iters)
                group.append(Record(
                    name=f"blocksweep/{prec}/{m}x{n}x{k}/{bm}x{bn}x{bk}",
                    us_per_call=dt * 1e6,
                    derived={"m": m, "n": n, "k": k, "precision": prec,
                             "blocks": f"{bm}x{bn}x{bk}",
                             "backend": backend, "winner": False}))
            best = min(group, key=lambda r: r.us_per_call)
            best.derived["winner"] = True
            out.extend(group)
    return out


# ---------------------------------------------------------------------------
# Fig 6–8 — contention sweep (stream count × working-set size)
# ---------------------------------------------------------------------------

def contention_sweep(sizes: Dict[str, int] = None,
                     stream_counts: Sequence[int] = (1, 2, 4),
                     iters: int = 3) -> List[Record]:
    """Per-stream dilation under concurrency for thin/medium/thick kernels.

    The paper reads L2-miss counters; without hardware counters the
    *dilation* (concurrent time / isolated time) is the observable the
    paper's Fig 8 reports, and the thin/medium/thick contrast carries the
    same signature (bigger working sets → more contention).
    """
    sizes = sizes or {"thin": 128, "medium": 256, "thick": 512}
    out: List[Record] = []
    for label, s in sizes.items():
        dtype = jnp.float32
        fn = _matmul_fn(dtype)
        a, b = _mk((s, s), dtype), _mk((s, s), dtype, 1)
        iso = _time_fn(fn, a, b, iters=iters)
        for ns in stream_counts:
            def mk(i):
                ai = _mk((s, s), dtype, key=i)
                return lambda: fn(ai, b)
            rep = cc.characterize_streams(mk, ns, mode="async")
            dilation = (np.mean(rep.per_stream_s) / iso) if iso else 0.0
            out.append(Record(
                name=f"contention/{label}/streams={ns}",
                us_per_call=float(np.mean(rep.per_stream_s)) * 1e6,
                derived={"dilation": round(float(dilation), 3),
                         "fairness": round(rep.fairness, 4),
                         "cv": round(rep.cv, 4),
                         "overlap_eff": round(rep.overlap_efficiency, 4),
                         "size": s, "streams": ns}))
    return out
