# The paper's contribution as composable modules:
#   fp8               — FP8 tensor-scaled matmul + delayed scaling (§5)
#   sparsity          — 2:4 prune/pack + packed matmul (§7)
#   concurrency       — stream scheduling + fairness/overlap metrics (§6)
#   characterization  — the microbenchmark methodology itself (§4)
# (Submodules are imported lazily by callers to keep import costs low and
# avoid cycles; `from repro.core import fp8` etc.)

__all__ = ["fp8", "sparsity", "concurrency", "characterization"]
