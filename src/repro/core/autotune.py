"""Persistent autotune store + online policy calibration.

The acting half of the execution observatory (the seeing half is
:mod:`repro.runtime.telemetry`): everything the policy layer currently
decides from *hard-coded* Table-3/§9.2 constants — preferred block
shapes, the FP8-demotion occupancy threshold — becomes a **measured**
quantity persisted to a JSON artifact, so one benchmark or calibration
run permanently improves every later ``resolve_policy`` lookup.

* :class:`AutotuneStore` — serializes/loads block-shape cache entries
  (:class:`repro.core.execution.BlockShapeCache`), raw occupancy samples
  (per-precision throughput vs grid-tile count), and the thresholds
  calibrated from them, to ``<artifact_dir>/autotune.json``.
* :meth:`AutotuneStore.calibrate` — re-derives the FP8 occupancy knee
  from recorded samples: the smallest observed tile count where measured
  FP8 throughput matches the bf16 baseline. Below the knee the advisor
  demotes to bf16 *because measurement said so*, not because Table 3
  said so on different hardware.
* :func:`install` — loads the artifact, folds its block entries into the
  global ``BLOCK_CACHE``, and installs a calibrated
  :class:`~repro.core.concurrency.OccupancyAdvisor` as the
  ``resolve_policy`` default.

Artifact location: ``$REPRO_AUTOTUNE_DIR`` or
``benchmarks/artifacts/autotune``. Reset by deleting the directory or
``AutotuneStore.reset()`` / ``launch/profile.py --reset``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import concurrency as cc

ENV_DIR = "REPRO_AUTOTUNE_DIR"
DEFAULT_DIR = os.path.join("benchmarks", "artifacts", "autotune")
ARTIFACT_NAME = "autotune.json"
SCHEMA_VERSION = 1

# Calibration baseline precision: FP8 is judged against this (§5's
# "FP16 at 128 wavefronts outperforms underutilized FP8", bf16 on TPU).
BASELINE_PRECISION = "bf16"


def artifact_dir() -> str:
    return os.environ.get(ENV_DIR) or DEFAULT_DIR


@dataclasses.dataclass
class Sample:
    """One occupancy observation: throughput of a GEMM at a grid-tile
    count, per precision (the Fig-2 axis as raw evidence)."""
    precision: str
    tiles: int
    gflops: float
    m: int = 0
    k: int = 0
    n: int = 0
    source: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Record serialization (shared by benchmarks/run.py --out)
# ---------------------------------------------------------------------------

def json_safe(v: Any) -> Any:
    """Coerce one derived value to a JSON-serializable form: scalars pass
    through, lists/tuples of scalars recurse (``StreamReport.per_stream_s``
    survives a dump/load round trip), anything else stringifies."""
    if isinstance(v, (int, float, str, bool, type(None))):
        return v
    if isinstance(v, (list, tuple)):
        return [json_safe(x) for x in v]
    return str(v)


def record_to_dict(rec) -> Dict[str, Any]:
    """``characterization.Record`` → plain dict (JSON-safe derived).

    The one Record schema: ``StreamReport.to_record`` produces these,
    ``dump_records``/``load_records`` persist them, and
    :meth:`AutotuneStore.add_records` ingests them."""
    return {"name": rec.name, "us_per_call": float(rec.us_per_call),
            "derived": {k: json_safe(v) for k, v in rec.derived.items()}}


def dump_records(records: Sequence[Any], path: str) -> str:
    """Write benchmark Records as a JSON list (machine-readable bench
    trajectories across PRs); returns the path."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    _atomic_write(path, json.dumps([record_to_dict(r) for r in records],
                                   indent=1))
    return path


def load_records(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        return json.load(f)


def _atomic_write(path: str, text: str) -> None:
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".autotune-")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


# ---------------------------------------------------------------------------
# The store
# ---------------------------------------------------------------------------

class AutotuneStore:
    """Measured policy inputs, persisted.

    ``blocks``: {(m, k, n, prec): (blocks, seconds)} — the
    ``BlockShapeCache`` entry format.
    ``samples``: occupancy evidence (:class:`Sample`).
    ``thresholds``: output of :meth:`calibrate` (empty until calibrated).
    """

    def __init__(self, art_dir: Optional[str] = None):
        self.dir = art_dir or artifact_dir()
        self.blocks: Dict[Tuple[int, int, int, str],
                          Tuple[Tuple[int, int, int], float]] = {}
        self.samples: List[Sample] = []
        self.thresholds: Dict[str, float] = {}

    @property
    def path(self) -> str:
        return os.path.join(self.dir, ARTIFACT_NAME)

    # -- recording ----------------------------------------------------------
    def record_block(self, m: int, k: int, n: int, prec: str,
                     blocks: Sequence[int], seconds: float) -> None:
        key = (int(m), int(k), int(n), str(prec))
        cur = self.blocks.get(key)
        if cur is None or seconds < cur[1]:
            self.blocks[key] = (tuple(int(b) for b in blocks),
                                float(seconds))

    def record_sample(self, precision: str, tiles: int, gflops: float,
                      m: int = 0, k: int = 0, n: int = 0,
                      source: str = "") -> None:
        self.samples.append(Sample(precision=str(precision),
                                   tiles=int(tiles), gflops=float(gflops),
                                   m=int(m), k=int(k), n=int(n),
                                   source=source))

    def ingest_cache(self, cache) -> int:
        """Fold a :class:`BlockShapeCache`'s *measured* entries in (seeded
        entries carry seconds=inf and stay out: the artifact records
        evidence, not priors). Returns how many entries were taken."""
        n = 0
        for (m, k, n_, prec), (blocks, seconds) in cache.entries().items():
            if seconds == float("inf"):
                continue
            self.record_block(m, k, n_, prec, blocks, seconds)
            n += 1
        return n

    def add_records(self, records: Sequence[Any]) -> int:
        """Ingest benchmark Records: ``occupancy/{prec}/tiles={t}`` rows
        become samples, ``latency/{prec}/{m}x{n}x{k}`` rows become block
        entries (precision-preferred blocks clamped to the shape, matching
        ``execution.seed_cache_from_records``), and
        ``blocksweep/{prec}/{m}x{n}x{k}/{bm}x{bn}x{bk}`` rows become block
        entries carrying the tiling that was *actually measured* — the
        per-key min keeps the sweep's winner. Returns rows ingested."""
        from repro.core import execution as ex
        n_in = 0
        for r in records:
            parts = r.name.split("/")
            # blocksweep (GEMM tilings) and pagedsweep (paged flash-decode
            # page geometries) share the shape grammar and the per-key-min
            # block store.
            sweep = ex.parse_blocksweep_name(r.name) \
                or ex.parse_pagedsweep_name(r.name)
            if sweep is not None:
                m, n, k, prec, blocks = sweep
                self.record_block(m, k, n, prec, blocks,
                                  r.us_per_call * 1e-6)
                n_in += 1
            elif len(parts) == 3 and parts[0] == "occupancy":
                d = r.derived
                if "tiles" in d and "gflops" in d:
                    # Store tiles in the advisor's unit — M×N grid tiles
                    # (occupancy_sweep's "tiles" counts M tiles only; its
                    # fixed N adds a ceil(n/128) factor to the fill).
                    if d.get("m") and d.get("n"):
                        tiles = ex.grid_tiles(int(d["m"]), int(d["n"]))
                    else:
                        tiles = int(d["tiles"])
                    self.record_sample(
                        d.get("precision", parts[1]), tiles,
                        float(d["gflops"]), m=int(d.get("m", 0)),
                        k=int(d.get("k", 0)), n=int(d.get("n", 0)),
                        source=r.name)
                    n_in += 1
            elif len(parts) == 3 and parts[0] == "latency":
                prec = parts[1]
                pref = ex.BlockShapeCache.TABLE3_PREFERRED.get(prec)
                if pref is None:
                    continue
                try:
                    m, n, k = (int(v) for v in parts[2].split("x"))
                except ValueError:
                    continue
                blocks = tuple(min(b, d) for b, d in zip(pref, (m, n, k)))
                self.record_block(m, k, n, prec, blocks,
                                  r.us_per_call * 1e-6)
                n_in += 1
        return n_in

    # -- calibration --------------------------------------------------------
    def calibrate(self, n_cores: Optional[int] = None,
                  win_ratio: float = 1.0) -> Dict[str, float]:
        """Re-derive the FP8 occupancy knee from the recorded samples.

        Per tile-count bucket, mean FP8 throughput is compared against the
        bf16 baseline; the knee is the smallest bucket where FP8 reaches
        ``win_ratio`` of bf16. The demotion threshold is the knee
        expressed as grid fill (tiles / cores); adding more samples at or
        above the knee where FP8 wins can only keep or *lower* it (the
        knee is a min over winning buckets), never raise it.
        """
        n_cores = n_cores or cc.detect_core_count()
        by: Dict[str, Dict[int, List[float]]] = {}
        for s in self.samples:
            by.setdefault(s.precision, {}).setdefault(
                s.tiles, []).append(s.gflops)

        def mean(prec: str, tiles: int) -> Optional[float]:
            vals = by.get(prec, {}).get(tiles)
            return sum(vals) / len(vals) if vals else None

        fp8_tiles = sorted(by.get("fp8", {}))
        winning = []
        comparable = []
        for t in fp8_tiles:
            base = mean(BASELINE_PRECISION, t)
            f8 = mean("fp8", t)
            if base is None or f8 is None or base <= 0:
                continue
            comparable.append(t)
            if f8 >= win_ratio * base:
                winning.append(t)

        thresholds: Dict[str, float] = {"n_cores": float(n_cores),
                                        "samples": float(len(self.samples))}
        if winning:
            knee = min(winning)
            thresholds["knee_tiles"] = float(knee)
            thresholds["demote_below_fill"] = knee / n_cores
            thresholds["fp8_fill_target"] = max(
                cc.OccupancyAdvisor.FP8_TILE_THRESHOLD, knee / n_cores)
        elif comparable:
            # FP8 never won in the measured range: demote everywhere we
            # have evidence for (conservative, still measurement-driven).
            top = max(comparable)
            thresholds["knee_tiles"] = float(top)
            thresholds["demote_below_fill"] = top / n_cores
            thresholds["fp8_fill_target"] = max(
                cc.OccupancyAdvisor.FP8_TILE_THRESHOLD, top / n_cores)
        self.thresholds = thresholds
        return thresholds

    def make_advisor(self, n_cores: Optional[int] = None
                     ) -> cc.OccupancyAdvisor:
        """An :class:`OccupancyAdvisor` running on the calibrated
        thresholds (falls back to the Table-3 defaults for anything not
        measured). ``calibrated`` is claimed only when a knee was actually
        derived — a store without comparable fp8/bf16 evidence hands back
        a prior-threshold advisor that says so."""
        thr = self.thresholds
        return cc.OccupancyAdvisor(
            n_cores=n_cores if n_cores is not None else (
                int(thr["n_cores"]) if "n_cores" in thr else None),
            fp8_fill_target=thr.get("fp8_fill_target"),
            demote_below_fill=thr.get("demote_below_fill"),
            calibrated=thr.get("demote_below_fill") is not None)

    # -- persistence --------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": SCHEMA_VERSION,
            "blocks": [{"m": m, "k": k, "n": n, "prec": prec,
                        "blocks": list(blocks), "seconds": seconds}
                       for (m, k, n, prec), (blocks, seconds)
                       in sorted(self.blocks.items())],
            "samples": [s.to_dict() for s in self.samples],
            "thresholds": self.thresholds,
        }

    def save(self) -> str:
        os.makedirs(self.dir, exist_ok=True)
        _atomic_write(self.path, json.dumps(self.to_dict(), indent=1))
        return self.path

    def load(self) -> bool:
        """Merge the on-disk artifact in (keeps anything recorded since
        construction). Returns False when no artifact exists."""
        if not os.path.exists(self.path):
            return False
        with open(self.path) as f:
            data = json.load(f)
        for b in data.get("blocks", ()):
            self.record_block(b["m"], b["k"], b["n"], b["prec"],
                              b["blocks"], b["seconds"])
        for s in data.get("samples", ()):
            self.samples.append(Sample(**s))
        if data.get("thresholds"):
            self.thresholds = dict(data["thresholds"])
        return True

    def reset(self) -> None:
        self.blocks.clear()
        self.samples.clear()
        self.thresholds.clear()
        try:
            os.unlink(self.path)
        except OSError:
            pass

    # -- application --------------------------------------------------------
    def apply(self, cache=None) -> int:
        """Fold the stored block entries into a :class:`BlockShapeCache`
        (the global ``BLOCK_CACHE`` by default); returns entries applied."""
        from repro.core import execution as ex
        cache = cache if cache is not None else ex.BLOCK_CACHE
        n = 0
        for (m, k, n_, prec), (blocks, seconds) in self.blocks.items():
            cache.record(m, k, n_, prec, blocks, seconds)
            n += 1
        return n


def install(store: Optional[AutotuneStore] = None,
            art_dir: Optional[str] = None) -> Optional[AutotuneStore]:
    """Close the loop for this process: load the persisted artifact, seed
    the global ``BLOCK_CACHE`` with its measured block entries, and make
    the calibrated advisor the ``resolve_policy`` default. Returns the
    store, or None when no artifact exists (nothing installed)."""
    from repro.core import execution as ex
    if store is None:
        store = AutotuneStore(art_dir)
        if not store.load():
            return None
    store.apply()
    if store.thresholds.get("demote_below_fill") is not None:
        ex.set_default_advisor(store.make_advisor())
    return store
