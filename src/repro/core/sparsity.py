"""2:4 structured sparsity (paper §7), adapted to TPU.

The paper characterizes CDNA3's sparse-MFMA path: 2 of every 4 consecutive
elements are zero, hardware skips the zeros (theoretical 2× FLOPs), but the
realized benefit on MI300A is *context-dependent* — break-even in isolation
(constant rocSPARSE overhead), 1.3× under concurrency.

TPU has **no sparse MXU**. The TPU-native adaptation (DESIGN.md §2):

* ``prune_24`` — magnitude-based 2:4 pruning along the contraction (K) dim;
  numerics identical to the paper's pattern.
* ``pack_24 / unpack_24`` — compressed representation: values ``(K/2, N)``
  plus 2-bit indices packed 4-per-byte ``(K/8, N)``. For fp8 values this is
  0.3125× the HBM bytes of a *bf16 dense* weight (0.625× of fp8 dense).
* ``sparse24_matmul_ref`` — decompress-then-dense-matmul oracle. The Pallas
  kernel (kernels/sparse24_matmul.py) performs the decompress in VMEM so HBM
  only ever sees packed bytes: FLOPs unchanged, weight bandwidth halved —
  a *memory-roofline* optimization, which is exactly the regime (decode,
  small batch) where TPU LLM serving is bandwidth-bound.
* ``prune_block24 / block24_matmul_ref`` — beyond-paper variant: 2:4 at the
  granularity of K-blocks (2 of every 4 consecutive 128-wide K-blocks are
  zero), which lets the Pallas kernel *skip MXU tiles* for a real 2× FLOP
  reduction. This is the "custom kernels could achieve optimal speedup"
  direction the paper points at (§9.1).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# 2:4 pruning (element granularity, along K = axis 0 of a (K, N) weight)
# ---------------------------------------------------------------------------

def prune_24(w: jax.Array) -> jax.Array:
    """Magnitude-prune to 2:4 along axis 0. ``w``: (K, N), K % 4 == 0.

    Keeps the 2 largest-magnitude elements of every contiguous group of 4.
    Deterministic tie-break toward lower index (matches cuSPARSELt/rocSPARSE
    conventions closely enough for numerics tests).
    """
    K, N = w.shape
    assert K % 4 == 0, f"K={K} must be divisible by 4"
    g = w.reshape(K // 4, 4, N)
    mag = jnp.abs(g)
    # rank within each group of 4; keep top-2. argsort twice gives ranks.
    order = jnp.argsort(-mag, axis=1, stable=True)
    ranks = jnp.argsort(order, axis=1, stable=True)
    keep = ranks < 2
    return (g * keep).reshape(K, N).astype(w.dtype)


def check_24(w: jax.Array) -> jax.Array:
    """True iff every group of 4 along axis 0 has <= 2 nonzeros."""
    K, N = w.shape
    nnz = (w.reshape(K // 4, 4, N) != 0).sum(axis=1)
    return jnp.all(nnz <= 2)


# ---------------------------------------------------------------------------
# Packing: values (K/2, N) + 2-bit indices packed 4/byte (K/8, N)
# ---------------------------------------------------------------------------

def pack_24(w24: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Compress a 2:4 weight. Returns (values (K/2, N), meta (K/8, N) uint8).

    Each group of 4 rows contributes exactly 2 values; their in-group
    positions (2 bits each) for 2 consecutive groups are packed into one
    byte: ``meta = p0 | p1<<2 | p2<<4 | p3<<6`` where (p0,p1) index group
    2g and (p2,p3) group 2g+1.

    Groups with fewer than 2 nonzeros are padded with index slots holding
    zero values (sound: contributes 0 to the matmul).
    """
    K, N = w24.shape
    assert K % 8 == 0, f"K={K} must be divisible by 8 for byte packing"
    g = w24.reshape(K // 4, 4, N)
    nz = (g != 0)
    # For each group: indices of the (up to) 2 nonzero slots, padded by the
    # smallest zero slots. Build a sort key: nonzero first (by position),
    # then zeros (by position).
    pos = jnp.arange(4, dtype=jnp.int32)[None, :, None]
    key = jnp.where(nz, pos, pos + 4)        # nonzeros sort before zeros
    order = jnp.argsort(key, axis=1, stable=True)   # (G, 4, N)
    idx = order[:, :2, :].astype(jnp.uint8)          # (G, 2, N) positions
    vals = jnp.take_along_axis(g, order[:, :2, :].astype(jnp.int32), axis=1)
    values = vals.reshape(K // 2, N).astype(w24.dtype)
    # pack 4 2-bit indices (2 groups) per byte
    idx2 = idx.reshape(K // 8, 4, N).astype(jnp.uint8)
    meta = (idx2[:, 0] | (idx2[:, 1] << 2) | (idx2[:, 2] << 4)
            | (idx2[:, 3] << 6)).astype(jnp.uint8)
    return values, meta


def unpack_meta(meta: jax.Array) -> jax.Array:
    """(K/8, N) uint8 -> (K/2, N) int32 in-group positions (0..3)."""
    K8, N = meta.shape
    p0 = meta & 0x3
    p1 = (meta >> 2) & 0x3
    p2 = (meta >> 4) & 0x3
    p3 = (meta >> 6) & 0x3
    return jnp.stack([p0, p1, p2, p3], axis=1).reshape(K8 * 4, N).astype(jnp.int32)


def unpack_24(values: jax.Array, meta: jax.Array) -> jax.Array:
    """Decompress packed 2:4 back to dense (K, N)."""
    K2, N = values.shape
    K = K2 * 2
    idx = unpack_meta(meta)                       # (K/2, N) in 0..3
    gvals = values.reshape(K // 4, 2, N)
    gidx = idx.reshape(K // 4, 2, N)
    # scatter into (G, 4, N) via one-hot (vectorized; no gather/scatter op,
    # mirrors what the Pallas kernel does in VMEM)
    onehot = (gidx[:, :, None, :] == jnp.arange(4, dtype=jnp.int32)[None, None, :, None])
    dense = jnp.sum(gvals[:, :, None, :].astype(jnp.float32) * onehot, axis=1)
    return dense.reshape(K, N).astype(values.dtype)


def sparse24_matmul_ref(x: jax.Array, values: jax.Array, meta: jax.Array,
                        out_dtype=jnp.bfloat16) -> jax.Array:
    """Oracle: decompress then dense matmul (f32 accumulation)."""
    w = unpack_24(values, meta)
    acc = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# Beyond-paper: block-2:4 (tile-skipping) variant
# ---------------------------------------------------------------------------

def prune_block24(w: jax.Array, block: int = 128) -> Tuple[jax.Array, jax.Array]:
    """Prune 2 of every 4 consecutive K-blocks (by Frobenius mass).

    Returns (w_pruned dense (K,N), keep_mask (K/block,) bool). Unlike element
    2:4, a whole 128-wide K-block of zeros lets the MXU skip the tile.
    """
    K, N = w.shape
    assert K % (4 * block) == 0, f"K={K} must divide 4*block={4*block}"
    nb = K // block
    blocks = w.reshape(nb, block, N)
    mass = jnp.sum(jnp.abs(blocks.astype(jnp.float32)), axis=(1, 2))
    g = mass.reshape(nb // 4, 4)
    order = jnp.argsort(-g, axis=1, stable=True)
    ranks = jnp.argsort(order, axis=1, stable=True)
    keep = (ranks < 2).reshape(nb)
    wp = (blocks * keep[:, None, None]).reshape(K, N).astype(w.dtype)
    return wp, keep


def block24_matmul_ref(x: jax.Array, w_pruned: jax.Array, keep: jax.Array,
                       block: int = 128, out_dtype=jnp.bfloat16) -> jax.Array:
    """Oracle for the tile-skipping kernel: gather kept blocks, half-K matmul."""
    K, N = w_pruned.shape
    nb = K // block
    kept_idx = jnp.nonzero(keep, size=nb // 2)[0]          # static size: exactly half
    wb = w_pruned.reshape(nb, block, N)[kept_idx]           # (nb/2, block, N)
    xb = x.reshape(*x.shape[:-1], nb, block)
    xb = jnp.take(xb, kept_idx, axis=-2)                    # (..., nb/2, block)
    acc = jnp.einsum("...gk,gkn->...n", xb.astype(jnp.float32),
                     wb.astype(jnp.float32))
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# Byte accounting (used by the roofline + benchmarks)
# ---------------------------------------------------------------------------

def packed_bytes(K: int, N: int, value_dtype=jnp.float8_e4m3fn) -> int:
    vbytes = jnp.dtype(value_dtype).itemsize
    return (K // 2) * N * vbytes + (K // 8) * N          # values + meta


def dense_bytes(K: int, N: int, dtype=jnp.bfloat16) -> int:
    return K * N * jnp.dtype(dtype).itemsize
