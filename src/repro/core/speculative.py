"""Speculative multi-token decoding: low-precision draft, exact verify.

The paper's mixed-precision case study shows FP8 matrix cores (and 2:4
structured sparsity) delivering large throughput headroom that only pays
off when the surrounding *execution structure* exploits it. Draft-and-
verify speculative decoding is that structure at the serving layer: a
cheap **draft** pass proposes ``k - 1`` candidate tokens under an fp8 (or
``fp8:sparse24``) :class:`~repro.core.execution.ExecutionPolicy`, then
ONE batched bf16 **verify** pass (:func:`repro.models.transformer.
multi_decode_step`) scores all ``k`` positions and accepts the longest
prefix whose drafts match the verify argmaxes. Because step ``j`` of the
verify runs the exact plain ``decode_step`` computation at position
``pos + j``, the committed tokens are *provably identical* to plain
greedy decode — acceptance only changes how many of them land per step.

Division of labor:

* this module — the :class:`SpecDecodeSpec` knob surface, the jitted
  draft-chain builder (:func:`make_draft_step` — the draft policy is
  baked into ``rt.policy`` via ``apply_policy``, so it holds regardless
  of the caller's ambient policy scope), the verify wrapper
  (:func:`make_verify_step`), and the online :class:`AdaptiveK`
  controller (mirrors :class:`~repro.runtime.scheduler.AdaptiveQuota`:
  per-tenant acceptance-rate EMAs re-derive the speculation depth every
  ``interval`` steps; the floor ``k = 1`` disables drafting).
* :mod:`repro.models.transformer` — the multi-token verify step and the
  rejected-write cache rollback (dense mask-scrub / paged pool scrub /
  recurrent-state snapshot select).
* :mod:`repro.runtime.serve_loop` — dispatch: the draft runs on its own
  :class:`~repro.core.concurrency.ExecutionLane` and the verify thunk
  consumes the draft's *future* (an XLA data dependency — the host never
  materializes draft tokens), so draft(n+1) can overlap verify(n).

Exactness kill switch: speculation is greedy-only. A session with
``temperature > 0`` refuses a ``SpecDecodeSpec`` outright, and ``k = 1``
falls back to the *exact* plain decode path (same jitted fn, same rng
stream) — the fig22 baseline arm.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Union

import jax.numpy as jnp

from repro.core import execution as ex

__all__ = ["SpecDecodeSpec", "AdaptiveK", "make_draft_step",
           "make_verify_step"]


@dataclasses.dataclass(frozen=True)
class SpecDecodeSpec:
    """Speculative-decoding knobs (``ServeSession(speculative=...)``,
    ``ServingSpec``/``PartitionSpec`` field ``speculative``).

    ``k`` is the maximum tokens *committed* per decode step — one verify
    token plus ``k - 1`` drafts — so ``k = 1`` means no drafting (the
    plain decode path, bit-identical). ``draft_policy`` is the execution
    policy spec the draft chain runs under (``"fp8"`` /
    ``"fp8:sparse24"`` / any :func:`~repro.core.execution.parse_policy`
    string, or an :class:`~repro.core.execution.ExecutionPolicy`).

    ``adaptive=True`` enables the :class:`AdaptiveK` controller: every
    ``interval`` speculative steps each tenant's acceptance-rate EMA
    (smoothing ``ema_alpha``) moves its desired depth — ``>= grow_above``
    grows by 1 toward ``k``, ``<= shrink_below`` shrinks by 1 toward the
    floor of 1 — and the session actuates the minimum across tenants
    sharing the batch.
    """
    k: int = 2
    draft_policy: Union[str, ex.ExecutionPolicy] = "fp8"
    adaptive: bool = False
    ema_alpha: float = 0.3
    interval: int = 8
    grow_above: float = 0.7
    shrink_below: float = 0.3
    # Re-probe: after a tenant has sat at the k=1 floor for this many
    # consecutive recalcs, its desired depth retries 2 so fresh
    # acceptance evidence can flow (with drafting off the EMA never
    # updates). 0 (the default) keeps the floor sticky — the pre-knob
    # behavior, test-pinned.
    reprobe_interval: int = 0

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"speculative k must be >= 1, got {self.k}")
        if self.interval <= 0:
            raise ValueError("adaptive interval must be positive")
        if self.reprobe_interval < 0:
            raise ValueError("reprobe_interval must be >= 0")
        if not (0.0 < self.ema_alpha <= 1.0):
            raise ValueError("ema_alpha must be in (0, 1]")
        if not (0.0 <= self.shrink_below <= self.grow_above <= 1.0):
            raise ValueError("need 0 <= shrink_below <= grow_above <= 1")
        self.resolved()                      # validate the policy spec now

    def resolved(self) -> ex.ExecutionPolicy:
        """The draft policy as an :class:`ExecutionPolicy`."""
        if isinstance(self.draft_policy, ex.ExecutionPolicy):
            return self.draft_policy
        return ex.parse_policy(self.draft_policy)

    def spec_key(self) -> str:
        """Round-trippable draft-policy string (jit cache-key component)."""
        return self.resolved().full_spec()

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["draft_policy"] = self.spec_key()
        return d

    @classmethod
    def from_any(cls, v: Union[None, int, Dict[str, Any], "SpecDecodeSpec"]
                 ) -> Optional["SpecDecodeSpec"]:
        """``None`` / int (k shorthand) / dict / instance →
        ``Optional[SpecDecodeSpec]``."""
        if v is None or isinstance(v, SpecDecodeSpec):
            return v
        if isinstance(v, bool):
            raise TypeError("speculative must be a k (int), dict, or "
                            "SpecDecodeSpec — not a bool")
        if isinstance(v, int):
            return cls(k=v)
        if isinstance(v, dict):
            known = {f.name for f in dataclasses.fields(cls)}
            unknown = set(v) - known
            if unknown:
                raise ValueError(f"unknown SpecDecodeSpec field(s) "
                                 f"{sorted(unknown)}; known: {sorted(known)}")
            return cls(**v)
        raise TypeError(f"speculative spec {v!r} is not None/int/dict/"
                        "SpecDecodeSpec")


# ---------------------------------------------------------------------------
# Jitted step builders (consumed through serve_loop._cached_jit)
# ---------------------------------------------------------------------------

def make_draft_step(cfg, rt, draft_policy: ex.ExecutionPolicy,
                    n_draft: int, *, paged: bool = False):
    """Build the draft chain: ``n_draft`` greedy ``decode_step``s under
    ``draft_policy``, all from the *same* starting cache refs — the
    intermediate draft caches are dropped (JAX arrays are immutable, so
    the session's committed cache is untouched), which is what makes
    re-drafting after a live migration free: there is no draft state to
    carry, only the committed cache the handoff already moves.

    Returns a function ``(params, tokens (B,1), caches, pos[, page_map])
    -> tokens_seq (B, n_draft+1)`` whose row is the verify input:
    ``[t0, d1, ..., d_n]``. Greedy argmax only — the draft proposes, it
    never samples."""
    from repro.models import transformer as tf
    cfg, rt = ex.apply_policy(cfg, rt, draft_policy)

    def draft(params, tokens, caches, pos, page_map=None):
        b = tokens.shape[0]
        posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
        tok = tokens.astype(jnp.int32)
        seq = [tok]
        cur = caches
        for j in range(n_draft):
            if paged:
                logits, cur = tf.paged_decode_step(params, tok, cur,
                                                   posb + j, page_map,
                                                   cfg, rt)
            else:
                logits, cur = tf.decode_step(params, tok, cur, posb + j,
                                             cfg, rt)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            seq.append(tok)
        return jnp.concatenate(seq, axis=1)

    if paged:
        return lambda params, tokens, caches, pos, page_map: \
            draft(params, tokens, caches, pos, page_map)
    return lambda params, tokens, caches, pos: \
        draft(params, tokens, caches, pos)


def make_verify_step(cfg, rt, *, paged: bool = False):
    """Build the bf16 (session-policy) verify step around
    :func:`~repro.models.transformer.multi_decode_step`. ``cfg``/``rt``
    must already carry the session policy (``ServeSession`` applies it at
    construction) so verification is bit-identical to the session's plain
    decode step."""
    from repro.models import transformer as tf
    if paged:
        def step(params, tokens_seq, caches, pos, active, page_map):
            return tf.paged_multi_decode_step(params, tokens_seq, caches,
                                              pos, active, page_map, cfg, rt)
    else:
        def step(params, tokens_seq, caches, pos, active):
            return tf.multi_decode_step(params, tokens_seq, caches, pos,
                                        active, cfg, rt)
    return step


# ---------------------------------------------------------------------------
# Online depth control (the AdaptiveQuota of speculation)
# ---------------------------------------------------------------------------

class AdaptiveK:
    """Re-derive the speculation depth online from acceptance telemetry.

    Mirrors :class:`~repro.runtime.scheduler.AdaptiveQuota`'s shape: the
    session feeds one observation per tenant per speculative step
    (:meth:`observe`), and every ``interval`` ticks (:meth:`on_step`)
    each tenant's EMA moves its *desired* depth by at most 1 — growth
    toward ``spec.k`` above ``grow_above``, shrink toward the floor of 1
    below ``shrink_below``. The actuated session depth is the **minimum**
    desired depth across tenants sharing the batch (the verify step is
    batch-wide; one low-acceptance tenant paying for deep drafts it
    rejects costs more than shallow drafts cost the others).

    The floor disables drafting entirely (``k = 1`` runs the plain decode
    path). With drafting off no new acceptance evidence arrives, so the
    floor is sticky until a tenant's recorded EMA decays out — by design:
    re-probing costs exact work, and a deployment that wants the probe
    back simply re-admits speculation via the spec. The
    ``spec.reprobe_interval`` knob softens this: a tenant parked at the
    floor for that many consecutive recalcs gets its desired depth bumped
    back to 2 for one probe — sustained rejection sends it straight back
    down, while a workload whose acceptance recovered climbs out.
    """

    def __init__(self, spec: SpecDecodeSpec):
        self.spec = spec
        self.max_k = spec.k
        self.ema: Dict[str, float] = {}
        self.desired: Dict[str, int] = {}
        self.k = spec.k
        self.steps = 0
        self.recalcs = 0
        self.reprobes = 0
        self._parked: Dict[str, int] = {}    # consecutive recalcs at floor

    def observe(self, tenant: str, drafted: int, accepted: int) -> None:
        """One tenant-step acceptance sample (``accepted`` of ``drafted``
        proposed tokens survived the verify)."""
        if drafted <= 0:
            return
        r = accepted / drafted
        prev = self.ema.get(tenant)
        a = self.spec.ema_alpha
        self.ema[tenant] = r if prev is None else (1 - a) * prev + a * r
        self.desired.setdefault(tenant, self.k)

    def on_step(self) -> int:
        """Tick once per decode step; returns the depth to use next."""
        self.steps += 1
        if self.steps % self.spec.interval == 0 and self.ema:
            self.recalcs += 1
            for tenant, r in self.ema.items():
                d = self.desired.get(tenant, self.k)
                if r >= self.spec.grow_above:
                    d = min(self.max_k, d + 1)
                elif r <= self.spec.shrink_below:
                    d = max(1, d - 1)
                if d == 1 and self.spec.reprobe_interval > 0:
                    parked = self._parked.get(tenant, 0) + 1
                    if parked >= self.spec.reprobe_interval:
                        d = min(2, self.max_k)
                        self.reprobes += 1
                        parked = 0
                    self._parked[tenant] = parked
                else:
                    self._parked[tenant] = 0
                self.desired[tenant] = d
            self.k = min(self.desired.values())
        return self.k

    def forget(self, tenant: str) -> None:
        """Drop a departed tenant's record (migration / completion) so it
        stops constraining the batch-wide minimum."""
        self.ema.pop(tenant, None)
        self.desired.pop(tenant, None)
        self._parked.pop(tenant, None)
        if self.desired:
            self.k = min(self.desired.values())
