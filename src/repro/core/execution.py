"""Unified execution-policy layer (paper §9.2 as the default execution path).

The paper's guidance is *contextual*: FP8 wins only above an occupancy
threshold (§5), queue concurrency collapses fairness past 4–8 streams (§6),
and 2:4 sparsity is break-even in isolation but pays under memory-bound /
multi-tenant execution (§7). This module turns that guidance into one
dispatch seam:

* :class:`ExecutionPolicy` — precision × sparsity × backend × block shapes
  × stream budget, the single value threaded through models, runtime loops,
  launchers, and benchmarks.
* :func:`matmul` — the dispatcher every dense/FP8/2:4 GEMM routes through,
  resolving against the :mod:`repro.kernels.registry` backends.
* :func:`resolve_policy` — consults :class:`~repro.core.concurrency.
  OccupancyAdvisor` with the workload's grid-tile fill at trace time and
  returns the policy the paper would pick (precision demotion below the
  FP8 occupancy threshold, sparsity on for multi-tenant/memory-bound,
  stream caps for latency-sensitive work).
* :class:`BlockShapeCache` — (M, K, N, dtype)-keyed block-shape autotune
  cache, seeded from the Table-3 tile-latency findings and refinable from
  measured ``benchmarks/table3_tile_latency.py`` records.

Echoing AMD's partitioning guide, selection is *explicit placement*, not a
single-pool default: callers say what they know (shapes, tenancy, latency
sensitivity) and the policy layer picks the execution mode.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import concurrency as cc
from repro.kernels import registry

PRECISIONS = ("bf16", "fp8")
SPARSITIES = ("dense", "sparse24")

# MXU tile edge: one unit of TPU grid parallelism (the wavefront analogue).
MXU_TILE = 128


# ---------------------------------------------------------------------------
# Packed 2:4 weight (serving representation, consumed by backend.sparse24)
# ---------------------------------------------------------------------------

class PackedWeight(NamedTuple):
    """2:4-compressed linear weight: values (K/2, N) + meta (K/8, N) uint8."""
    values: jax.Array
    meta: jax.Array

    @property
    def k(self) -> int:
        return self.values.shape[0] * 2

    @property
    def n(self) -> int:
        return self.values.shape[1]


def pack_weight(w: jax.Array) -> PackedWeight:
    from repro.core import sparsity as sp
    vals, meta = sp.pack_24(sp.prune_24(w))
    return PackedWeight(vals, meta)


def pack_model_params(params):
    """Pre-pack every eligible linear weight to :class:`PackedWeight`.

    The serving form of a sparse24 policy: prune+pack **once** at session
    setup so decode streams packed bytes from HBM (the §7 bandwidth win),
    instead of re-pruning inside every jitted step. Eligible leaves are the
    ``dense()``-consumed projections (``w_*`` / ``out_proj``) with a
    packable contraction dim — 2-D weights and scan-stacked 3-D weights
    (packed per layer via vmap). Embeddings, the LM head, routers, norms,
    biases, and 4-D MoE expert stacks are left dense.
    """
    from repro.core import sparsity as sp

    def pack2d(w):
        vals, meta = sp.pack_24(sp.prune_24(w))
        return vals, meta

    def maybe(key: str, v):
        if isinstance(v, dict):
            return {k: maybe(k, vv) for k, vv in v.items()}
        if not (key.startswith("w_") or key == "out_proj"):
            return v
        if not hasattr(v, "ndim") or v.ndim not in (2, 3):
            return v
        if v.shape[-2] % 8 or not jnp.issubdtype(v.dtype, jnp.floating):
            return v
        vals, meta = pack2d(v) if v.ndim == 2 else jax.vmap(pack2d)(v)
        return PackedWeight(vals, meta)

    return {k: maybe(k, v) for k, v in params.items()}


# ---------------------------------------------------------------------------
# Policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How a matmul (and the workload around it) should execute.

    ``block_m/n/k`` of ``None`` defer to the autotune cache / kernel
    defaults. ``streams`` is the concurrency budget the policy resolver
    granted (consumed by serving / benchmark harnesses, not by ``matmul``).
    ``overlap`` gates whether work under this policy may be co-dispatched
    with other partitions' work by the :class:`OverlapPlanner` (serving
    honors it per partition; ``no_overlap`` in the spec string turns it
    off).
    """
    precision: str = "bf16"             # bf16 | fp8
    sparsity: str = "dense"             # dense | sparse24
    backend: str = "jnp"                # registry name
    block_m: Optional[int] = None
    block_n: Optional[int] = None
    block_k: Optional[int] = None
    streams: int = 1
    overlap: bool = True
    rationale: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.precision not in PRECISIONS:
            raise ValueError(f"precision {self.precision!r} not in "
                             f"{PRECISIONS}")
        if self.sparsity not in SPARSITIES:
            raise ValueError(f"sparsity {self.sparsity!r} not in "
                             f"{SPARSITIES}")

    @property
    def blocks(self) -> Dict[str, Optional[int]]:
        return {"bm": self.block_m, "bn": self.block_n, "bk": self.block_k}

    def spec(self) -> str:
        """Compact string form, parseable by :func:`parse_policy`."""
        return f"{self.precision}:{self.sparsity}:{self.backend}"

    def full_spec(self) -> str:
        """Round-trippable string form: :meth:`spec` plus block shapes and
        stream budget when set (the :class:`~repro.runtime.server.
        ServingSpec` serialization of a policy)."""
        parts = [self.spec()]
        if all(b is not None
               for b in (self.block_m, self.block_n, self.block_k)):
            parts.append(f"{self.block_m}x{self.block_n}x{self.block_k}")
        if self.streams != 1:
            parts.append(f"streams={self.streams}")
        if not self.overlap:
            parts.append("no_overlap")
        return ":".join(parts)

    def describe(self) -> str:
        base = self.spec() + (f" streams={self.streams}")
        if not self.overlap:
            base += " no_overlap"
        if self.rationale:
            base += "\n  - " + "\n  - ".join(self.rationale)
        return base


def parse_policy(spec: str, base: Optional[ExecutionPolicy] = None
                 ) -> ExecutionPolicy:
    """Parse ``"fp8:sparse24:pallas"``-style specs (parts in any order,
    any subset): precision, sparsity, backend name, ``NxNxN`` blocks,
    ``streams=N``, ``overlap``/``no_overlap``."""
    pol = base or ExecutionPolicy()
    updates: Dict[str, Any] = {}
    for tok in filter(None, (t.strip() for t in spec.split(":"))):
        if tok in PRECISIONS:
            updates["precision"] = tok
        elif tok in SPARSITIES:
            updates["sparsity"] = tok
        elif tok in registry.available_backends():
            updates["backend"] = tok
        elif tok.startswith("streams="):
            updates["streams"] = int(tok.split("=", 1)[1])
        elif tok in ("overlap", "no_overlap"):
            updates["overlap"] = tok == "overlap"
        elif "x" in tok:
            bm, bn, bk = (int(v) for v in tok.split("x"))
            updates.update(block_m=bm, block_n=bn, block_k=bk)
        else:
            raise ValueError(
                f"unrecognized policy token {tok!r} in {spec!r} (want one of "
                f"{PRECISIONS + SPARSITIES}, a backend "
                f"{registry.available_backends()}, MxNxK blocks, or "
                f"streams=N)")
    return dataclasses.replace(pol, **updates)


# Module-level defaults: benchmarks/launchers flip these once instead of
# threading a policy through every call site.
_default_policy: Optional[ExecutionPolicy] = None
_default_backend: str = "jnp"

# Partition-local policy scope. A multi-partition serving runtime runs
# *heterogeneous* policies side by side (a throughput partition on
# fp8/sparse24 while a latency partition stays bf16), so "the" default
# policy is context-dependent: while a partition's session executes, any
# consumer that would fall back to the ambient module default must see the
# partition-local policy instead. Context-var based so concurrently
# stepping partitions (threads) cannot leak scopes into each other.
_scope_policy: "contextvars.ContextVar[Optional[ExecutionPolicy]]" = \
    contextvars.ContextVar("repro_policy_scope", default=None)


@contextlib.contextmanager
def policy_scope(policy: Optional[ExecutionPolicy]):
    """Make ``policy`` the contextual default for the enclosed block.

    Precedence while active: explicit ``rt.policy`` > this scope > the
    module default (``set_default_policy``) > legacy derived switches.
    ``None`` is a no-op scope (inherit whatever is ambient)."""
    tok = _scope_policy.set(policy)
    try:
        yield policy
    finally:
        _scope_policy.reset(tok)


def get_scope_policy() -> Optional[ExecutionPolicy]:
    return _scope_policy.get()


def set_default_policy(policy: Optional[ExecutionPolicy]) -> None:
    global _default_policy
    _default_policy = policy


def get_default_policy() -> ExecutionPolicy:
    scoped = _scope_policy.get()
    if scoped is not None:
        return scoped
    return _default_policy if _default_policy is not None \
        else ExecutionPolicy(backend=_default_backend)


def set_default_backend(name: str) -> None:
    registry.get_backend(name)          # validate eagerly
    global _default_backend
    _default_backend = name


def default_backend() -> str:
    return _default_backend


def policy_from(cfg, rt) -> ExecutionPolicy:
    """Effective policy for a model call site.

    Precedence: explicit ``rt.policy`` > the partition-local
    :func:`policy_scope` > module default policy > derived from the legacy
    per-object switches (``cfg.precision``, ``cfg.sparsity_24``,
    ``rt.use_pallas``) + module default backend.
    """
    pol = getattr(rt, "policy", None)
    if pol is not None:
        return pol
    scoped = _scope_policy.get()
    if scoped is not None:
        return scoped
    if _default_policy is not None:
        return _default_policy
    return ExecutionPolicy(
        precision=cfg.precision,
        sparsity="sparse24" if cfg.sparsity_24 else "dense",
        backend="pallas" if rt.use_pallas else _default_backend)


def apply_policy(cfg, rt, policy: ExecutionPolicy):
    """Fold a policy back into (cfg, rt) so non-matmul consumers (param
    init, serving weight prep, logging) see consistent switches.

    ``rt.use_pallas`` is deliberately left alone: it additionally gates the
    flash-attention kernel, which is forward-only — the policy governs the
    (differentiable) matmul seam, so ``--backend pallas`` stays trainable.
    """
    cfg = dataclasses.replace(
        cfg, precision=policy.precision,
        sparsity_24=policy.sparsity == "sparse24")
    rt = dataclasses.replace(rt, policy=policy)
    return cfg, rt


# ---------------------------------------------------------------------------
# Block-shape autotune cache (Table 3: preferred tile is precision-dependent)
# ---------------------------------------------------------------------------

def _dtype_key(dtype) -> str:
    if isinstance(dtype, str):      # already a precision key ("fp8", ...)
        return dtype
    name = jnp.dtype(dtype).name
    return {"float8_e4m3fn": "fp8", "float8_e5m2": "fp8",
            "bfloat16": "bf16", "float32": "fp32"}.get(name, name)


class BlockShapeCache:
    """(M, K, N, dtype) → (bm, bn, bk) with best observed latency.

    Seeded with the Table-3 finding — larger tiles pay a per-issue latency
    premium and the preferred shape is precision-dependent (FP8 wants the
    deepest K block to amortize its occupancy threshold; bf16 peaks at the
    square MXU-native tile) — and refined by :meth:`record` whenever a
    harness measures a (shape, blocks) pair.
    """

    # Per-precision preferred blocks, from table3_tile_latency: the probe
    # shapes it sweeps are exactly the kernel-block candidates.
    TABLE3_PREFERRED: Dict[str, Tuple[int, int, int]] = {
        "fp8": (256, 256, 512),
        "bf16": (256, 256, 256),
        "fp32": (128, 128, 256),
    }
    # The Table-3 probe grid itself (m, n, k): candidates for autotuning.
    TABLE3_SHAPES: Tuple[Tuple[int, int, int], ...] = (
        (128, 128, 128), (256, 256, 128), (128, 128, 256), (256, 256, 256))

    def __init__(self, seed: bool = True):
        self._best: Dict[Tuple[int, int, int, str],
                         Tuple[Tuple[int, int, int], float]] = {}
        if seed:
            self.seed_from_table3()

    def seed_from_table3(self) -> None:
        for prec, blocks in self.TABLE3_PREFERRED.items():
            for (m, n, k) in self.TABLE3_SHAPES:
                bm, bn, bk = (min(b, d) for b, d in zip(blocks, (m, n, k)))
                self._best[(m, k, n, prec)] = ((bm, bn, bk), math.inf)

    def record(self, m: int, k: int, n: int, dtype,
               blocks: Tuple[int, int, int], seconds: float) -> None:
        key = (m, k, n, _dtype_key(dtype))
        cur = self._best.get(key)
        if cur is None or seconds < cur[1]:
            self._best[key] = (tuple(blocks), seconds)

    def lookup(self, m: int, k: int, n: int, dtype
               ) -> Optional[Tuple[Optional[int], ...]]:
        prec = _dtype_key(dtype)
        hit = self._best.get((m, k, n, prec))
        if hit is not None:
            return hit[0]
        pref = self.TABLE3_PREFERRED.get(prec)
        if pref is None:
            return None
        # Clamp the precision-preferred blocks to the problem — but a dim
        # below MXU-lane granularity gets no hint (None → kernel default):
        # the policy's blocks are stamped onto every GEMM of the workload,
        # and a sub-8 hint from one tiny dim (e.g. decode slots) would
        # otherwise force every matmul off the kernel path.
        clamped = tuple(min(b, d) for b, d in zip(pref, (m, n, k)))
        return tuple((c if c >= 8 else None) for c in clamped)

    def entries(self) -> Dict[Tuple[int, int, int, str],
                              Tuple[Tuple[int, int, int], float]]:
        """Snapshot of {(m, k, n, prec): (blocks, best seconds)} — the
        serialization surface for :mod:`repro.core.autotune`."""
        return dict(self._best)

    def __len__(self) -> int:
        return len(self._best)


BLOCK_CACHE = BlockShapeCache()


# Precisions the block-evidence ingestion paths understand (dtype-mapped).
SWEEP_DTYPES = {"fp8": jnp.float8_e4m3fn, "bf16": jnp.bfloat16,
                "fp16": jnp.float16, "fp32": jnp.float32}


def parse_blocksweep_name(name: str
                          ) -> Optional[Tuple[int, int, int, str,
                                              Tuple[int, int, int]]]:
    """Parse a ``blocksweep/{prec}/{m}x{n}x{k}/{bm}x{bn}x{bk}`` record
    name into ``(m, n, k, prec, (bm, bn, bk))``; None if it isn't one or
    names a precision outside :data:`SWEEP_DTYPES`. The single parser for
    both ingestion paths (:func:`seed_cache_from_records` and
    :meth:`repro.core.autotune.AutotuneStore.add_records`), so they can't
    drift on format or accepted precisions."""
    parts = name.split("/")
    if len(parts) != 4 or parts[0] != "blocksweep" \
            or parts[1] not in SWEEP_DTYPES:
        return None
    try:
        m, n, k = (int(v) for v in parts[2].split("x"))
        blocks = tuple(int(v) for v in parts[3].split("x"))
    except ValueError:
        return None
    if len(blocks) != 3:
        return None
    return m, n, k, parts[1], blocks


def parse_pagedsweep_name(name: str
                          ) -> Optional[Tuple[int, int, int, str,
                                              Tuple[int, int, int]]]:
    """Parse a ``pagedsweep/{prec}/{m}x{n}x{k}/{bm}x{bn}x{bk}`` record
    name (the paged flash-decode tiling sweep,
    :func:`repro.kernels.paged_attention.sweep_paged_tilings`) into
    ``(m, n, k, prec, (bm, bn, bk))`` — m = query rows (slots), n = total
    KV length, k = head_dim, blocks = (1, page_size, head_dim). Same
    shape grammar as :func:`parse_blocksweep_name` so the Table-3
    evidence path ingests both."""
    parts = name.split("/")
    if len(parts) != 4 or parts[0] != "pagedsweep" \
            or parts[1] not in SWEEP_DTYPES:
        return None
    try:
        m, n, k = (int(v) for v in parts[2].split("x"))
        blocks = tuple(int(v) for v in parts[3].split("x"))
    except ValueError:
        return None
    if len(blocks) != 3:
        return None
    return m, n, k, parts[1], blocks


def seed_cache_from_records(records: Sequence[Any],
                            cache: Optional[BlockShapeCache] = None) -> int:
    """Ingest probe Records into the block cache; returns how many were
    folded in.

    ``latency/{prec}/{m}x{n}x{k}`` rows (the shape probe) keep the
    precision-preferred blocks clamped to the shape — the probe measures
    per-shape latency, not a block sweep, and fabricating a block choice a
    measurement never exercised would silently override the Table-3
    seeding. ``blocksweep/{prec}/{m}x{n}x{k}/{bm}x{bn}x{bk}`` rows (the
    tiling sweep) carry the blocks that *were* measured, so the cache's
    per-key best-latency rule promotes the sweep's winning tiling.
    """
    # NOT `cache or BLOCK_CACHE`: an empty cache is falsy (len 0) and
    # would silently redirect the caller's entries to the global cache
    cache = cache if cache is not None else BLOCK_CACHE
    n_in = 0
    for r in records:
        sweep = parse_blocksweep_name(r.name)
        if sweep is not None:
            m, n, k, prec, blocks = sweep
            cache.record(m, k, n, SWEEP_DTYPES[prec], blocks,
                         r.us_per_call * 1e-6)
            n_in += 1
            continue
        parts = r.name.split("/")
        if len(parts) != 3 or parts[0] != "latency":
            continue
        prec = parts[1]
        m, n, k = (int(v) for v in parts[2].split("x"))
        dtype = SWEEP_DTYPES.get(prec)
        pref = BlockShapeCache.TABLE3_PREFERRED.get(prec)
        if dtype is None or pref is None:
            continue
        blocks = tuple(min(b, d) for b, d in zip(pref, (m, n, k)))
        cache.record(m, k, n, dtype, blocks, r.us_per_call * 1e-6)
        n_in += 1
    return n_in


# ---------------------------------------------------------------------------
# Policy resolver (OccupancyAdvisor at trace time)
# ---------------------------------------------------------------------------

def grid_tiles(m: int, n: int, tile: int = MXU_TILE) -> int:
    """MXU-tile fill of an (M, N) output — the TPU 'active wavefronts'."""
    return max(1, -(-m // tile)) * max(1, -(-n // tile))


# Calibrated advisor installed by core/autotune.install(): when set,
# resolve_policy decides from *measured* thresholds instead of the
# Table-3/§9.2 constants.
_default_advisor: Optional[cc.OccupancyAdvisor] = None


def set_default_advisor(advisor: Optional[cc.OccupancyAdvisor]) -> None:
    global _default_advisor
    _default_advisor = advisor


def get_default_advisor() -> cc.OccupancyAdvisor:
    return _default_advisor if _default_advisor is not None \
        else cc.OccupancyAdvisor()


def _ambient_tracer():
    from repro.runtime import telemetry
    return telemetry.get_tracer()


def resolve_policy(m: int, k: int, n: int, *,
                   precision: str = "fp8",
                   backend: Optional[str] = None,
                   latency_sensitive: bool = False,
                   tenants: int = 1,
                   streams: Optional[int] = None,
                   advisor: Optional[cc.OccupancyAdvisor] = None,
                   cache: Optional[BlockShapeCache] = None,
                   tracer=None) -> ExecutionPolicy:
    """Pick the execution policy the paper's §9.2 rules would pick.

    ``(m, k, n)`` is the dominant GEMM of the workload (tokens × d_model ×
    d_ff for an LLM step); the advisor sees its grid-tile fill and may
    demote FP8 below the occupancy threshold, enable/disable 2:4, and cap
    the stream count. Explicit ``backend`` wins; otherwise Pallas is chosen
    whenever the resolved policy needs a technique only the kernels deliver
    (packed 2:4), else the module default.

    With no explicit ``advisor``, the module default applies — a
    *calibrated* advisor once :func:`repro.core.autotune.install` has
    loaded a measured artifact, the Table-3-constant one otherwise. The
    decision is recorded to ``tracer`` (or the ambient telemetry tracer).
    """
    advisor = advisor or get_default_advisor()
    profile = cc.WorkloadProfile(
        precision=precision,
        grid_tiles=grid_tiles(m, n),
        latency_sensitive=latency_sensitive,
        concurrent_tenants=tenants)
    advice = advisor.advise(profile)

    sparsity = "sparse24" if advice.use_sparsity and k % 8 == 0 else "dense"
    chosen_backend = backend if backend is not None else (
        "pallas_sparse24" if sparsity == "sparse24"
        and _default_backend.startswith("pallas") else _default_backend)
    registry.get_backend(chosen_backend)

    dtype = jnp.float8_e4m3fn if advice.suggested_precision == "fp8" \
        else jnp.bfloat16
    blocks = (cache if cache is not None else BLOCK_CACHE).lookup(
        m, k, n, dtype) or (None,) * 3

    n_streams = advice.max_streams if streams is None \
        else min(streams, advice.max_streams)
    pol = ExecutionPolicy(
        precision=advice.suggested_precision,
        sparsity=sparsity,
        backend=chosen_backend,
        block_m=blocks[0], block_n=blocks[1], block_k=blocks[2],
        streams=max(1, n_streams),
        rationale=tuple(advice.rationale))
    tr = tracer if tracer is not None else _ambient_tracer()
    if tr is not None:
        tr.record_resolve(m, k, n, policy=pol.spec(),
                          precision=pol.precision, backend=pol.backend,
                          fill=profile.grid_tiles / advisor.n_cores,
                          calibrated=advisor.calibrated,
                          streams=pol.streams)
    return pol


# ---------------------------------------------------------------------------
# The dispatcher
# ---------------------------------------------------------------------------

def matmul(x: jax.Array, w, policy: Optional[ExecutionPolicy] = None, *,
           out_dtype=jnp.bfloat16, tracer=None) -> jax.Array:
    """``x @ w`` through the policy's backend.

    ``w`` is a dense (K, N) array or a :class:`PackedWeight`; leading dims
    of ``x`` are preserved. FP8 applies only to 2-D dense weights (batched
    operands keep their native path, matching the per-call-site behavior
    this layer replaced).

    When a ``tracer`` is given (or an ambient telemetry tracer is
    installed), the dispatch is recorded as a trace-time event — op kind,
    (M, K, N), policy, backend — feeding the observatory's occupancy
    histogram and per-shape accounting. Events fire at trace time (once
    per jit specialization), not per executed step.
    """
    pol = policy or get_default_policy()
    be = registry.get_backend(pol.backend)
    packed = isinstance(w, PackedWeight)
    tr = tracer if tracer is not None else _ambient_tracer()
    if tr is not None:
        kk, nn = (w.k, w.n) if packed else (w.shape[-2], w.shape[-1])
        mm = 1
        for d in x.shape[:-1]:
            mm *= int(d)
        tr.record_matmul(mm, int(kk), int(nn),
                         precision=pol.precision, backend=pol.backend,
                         policy=pol.spec(),
                         op="sparse24" if packed else
                         ("fp8" if pol.precision == "fp8"
                          and w.ndim == 2 else "dense"))
    if packed:
        return be.sparse24(x, w.values, w.meta, out_dtype=out_dtype,
                           **pol.blocks)
    if pol.precision == "fp8" and w.ndim == 2:
        return be.fp8(x, w, out_dtype=out_dtype, **pol.blocks)
    return be.dense(x, w, out_dtype=out_dtype, **pol.blocks)


def raw_matmul(a: jax.Array, b: jax.Array, *,
               backend: Optional[str] = None,
               out_dtype=jnp.float32) -> jax.Array:
    """Benchmark-facing dispatch on *already-cast* operands: fp8 operands
    go through the pre-quantized GEMM entry (unit scales), everything else
    through ``dense`` — so one ``--backend`` flag re-targets every
    characterization sweep."""
    be = registry.get_backend(backend or get_default_policy().backend)
    is_fp8 = a.dtype in (jnp.float8_e4m3fn, jnp.float8_e5m2)
    tr = _ambient_tracer()
    if tr is not None:
        tr.record_matmul(int(a.shape[0]), int(a.shape[-1]),
                         int(b.shape[-1]),
                         precision=_dtype_key(a.dtype),
                         backend=backend or get_default_policy().backend,
                         op="fp8_qdot" if is_fp8 else "dense")
    if is_fp8:
        return be.fp8_qdot(a, b, 1.0, 1.0, out_dtype=out_dtype)
    return be.dense(a, b, out_dtype=out_dtype)


def dispatch_matmul(x: jax.Array, w,
                    policy: Optional[ExecutionPolicy] = None, *,
                    out_dtype=jnp.bfloat16, lane=None, overlap_group=-1,
                    tracer=None) -> "cc.LaneHandle":
    """Async form of :func:`matmul`: enqueue the GEMM through the policy's
    backend :meth:`~repro.kernels.registry.MatmulBackend.dispatch` entry
    and return a joinable :class:`~repro.core.concurrency.LaneHandle`
    instead of a blocked-on array. Same routing as :func:`matmul`
    (PackedWeight → sparse24, fp8 2-D dense → fp8, else dense); trace-time
    telemetry carries the lane and overlap-group so the planner's pairing
    decisions are attributable."""
    pol = policy or get_default_policy()
    be = registry.get_backend(pol.backend)
    packed = isinstance(w, PackedWeight)
    tr = tracer if tracer is not None else _ambient_tracer()
    if tr is not None:
        kk, nn = (w.k, w.n) if packed else (w.shape[-2], w.shape[-1])
        mm = 1
        for d in x.shape[:-1]:
            mm *= int(d)
        tr.record_matmul(mm, int(kk), int(nn),
                         precision=pol.precision, backend=pol.backend,
                         policy=pol.spec(),
                         lane=getattr(lane, "name", ""),
                         overlap_group=overlap_group,
                         op="sparse24" if packed else
                         ("fp8" if pol.precision == "fp8"
                          and w.ndim == 2 else "dense"))
    if packed:
        return be.dispatch("sparse24", x, w.values, w.meta, lane=lane,
                           overlap_group=overlap_group,
                           out_dtype=out_dtype, **pol.blocks)
    if pol.precision == "fp8" and w.ndim == 2:
        return be.dispatch("fp8", x, w, lane=lane,
                           overlap_group=overlap_group,
                           out_dtype=out_dtype, **pol.blocks)
    return be.dispatch("dense", x, w, lane=lane,
                       overlap_group=overlap_group,
                       out_dtype=out_dtype, **pol.blocks)


# ---------------------------------------------------------------------------
# Overlap planning (measured online pairing — AsyncSparse / paper §6)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OverlapCandidate:
    """One unit of dispatchable work the planner may co-schedule.

    ``ema_s`` is the Tracer's measured per-shape latency EMA for the
    work's dominant GEMM (``None`` = never measured → stays serial this
    round); ``allowed`` carries the owning policy's ``overlap`` gate."""
    index: int
    sparsity: str = "dense"
    shape: Optional[Tuple[int, int, int, str]] = None
    ema_s: Optional[float] = None
    allowed: bool = True


@dataclasses.dataclass
class OverlapPlan:
    """The planner's verdict for one dispatch round: ``groups`` are tuples
    of candidate indices to co-dispatch (one overlap-group id each);
    ``serial`` indices run alone. Every candidate index appears exactly
    once across the two."""
    groups: Tuple[Tuple[int, ...], ...]
    serial: Tuple[int, ...]

    @property
    def n_overlapped(self) -> int:
        return sum(len(g) for g in self.groups)


class OverlapPlanner:
    """Measured online pairing of sparse24/dense work for lane overlap.

    The paper characterizes ACE concurrency *offline* (fig4/fig13:
    contention is shape- and pairing-dependent, not uniform); AsyncSparse
    shows sparse matmul winning specifically on asynchronous execution.
    This planner schedules that trade *online*: work is dispatched serial
    until the Tracer has a measured latency EMA for its shape, then
    sparse24 candidates are paired with the dense candidate of closest
    measured latency — a balanced pair overlaps fully, while a lopsided
    one (ratio above ``max_imbalance``) would just serialize behind its
    slow member, so it stays serial. Leftover same-kind candidates are
    paired by adjacent measured latency when ``pair_homogeneous`` (two
    dense partitions still overlap host work with device work).
    """

    def __init__(self, *, max_imbalance: float = 8.0,
                 pair_homogeneous: bool = True):
        if max_imbalance < 1.0:
            raise ValueError("max_imbalance must be >= 1.0")
        self.max_imbalance = max_imbalance
        self.pair_homogeneous = pair_homogeneous

    def _ratio(self, a: OverlapCandidate, b: OverlapCandidate) -> float:
        hi = max(a.ema_s, b.ema_s)
        lo = max(min(a.ema_s, b.ema_s), 1e-12)
        return hi / lo

    def candidate(self, index: int, *, sparsity: str = "dense",
                  shape: Optional[Tuple[int, int, int, str]] = None,
                  tracer=None, allowed: bool = True) -> OverlapCandidate:
        """Build a candidate, looking its shape's measured EMA up in the
        tracer (``None`` EMA when unmeasured — "measure first, overlap
        second")."""
        ema = None
        if tracer is not None and shape is not None:
            ema = tracer.shape_latency_ema().get(tuple(shape))
        return OverlapCandidate(index=index, sparsity=sparsity,
                                shape=shape, ema_s=ema, allowed=allowed)

    def plan(self, candidates: Sequence[OverlapCandidate]) -> OverlapPlan:
        serial = [c.index for c in candidates
                  if not c.allowed or c.ema_s is None]
        live = [c for c in candidates if c.allowed and c.ema_s is not None]
        sparse = [c for c in live if c.sparsity == "sparse24"]
        dense = [c for c in live if c.sparsity != "sparse24"]
        groups = []
        used = set()
        # 1) each sparse24 candidate takes the closest-latency dense one
        for s in sparse:
            best, best_ratio = None, None
            for d in dense:
                if d.index in used:
                    continue
                ratio = self._ratio(s, d)
                if ratio > self.max_imbalance:
                    continue
                if best_ratio is None or ratio < best_ratio:
                    best, best_ratio = d, ratio
            if best is not None:
                used.add(s.index)
                used.add(best.index)
                groups.append((s.index, best.index))
        # 2) leftovers pair by adjacent measured latency
        left = sorted((c for c in live if c.index not in used),
                      key=lambda c: (c.ema_s, c.index))
        if self.pair_homogeneous:
            i = 0
            while i + 1 < len(left):
                a, b = left[i], left[i + 1]
                if self._ratio(a, b) <= self.max_imbalance:
                    groups.append((a.index, b.index))
                    used.add(a.index)
                    used.add(b.index)
                    i += 2
                else:
                    i += 1
        serial.extend(c.index for c in left if c.index not in used)
        return OverlapPlan(groups=tuple(tuple(g) for g in groups),
                           serial=tuple(sorted(serial)))
