"""Concurrent-execution layer — the ACE analogue on TPU (paper §6).

MI300A exposes hardware ACE queues that time/space-share one GPU. A TPU
chip runs one program at a time, so the framework provides the two
TPU-idiomatic concurrency mechanisms and instruments both with the paper's
metrics (overlap efficiency, fairness, per-stream CV):

* ``run_async_dispatch``  — one device (set), N workloads enqueued through
  JAX's runahead queue: time-multiplexing, the moral equivalent of N HSA
  queues feeding one ACE. Aggregate throughput rises; per-stream latency
  becomes contention-dependent — the paper's fairness collapse reproduces
  here.
* ``run_spatial``         — N disjoint device subsets, one workload each:
  space-multiplexing (sub-mesh multi-tenancy). TPU can give what MI300A
  cannot: *hard isolation* (no shared L2/LDS), at the cost of peak
  per-stream throughput.

``OccupancyAdvisor`` encodes the paper's §9.2 guidance as executable
policy (used by the serving layer and the examples).
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

# TPU-class table value (paper Table 1 adaptation): used whenever the
# runtime can't report a real accelerator core count (CPU containers).
DEFAULT_N_CORES = 256


def detect_core_count(default: int = DEFAULT_N_CORES) -> int:
    """Grid-parallelism capacity of the attached accelerator(s).

    Precedence: ``REPRO_N_CORES`` env override > summed per-device core
    count from ``jax.devices()`` (accelerators only) > ``default``. CPU
    devices report no meaningful MXU-slot count, so a CPU-only container
    keeps the TPU-class table value — test and CI behavior is stable.
    """
    env = os.environ.get("REPRO_N_CORES")
    if env:
        try:
            val = int(env)
        except ValueError:
            warnings.warn(
                f"REPRO_N_CORES={env!r} is not an integer; ignoring the "
                f"override and falling back to detection/default",
                RuntimeWarning, stacklevel=2)
        else:
            if val > 0:
                return val
            warnings.warn(
                f"REPRO_N_CORES={env!r} is not a positive core count; "
                f"ignoring the override and falling back to "
                f"detection/default",
                RuntimeWarning, stacklevel=2)
    try:
        devices = jax.devices()
    except Exception:  # noqa: BLE001 — no backend at all
        return default
    total = 0
    reported = False
    for d in devices:
        if getattr(d, "platform", "cpu") == "cpu":
            return default
        per = getattr(d, "num_cores", None) or getattr(d, "core_count", None)
        if per:
            reported = True
            total += int(per)
    # Accelerators that expose no core-count attribute (TPU devices often
    # don't) keep the table default: a device *count* of 1-8 is not a
    # grid-parallelism capacity, and fill-denominated thresholds scaled
    # by it would be meaningless.
    return total if reported else default


# ---------------------------------------------------------------------------
# Metrics (paper §4.2)
# ---------------------------------------------------------------------------

def fairness_raw(times: Sequence[float]) -> float:
    """Unclamped 1 - (t_max - t_min)/t_mean ∈ (-inf, 1]. Diagnostic only:
    below 0 the spread exceeds the mean and the magnitude is not
    interpretable as a fairness level."""
    t = np.asarray(times, dtype=np.float64)
    if t.size == 0 or t.mean() == 0:
        return 1.0
    return float(1.0 - (t.max() - t.min()) / t.mean())


def fairness(times: Sequence[float]) -> float:
    """1 - (t_max - t_min)/t_mean clamped to [0, 1].

    Paper convention: the fairness index is reported in [0, 1] (Fig 5:
    0.016–0.138 at 8 streams), 1.0 = perfectly balanced, 0.0 = fully
    collapsed. The raw expression goes arbitrarily negative for skewed
    streams (spread > mean), which is meaningless as a *level* — use
    :func:`fairness_raw` when the unbounded value is wanted."""
    return max(0.0, fairness_raw(times))


def fairness_min_max(times: Sequence[float]) -> float:
    """min/max per-stream time ratio (paper §7.2 variant); 1.0 = balanced."""
    t = np.asarray(times, dtype=np.float64)
    if t.size == 0 or t.max() == 0:
        return 1.0
    return float(t.min() / t.max())


def cv(times: Sequence[float]) -> float:
    t = np.asarray(times, dtype=np.float64)
    if t.size == 0 or t.mean() == 0:
        return 0.0
    return float(t.std() / t.mean())


def latency_percentiles(times: Sequence[float],
                        ps: Sequence[int] = (50, 99)) -> Dict[str, float]:
    """{"p50": ..., "p99": ...} over a latency sample (paper Fig 8's
    per-stream distribution view); zeros when the sample is empty."""
    t = np.asarray(times, dtype=np.float64)
    if t.size == 0:
        return {f"p{p}": 0.0 for p in ps}
    return {f"p{p}": float(np.percentile(t, p)) for p in ps}


def overlap_efficiency(serial_total: float, concurrent_total: float,
                       n_streams: int) -> float:
    """Fraction of ideal overlap achieved: 1.0 when concurrent time equals
    serial/n (perfect overlap), 0.0 when no faster than serial."""
    if serial_total <= 0 or n_streams <= 1:
        return 0.0
    ideal = serial_total / n_streams
    if concurrent_total <= ideal:
        return 1.0
    return float((serial_total - concurrent_total)
                 / (serial_total - ideal))


@dataclasses.dataclass
class StreamReport:
    n_streams: int
    mode: str                        # serial | async | spatial
    per_stream_s: List[float]
    wall_s: float
    serial_wall_s: float
    speedup: float
    overlap_efficiency: float
    fairness: float
    fairness_min_max: float
    cv: float
    # How per_stream_s was measured. "dispatch_to_ready" (the lane-handle
    # clock: each stream's time runs from ITS OWN dispatch to its result
    # being ready) is the only mode produced since the lane refactor.
    timing: str = "dispatch_to_ready"

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        for k, v in d.items():
            if isinstance(v, float):
                d[k] = round(v, 9)
            elif isinstance(v, list):
                d[k] = [round(x, 9) if isinstance(x, float) else x
                        for x in v]
        # keep numbers comparable across the timing change: pre-lane
        # reports measured every stream from one global t0 (so a late
        # stream's time included every earlier stream's completion wait)
        d["legacy_timing"] = ("pre-lane per_stream_s ran from a global t0"
                              " — not per-dispatch")
        return d

    def to_record(self, name: str, **extra: Any):
        """Serialize as a :class:`repro.core.characterization.Record` —
        the one schema fig4/fig5 CSVs, ``dump_records``/``load_records``
        and ``AutotuneStore.add_records`` all consume. ``extra`` keys are
        merged into ``derived`` (e.g. ``precision=...``, ``streams=...``)."""
        from repro.core.characterization import Record
        derived = dict(self.to_dict())
        derived.update(extra)
        return Record(name=name, us_per_call=self.wall_s * 1e6,
                      derived=derived)


# ---------------------------------------------------------------------------
# Execution lanes (dispatch-and-join seam)
# ---------------------------------------------------------------------------

def _block(x):
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, x)


@dataclasses.dataclass
class LaneHandle:
    """A joinable in-flight dispatch.

    ``result`` holds whatever the thunk returned — with JAX async dispatch
    that's future-backed arrays already enqueued on the device, not yet
    blocked on. ``join()`` blocks until ready and stamps ``ready_t``;
    ``dispatch_to_ready_s`` is then the stream's own dispatch→ready time
    (NOT measured from some global start, so it excludes other streams'
    completion waits when dispatch outpaces execution)."""
    lane: str
    label: str
    result: Any
    dispatch_t: float
    overlap_group: int = -1
    ready_t: Optional[float] = None

    def join(self) -> Any:
        if self.ready_t is None:
            _block(self.result)
            self.ready_t = time.perf_counter()
        return self.result

    @property
    def done(self) -> bool:
        return self.ready_t is not None

    @property
    def dispatch_to_ready_s(self) -> float:
        end = self.ready_t if self.ready_t is not None else time.perf_counter()
        return max(0.0, end - self.dispatch_t)


class ExecutionLane:
    """A named async dispatch context — the ACE-queue analogue the rest of
    the stack programs against.

    ``dispatch(thunk)`` calls the thunk immediately (with JAX that enqueues
    the computation through the runahead queue and returns future arrays)
    and wraps the un-blocked result in a :class:`LaneHandle`. Callers join
    handles when — and only when — they need the values on the host, which
    is what lets two lanes' work genuinely overlap. A lane given a
    ``tracer`` (duck-typed ``repro.runtime.telemetry.Tracer``) records one
    ``dispatch`` event per dispatch so overlap decisions are attributable
    after the fact."""

    def __init__(self, name: str = "lane0", *, index: int = 0, tracer=None):
        self.name = name
        self.index = index
        self.tracer = tracer
        self.handles: List[LaneHandle] = []

    def dispatch(self, thunk: Callable[[], Any], *, label: str = "",
                 overlap_group: int = -1) -> LaneHandle:
        t0 = time.perf_counter()
        result = thunk()               # enqueued via JAX async dispatch
        h = LaneHandle(lane=self.name,
                       label=label or getattr(thunk, "__name__", "thunk"),
                       result=result, dispatch_t=t0,
                       overlap_group=overlap_group)
        self.handles.append(h)
        if self.tracer is not None:
            self.tracer.record("dispatch", lane=self.name,
                               overlap_group=overlap_group,
                               meta={"label": h.label})
        return h

    def join_all(self) -> List[Any]:
        return [h.join() for h in self.handles]

    def reset(self) -> None:
        self.handles.clear()

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"ExecutionLane({self.name!r}, index={self.index}, "
                f"inflight={sum(not h.done for h in self.handles)})")


# ---------------------------------------------------------------------------
# Stream runners (rebuilt on lanes)
# ---------------------------------------------------------------------------

def run_serial(thunks: Sequence[Callable[[], Any]],
               lane: Optional[ExecutionLane] = None) -> List[float]:
    """Execute each workload to completion before the next; returns
    per-stream durations."""
    lane = lane if lane is not None else ExecutionLane("serial")
    times = []
    for fn in thunks:
        h = lane.dispatch(fn)
        h.join()
        times.append(h.dispatch_to_ready_s)
    return times


def run_async_dispatch(thunks: Sequence[Callable[[], Any]],
                       lane: Optional[ExecutionLane] = None) -> List[float]:
    """Enqueue all workloads through the JAX dispatch queue, then join in
    dispatch order — the ACE multi-queue analogue. Returns each stream's
    own dispatch→ready time (see :class:`LaneHandle`): a late stream is no
    longer charged for earlier streams' completion waits, which the old
    global-t0 measurement did whenever dispatch outpaced execution."""
    lane = lane if lane is not None else ExecutionLane("async")
    handles = [lane.dispatch(fn) for fn in thunks]   # all enqueued
    times = []
    for h in handles:
        h.join()
        times.append(h.dispatch_to_ready_s)
    return times


def run_spatial(fns_and_args: Sequence[tuple], devices: Sequence) -> List[float]:
    """One workload per device (subset): spatial multi-tenancy.

    ``fns_and_args[i] = (jitted_fn_on_device_i, args)``; returns per-stream
    completion times from the common start."""
    t0 = time.perf_counter()
    results = [fn(*args) for fn, args in fns_and_args]
    times = []
    for r in results:
        _block(r)
        times.append(time.perf_counter() - t0)
    return times


def characterize_streams(make_thunk: Callable[[int], Callable[[], Any]],
                         n_streams: int, *, warmup: int = 1,
                         mode: str = "async", tracer=None) -> StreamReport:
    """Run the paper's Fig-4/5 experiment for one stream count.

    ``tracer`` (a :class:`repro.runtime.telemetry.Tracer`, duck-typed)
    receives one ``stream`` event per stream with its measured completion
    time plus a ``stream_report`` aggregate — the §6 observables feeding
    the online calibration loop."""
    thunks = [make_thunk(i) for i in range(n_streams)]
    # warm EVERY thunk: each stream may be a distinct jitted computation
    # (or a distinct shape), and any compilation left for the timed region
    # lands on the early streams and inflates their times.
    for _ in range(warmup):
        for fn in thunks:
            _block(fn())

    serial_times = run_serial(thunks)
    serial_total = sum(serial_times)

    t0 = time.perf_counter()
    if mode == "async":
        per_stream = run_async_dispatch(thunks)
    else:
        per_stream = run_serial(thunks)
    wall = time.perf_counter() - t0

    report = StreamReport(
        n_streams=n_streams,
        mode=mode,
        per_stream_s=per_stream,
        wall_s=wall,
        serial_wall_s=serial_total,
        speedup=serial_total / wall if wall > 0 else 0.0,
        overlap_efficiency=overlap_efficiency(serial_total, wall, n_streams),
        fairness=fairness(per_stream),
        fairness_min_max=fairness_min_max(per_stream),
        cv=cv(per_stream),
    )
    if tracer is not None:
        for i, s in enumerate(per_stream):
            tracer.record_stream(i, s, mode=mode, n_streams=n_streams)
        tracer.record("stream_report", wall_s=wall, meta={
            "mode": mode, "n_streams": n_streams,
            "fairness": report.fairness, "cv": report.cv,
            "overlap_efficiency": report.overlap_efficiency})
    return report


# ---------------------------------------------------------------------------
# Occupancy advisor (paper §9.2 as executable policy)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkloadProfile:
    precision: str                  # fp8 | fp16 | bf16 | fp32
    grid_tiles: int                 # parallelism available (TPU: MXU tiles)
    latency_sensitive: bool = False
    concurrent_tenants: int = 1


@dataclasses.dataclass
class Advice:
    use_sparsity: bool
    max_streams: int
    suggested_precision: str
    batch_multiplier: int
    rationale: List[str]


class OccupancyAdvisor:
    """Paper §9.2 decision rules, re-based on the TPU adaptation:

    * FP8 needs ~2× the grid parallelism of bf16 to hide HBM latency
      (paper: 256+ wavefronts vs 192/128) — below the threshold, prefer
      bf16 or batch up.
    * concurrency: ≤4 streams for latency-sensitive (fairness > 0.5),
      6–8 for throughput; hard isolation → spatial sub-meshes.
    * sparsity: enable when the workload is memory-bound/multi-tenant
      (TPU: decode, small batch); disable for isolated compute-bound work.
    """

    # TPU v5e-class threshold: ~1 MXU tile per core with double-buffering.
    # These class constants are the *priors* (Table-3/§9.2 values); an
    # instance built by core/autotune carries measured ones instead.
    FP8_TILE_THRESHOLD = 2.0        # ×cores
    BF16_TILE_THRESHOLD = 1.0

    def __init__(self, n_cores: Optional[int] = None, *,
                 fp8_fill_target: Optional[float] = None,
                 demote_below_fill: Optional[float] = None,
                 calibrated: bool = False):
        self.n_cores = n_cores if n_cores is not None else detect_core_count()
        self.fp8_fill_target = self.FP8_TILE_THRESHOLD \
            if fp8_fill_target is None else float(fp8_fill_target)
        self.demote_below_fill = self.BF16_TILE_THRESHOLD \
            if demote_below_fill is None else float(demote_below_fill)
        self.calibrated = calibrated

    def advise(self, w: WorkloadProfile) -> Advice:
        rationale = []
        precision = w.precision
        batch_mult = 1
        src = "measured" if self.calibrated else "paper §9.2"
        fill = w.grid_tiles / self.n_cores
        if w.precision in ("fp8",) and fill < self.fp8_fill_target:
            if fill < self.demote_below_fill:
                precision = "bf16"
                rationale.append(
                    f"grid fill {fill:.2f}× cores < "
                    f"{self.demote_below_fill:g}"
                    f"× ({src}) needed for FP8 to hide HBM latency; bf16 "
                    "is faster at this occupancy ('FP16 at 128 wavefronts "
                    "outperforms underutilized FP8')")
            else:
                batch_mult = int(np.ceil(self.fp8_fill_target / fill))
                rationale.append(
                    f"batch ×{batch_mult} to reach FP8 occupancy threshold "
                    f"({src})")
        max_streams = 4 if w.latency_sensitive else 8
        if w.latency_sensitive and w.concurrent_tenants > 4:
            rationale.append(
                "latency-sensitive with >4 tenants: prefer spatial sub-mesh "
                "isolation over queue concurrency (fairness collapses at 8 "
                "streams: 0.016–0.138 in the paper)")
        use_sparsity = w.concurrent_tenants > 1 or w.latency_sensitive is False
        if w.concurrent_tenants == 1 and w.grid_tiles >= self.n_cores:
            use_sparsity = False
            rationale.append(
                "isolated compute-bound workload: 2:4 sparsity is break-even "
                "(paper §7.1) — disabled")
        else:
            rationale.append(
                "memory-bound/multi-tenant context: 2:4 packed weights cut "
                "HBM weight traffic (TPU adaptation of paper §7.2's "
                "concurrency-dependent win)")
        return Advice(use_sparsity=use_sparsity, max_streams=max_streams,
                      suggested_precision=precision,
                      batch_multiplier=batch_mult, rationale=rationale)
