"""Mixed-precision routing policies (paper §8.3 + §9.2).

The paper's mixed-precision case study shows FP8/FP16/FP32 stages have
different occupancy/batching sensitivities and should be scheduled
precision-aware. This module encodes that as a per-op-class policy object
the framework consults when building models and serving plans — the same
role Transformer-Engine recipes play, but explicit and testable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# Op classes, ordered roughly by numerical sensitivity (paper §9.2: keep
# precision-sensitive ops high while bulk GEMMs drop to FP8).
OP_CLASSES = (
    "router",        # MoE gate logits — f32 always (paper: precision-aware)
    "logits",        # LM head — f32 accumulation, high-precision softmax
    "norm",          # rms/layer norms — f32 statistics
    "attention_softmax",
    "qkv_proj",
    "attn_out_proj",
    "mlp",
    "expert_mlp",
    "ssm_recurrence",  # state accumulation — never FP8 (DESIGN.md §4)
)


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Maps op classes to compute dtypes + quantization choices."""
    name: str
    rules: Dict[str, str]        # op class -> "f32" | "bf16" | "fp8"
    grad_dtype: str = "e5m2"     # fp8 gradient format (range-wide)
    fwd_dtype: str = "e4m3"      # fp8 forward format (precision-narrow)

    def dtype_for(self, op_class: str) -> str:
        if op_class not in self.rules:
            raise KeyError(f"unknown op class {op_class!r}; "
                           f"known: {OP_CLASSES}")
        return self.rules[op_class]

    def uses_fp8(self) -> bool:
        return any(v == "fp8" for v in self.rules.values())


def _mk(name, **overrides) -> PrecisionPolicy:
    base = {
        "router": "f32",
        "logits": "f32",
        "norm": "f32",
        "attention_softmax": "f32",
        "qkv_proj": "bf16",
        "attn_out_proj": "bf16",
        "mlp": "bf16",
        "expert_mlp": "bf16",
        "ssm_recurrence": "f32",
    }
    base.update(overrides)
    return PrecisionPolicy(name=name, rules=base)


# The three deployment presets the paper's case studies correspond to:
BF16_BASELINE = _mk("bf16_baseline")
# paper-faithful FP8 recipe: all bulk GEMMs in FP8, sensitive ops high
FP8_TRAINING = _mk("fp8_training",
                   qkv_proj="fp8", attn_out_proj="fp8", mlp="fp8",
                   expert_mlp="fp8")
# serving: weights FP8 (+2:4-packable); softmax/logits still f32
FP8_SERVING = _mk("fp8_serving",
                  qkv_proj="fp8", attn_out_proj="fp8", mlp="fp8",
                  expert_mlp="fp8")

POLICIES = {p.name: p for p in (BF16_BASELINE, FP8_TRAINING, FP8_SERVING)}


def policy_for(precision: str, serving: bool = False) -> PrecisionPolicy:
    """Resolve an ArchConfig.precision string to a policy."""
    if precision == "fp8":
        return FP8_SERVING if serving else FP8_TRAINING
    return BF16_BASELINE


def validate(policy: PrecisionPolicy) -> None:
    """Invariants the paper's findings impose."""
    for op in ("router", "norm", "ssm_recurrence"):
        if policy.dtype_for(op) == "fp8":
            raise ValueError(
                f"{policy.name}: op class {op!r} must not run in FP8 "
                "(paper §9.2 / DESIGN.md §4 numerical-sensitivity rule)")
    if policy.grad_dtype not in ("e5m2", "bf16"):
        raise ValueError("gradients need range-wide formats (E5M2/bf16)")
