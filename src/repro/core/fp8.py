"""FP8 tensor-scaled matmul with delayed scaling (paper §5, §8.3).

Implements the OCP OFP8 formats the paper exercises on MI300A's MFMA units
(E4M3 "fp8" and E5M2 "bf8"), adapted to the TPU MXU contract:

* FP8 × FP8 operands with FP32 accumulation (``preferred_element_type``),
  mirroring ``V_MFMA_F32_..._FP8_FP8``.
* Per-tensor scaling with **delayed scaling**: the scale for step *t* is
  derived from a rolling amax history of the previous ``history`` steps
  (FP8-LM / Transformer-Engine recipe), so quantization is a static, cheap
  multiply at step time and the amax reduction happens off the critical path.
* A :class:`Fp8State` pytree threads per-tensor amax histories through the
  training step and is checkpointed with the model.

On TPU v5e the MXU upconverts FP8 inputs; on v6e+ the MXU consumes FP8
natively. Either way HBM traffic for weights/activations halves vs bf16 —
that (not the FLOP rate) is what moves the roofline for the memory-bound
cells (see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

E4M3 = jnp.float8_e4m3fn
E5M2 = jnp.float8_e5m2

# Max representable magnitudes (OCP OFP8).
E4M3_MAX = 448.0
E5M2_MAX = 57344.0

# Keep a safety margin so stochastic spikes don't saturate (TE default 0).
DEFAULT_MARGIN = 0.0


def fp8_max(dtype) -> float:
    if dtype == E4M3:
        return E4M3_MAX
    if dtype == E5M2:
        return E5M2_MAX
    raise ValueError(f"not an fp8 dtype: {dtype}")


# ---------------------------------------------------------------------------
# Scaling state
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TensorScale:
    """Delayed-scaling state for one logical tensor."""
    amax_history: jax.Array        # (history,) f32, rolling
    scale: jax.Array               # () f32 — quantization scale for *this* step

    @staticmethod
    def init(history: int = 16) -> "TensorScale":
        return TensorScale(
            amax_history=jnp.zeros((history,), jnp.float32),
            scale=jnp.ones((), jnp.float32),
        )


def update_scale(ts: TensorScale, new_amax: jax.Array,
                 dtype=E4M3, margin: float = DEFAULT_MARGIN) -> TensorScale:
    """Roll the amax history and derive next step's scale (delayed scaling)."""
    hist = jnp.concatenate([new_amax[None].astype(jnp.float32),
                            ts.amax_history[:-1]])
    amax = jnp.max(hist)
    fmax = fp8_max(dtype)
    # scale maps |x| <= amax onto the fp8 range; guard amax==0.
    scale = jnp.where(amax > 0, (fmax / (2.0 ** margin)) / amax, 1.0)
    return TensorScale(amax_history=hist, scale=scale.astype(jnp.float32))


def quantize(x: jax.Array, ts: TensorScale, dtype=E4M3) -> jax.Array:
    """Quantize with the (delayed) scale; saturating cast."""
    fmax = fp8_max(dtype)
    scaled = jnp.clip(x.astype(jnp.float32) * ts.scale, -fmax, fmax)
    return scaled.astype(dtype)


def dequantize_scale(ts: TensorScale) -> jax.Array:
    return 1.0 / ts.scale


def current_amax(x: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(x.astype(jnp.float32)))


# ---------------------------------------------------------------------------
# FP8 matmul primitive (jnp reference path; the Pallas kernel in
# kernels/fp8_matmul.py is the TPU drop-in)
# ---------------------------------------------------------------------------

def fp8_dot(x_q: jax.Array, w_q: jax.Array,
            x_inv_scale: jax.Array, w_inv_scale: jax.Array,
            out_dtype=jnp.bfloat16) -> jax.Array:
    """(…, K) fp8 × (K, N) fp8 → (…, N) with f32 accumulation, descaled."""
    acc = jax.lax.dot_general(
        x_q, w_q,
        dimension_numbers=(((x_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return (acc * (x_inv_scale * w_inv_scale)).astype(out_dtype)


def _saturate_cast(x32: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    fmax = fp8_max(dtype)
    return jnp.clip(x32 * scale, -fmax, fmax).astype(dtype)


def _quantized_dot(x_q, w_q, x_inv_scale, w_inv_scale, out_dtype, backend):
    """Route the pre-quantized forward GEMM through the backend registry
    (lazy import: the registry itself builds on this module)."""
    from repro.kernels.registry import get_backend
    return get_backend(backend).fp8_qdot(
        x_q, w_q, x_inv_scale, w_inv_scale, out_dtype=out_dtype)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def fp8_matmul(x: jax.Array, w: jax.Array,
               x_scale: jax.Array, w_scale: jax.Array,
               fwd_dtype=E4M3, grad_dtype=E5M2,
               backend: str = "jnp") -> jax.Array:
    """Differentiable tensor-scaled FP8 matmul.

    ``x_scale``/``w_scale`` are scalar (delayed) quantization scales.
    Forward operands use E4M3 (range-narrow, precise); gradients use E5M2
    (range-wide), matching the paper's fp8/bf8 MFMA operand pairs and the
    standard FP8 training recipe. ``backend`` names a registry backend for
    the forward GEMM; the backward dots stay on the jnp path (E5M2 grads
    need no kernel and must match cotangent dtypes exactly).
    """
    x_q = _saturate_cast(x.astype(jnp.float32), x_scale, fwd_dtype)
    w_q = _saturate_cast(w.astype(jnp.float32), w_scale, fwd_dtype)
    return _quantized_dot(x_q, w_q, 1.0 / x_scale, 1.0 / w_scale,
                          x.dtype, backend)


def _fp8_matmul_fwd(x, w, x_scale, w_scale, fwd_dtype, grad_dtype, backend):
    x_q = _saturate_cast(x.astype(jnp.float32), x_scale, fwd_dtype)
    w_q = _saturate_cast(w.astype(jnp.float32), w_scale, fwd_dtype)
    out = _quantized_dot(x_q, w_q, 1.0 / x_scale, 1.0 / w_scale,
                         x.dtype, backend)
    # zero-size dtype tokens so bwd can cast cotangents back to the primal
    # dtypes (dw must match w.dtype under jax.grad with bf16 params)
    x_tok = jnp.zeros((), x.dtype)
    w_tok = jnp.zeros((), w.dtype)
    return out, (x_q, w_q, x_scale, w_scale, x_tok, w_tok)


def _fp8_matmul_bwd(fwd_dtype, grad_dtype, backend, res, g):
    x_q, w_q, x_s, w_s, x_tok, w_tok = res
    # Gradient quantization: dynamic (current-tensor) scaling in E5M2.
    g32 = g.astype(jnp.float32)
    g_amax = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12)
    g_scale = fp8_max(grad_dtype) / g_amax
    g_q = _saturate_cast(g32, g_scale, grad_dtype)
    # dx = g @ w^T   (fp8 × fp8, f32 acc)
    dx = jax.lax.dot_general(
        g_q, w_q, (((g.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    dx = dx / (g_scale * w_s)
    # dw = x^T @ g  — contract all leading dims of x with those of g.
    lead = tuple(range(g.ndim - 1))
    dw = jax.lax.dot_general(
        x_q, g_q, ((lead, lead), ((), ())),
        preferred_element_type=jnp.float32)
    dw = dw / (g_scale * x_s)
    return (dx.astype(x_tok.dtype), dw.astype(w_tok.dtype),
            jnp.zeros_like(x_s), jnp.zeros_like(w_s))


fp8_matmul.defvjp(_fp8_matmul_fwd, _fp8_matmul_bwd)


# ---------------------------------------------------------------------------
# Module-level: an FP8 linear layer with threaded scaling state
# ---------------------------------------------------------------------------

def fp8_linear(x: jax.Array, w: jax.Array, state: Dict[str, TensorScale],
               name: str, history: int = 16,
               collect: Optional[Dict[str, jax.Array]] = None,
               backend: str = "jnp") -> jax.Array:
    """Linear layer in FP8 with delayed scaling.

    ``state[name + '/x']`` and ``state[name + '/w']`` are :class:`TensorScale`
    entries. When ``collect`` is given, current amaxes are recorded so the
    train step can produce the next-step state via :func:`fold_amaxes`.
    ``backend`` routes the forward GEMM through the named registry backend.
    """
    xs = state[f"{name}/x"]
    ws = state[f"{name}/w"]
    out = fp8_matmul(x, w, xs.scale, ws.scale, E4M3, E5M2, backend)
    if collect is not None:
        collect[f"{name}/x"] = current_amax(x)
        collect[f"{name}/w"] = current_amax(w)
    return out


def init_fp8_state(names, history: int = 16) -> Dict[str, TensorScale]:
    state: Dict[str, TensorScale] = {}
    for n in names:
        state[f"{n}/x"] = TensorScale.init(history)
        state[f"{n}/w"] = TensorScale.init(history)
    return state


def fold_amaxes(state: Dict[str, TensorScale],
                amaxes: Dict[str, jax.Array]) -> Dict[str, TensorScale]:
    """Produce next-step scaling state from this step's observed amaxes."""
    out = dict(state)
    for k, amax in amaxes.items():
        out[k] = update_scale(state[k], amax)
    return out


# ---------------------------------------------------------------------------
# Simple (stateless) dynamic-scaling quantized matmul — used by serving
# paths and benchmarks where no state threading is wanted.
# ---------------------------------------------------------------------------

def dynamic_fp8_matmul(x: jax.Array, w: jax.Array, dtype=E4M3,
                       out_dtype=jnp.bfloat16) -> jax.Array:
    fmax = fp8_max(dtype)
    xa = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12)
    wa = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32))), 1e-12)
    xs, wsc = fmax / xa, fmax / wa
    x_q = (x.astype(jnp.float32) * xs).astype(dtype)
    w_q = (w.astype(jnp.float32) * wsc).astype(dtype)
    return fp8_dot(x_q, w_q, 1.0 / xs, 1.0 / wsc, out_dtype=out_dtype)


def quantize_weight_static(w: jax.Array, dtype=E4M3) -> Tuple[jax.Array, jax.Array]:
    """Offline weight quantization for serving: returns (w_q, inv_scale)."""
    wa = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32))), 1e-12)
    s = fp8_max(dtype) / wa
    return (w.astype(jnp.float32) * s).astype(dtype), (1.0 / s).astype(jnp.float32)
