"""Paged serving-cache allocator (the vLLM-style block table, host side).

The dense serving cache pins ``batch_slots × max_len`` KV rows per layer
at session construction — capacity a tenant may never touch, and the unit
PR 5's live migration has to copy. This module owns the *logical* half of
the paged replacement: a pool of fixed-size pages (``page_size`` token
positions each) handed out from a free list, with a per-slot page table
mapping logical page index → physical page id. The *physical* half (the
pooled device arrays and the page-walking attention) lives in
:mod:`repro.models.transformer` / :mod:`repro.kernels.paged_attention`;
:class:`~repro.runtime.serve_loop.ServeSession` keeps the two in sync.

One page id is shared by every layer: physical page ``p`` names the same
``page_size`` rows in each layer's K, V and position pool, so the table is
per-slot, not per-layer. SSM/linear-attention state has no sequence axis;
the allocator accounts it as one fixed *state block* per occupied slot
(``state_block_tokens`` positions' worth of budget in the stats) while the
physical state stays slot-indexed — pooling a constant-size per-slot value
would buy no density.

Invariants the serving tests pin:
* a slot's table is always a logical *prefix* (lazy append, never holes);
* a freed page returns to the free list only after the session scrubbed
  its pool rows (k/v zeroed, pos ``-1``) — free-list reuse can never leak
  a previous tenant's KV;
* allocation failure raises :class:`PagesExhausted` (admission is
  *refused*, the session does not crash) — callers gate on
  :meth:`PageAllocator.can_alloc` first.

Utilization/fragmentation stats are cheap dict snapshots
(:meth:`PageAllocator.stats`) that the session forwards to its
:class:`~repro.runtime.telemetry.Tracer` as ``paging`` events.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

__all__ = ["PagesExhausted", "PageAllocator", "pages_for"]


class PagesExhausted(RuntimeError):
    """The pool has fewer free pages than the request needs. Admission
    paths treat this as back-pressure (queue the request), never as a
    crash."""


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` positions (ceil division)."""
    if n_tokens <= 0:
        return 0
    return -(-int(n_tokens) // int(page_size))


@dataclasses.dataclass
class SlotTable:
    """One slot's logical→physical page list (a strict prefix) plus its
    written-token count (for utilization accounting)."""
    pages: List[int] = dataclasses.field(default_factory=list)
    tokens: int = 0


class PageAllocator:
    """Free-list allocator over ``n_pages`` physical pages of
    ``page_size`` token positions, shared by every cache layer.

    ``max_pages_per_slot`` bounds each slot's table (``max_len //
    page_size`` in the session); :meth:`page_map` renders the tables as
    the dense ``(n_slots, max_pages_per_slot)`` int32 array (``-1`` =
    unallocated) the jitted decode step consumes.
    """

    def __init__(self, n_pages: int, page_size: int,
                 max_pages_per_slot: int, n_slots: int,
                 state_block_tokens: int = 0):
        if n_pages <= 0 or page_size <= 0:
            raise ValueError("n_pages and page_size must be positive")
        if max_pages_per_slot <= 0 or n_slots <= 0:
            raise ValueError("max_pages_per_slot and n_slots must be "
                             "positive")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.max_pages_per_slot = int(max_pages_per_slot)
        self.n_slots = int(n_slots)
        # SSM/linear-attention state accounted per occupied slot (token-
        # position equivalents; 0 for pure-attention stacks).
        self.state_block_tokens = int(state_block_tokens)
        # LIFO free list: a just-freed page is the next one handed out,
        # which is exactly the reuse pattern the no-stale-KV test attacks.
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._tables: List[SlotTable] = [SlotTable()
                                         for _ in range(self.n_slots)]
        # counters (monotonic; exposed via stats())
        self.alloc_count = 0
        self.free_count = 0
        self.extend_count = 0
        self.trim_count = 0
        self.oom_count = 0
        self.peak_pages_in_use = 0

    # -- queries -------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def occupied_slots(self) -> int:
        return sum(1 for t in self._tables if t.pages)

    def slot_pages(self, slot: int) -> List[int]:
        """The slot's physical page ids, logical order (a copy)."""
        return list(self._tables[slot].pages)

    def slot_tokens(self, slot: int) -> int:
        return self._tables[slot].tokens

    def pages_for(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.page_size)

    def can_alloc(self, n_pages: int) -> bool:
        return n_pages <= len(self._free)

    def can_admit_tokens(self, n_tokens: int) -> bool:
        """Free-page headroom check for admission: could a fresh slot hold
        ``n_tokens`` positions right now?"""
        need = self.pages_for(n_tokens)
        return need <= self.max_pages_per_slot and self.can_alloc(need)

    # -- mutation ------------------------------------------------------------
    def _take(self, n: int) -> List[int]:
        if n > len(self._free):
            self.oom_count += 1
            raise PagesExhausted(
                f"need {n} page(s), {len(self._free)} free "
                f"(pool {self.n_pages} × {self.page_size} tokens)")
        taken = [self._free.pop() for _ in range(n)]
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.pages_in_use)
        return taken

    def alloc_slot(self, slot: int, n_tokens: int) -> List[int]:
        """Give an empty slot its initial table: enough pages for
        ``n_tokens`` positions. Returns the physical page ids."""
        table = self._tables[slot]
        if table.pages:
            raise ValueError(f"slot {slot} already holds "
                             f"{len(table.pages)} page(s)")
        need = self.pages_for(n_tokens)
        if need > self.max_pages_per_slot:
            raise PagesExhausted(
                f"{n_tokens} tokens need {need} pages > per-slot cap "
                f"{self.max_pages_per_slot}")
        pages = self._take(need)
        table.pages = pages
        table.tokens = int(n_tokens)
        self.alloc_count += 1
        return list(pages)

    def extend_slot(self, slot: int, n_tokens: int) -> List[int]:
        """Grow ``slot``'s table to cover ``n_tokens`` positions (lazy
        append on decode overflow). Returns the *new* physical page ids
        (possibly empty)."""
        table = self._tables[slot]
        if not table.pages:
            raise ValueError(f"slot {slot} has no table to extend")
        need = self.pages_for(n_tokens)
        if need > self.max_pages_per_slot:
            raise PagesExhausted(
                f"{n_tokens} tokens need {need} pages > per-slot cap "
                f"{self.max_pages_per_slot}")
        grown: List[int] = []
        if need > len(table.pages):
            grown = self._take(need - len(table.pages))
            table.pages.extend(grown)
            self.extend_count += 1
        table.tokens = max(table.tokens, int(n_tokens))
        return grown

    def import_slot(self, slot: int, n_pages: int,
                    n_tokens: int) -> List[int]:
        """Allocate a table for a migrated-in slot: exactly ``n_pages``
        pages holding ``n_tokens`` already-written positions."""
        pages = self.alloc_slot(slot, n_pages * self.page_size)
        self._tables[slot].tokens = int(n_tokens)
        return pages

    def free_slot(self, slot: int) -> List[int]:
        """Return the slot's pages to the free list; the caller must have
        scrubbed (or be about to scrub) their pool rows. Returns the
        released page ids."""
        table = self._tables[slot]
        released = table.pages
        self._tables[slot] = SlotTable()
        self._free.extend(reversed(released))
        if released:
            self.free_count += 1
        return released

    def trim_slot(self, slot: int, n_tokens: int) -> List[int]:
        """Shrink ``slot``'s table back to what ``n_tokens`` committed
        positions need, releasing the surplus tail pages (the inverse of
        :meth:`extend_slot`; speculative decode over-grows for ``k``
        candidate positions and trims to the accepted count after the
        verify pass). The caller must already have scrubbed the released
        rows — the jitted verify step scrubs every rejected write before
        the host sees the accepted count, so the pages re-enter the free
        list clean. Returns the released page ids."""
        table = self._tables[slot]
        keep = max(1, self.pages_for(n_tokens)) if table.pages else 0
        if keep >= len(table.pages):
            table.tokens = min(table.tokens, int(n_tokens))
            return []
        released = table.pages[keep:]
        del table.pages[keep:]
        table.tokens = min(table.tokens, int(n_tokens))
        self._free.extend(reversed(released))
        self.trim_count += 1
        return released

    def note_tokens(self, slot: int, n_tokens: int) -> None:
        """Advance the slot's written-token count (utilization only)."""
        t = self._tables[slot]
        t.tokens = max(t.tokens, int(n_tokens))

    # -- rendering -----------------------------------------------------------
    def page_map(self) -> np.ndarray:
        """Dense ``(n_slots, max_pages_per_slot)`` int32 logical→physical
        table, ``-1`` where unallocated — the device-side operand of the
        paged decode step."""
        out = np.full((self.n_slots, self.max_pages_per_slot), -1, np.int32)
        for i, t in enumerate(self._tables):
            if t.pages:
                out[i, :len(t.pages)] = t.pages
        return out

    # -- stats ---------------------------------------------------------------
    def utilization(self) -> float:
        """Written token positions / allocated token capacity (1.0 = no
        internal fragmentation; 0.0 with nothing allocated)."""
        cap = self.pages_in_use * self.page_size
        if cap == 0:
            return 0.0
        used = sum(min(t.tokens, len(t.pages) * self.page_size)
                   for t in self._tables)
        return used / cap

    def fragmentation(self) -> float:
        """Allocated-but-unwritten fraction (1 - utilization when anything
        is allocated)."""
        return 1.0 - self.utilization() if self.pages_in_use else 0.0

    def stats(self) -> Dict[str, float]:
        occupied = self.occupied_slots()
        return {
            "pages": self.n_pages,
            "page_size": self.page_size,
            "pages_in_use": self.pages_in_use,
            "pages_free": self.free_pages,
            "peak_pages_in_use": self.peak_pages_in_use,
            "occupied_slots": occupied,
            "utilization": round(self.utilization(), 4),
            "fragmentation": round(self.fragmentation(), 4),
            "state_block_tokens": self.state_block_tokens * occupied,
            "allocs": self.alloc_count,
            "extends": self.extend_count,
            "trims": self.trim_count,
            "frees": self.free_count,
            "oom_refusals": self.oom_count,
        }

    def record(self, tracer, *, phase: str, slot: int = -1,
               tenant: str = "", **meta) -> None:
        """Emit one ``paging`` event on ``tracer`` (no-op without one)."""
        if tracer is None:
            return
        tracer.record("paging", tenant=tenant,
                      meta={"phase": phase, "slot": slot,
                            **self.stats(), **meta})


def state_block_tokens(cfg) -> int:
    """Token-position equivalents of one slot's SSM/linear-attention
    state (0 for pure-attention stacks) — the allocator's accounting unit
    for the non-paged half of the cache."""
    if getattr(cfg, "ssm_kind", ""):
        # one state block ≈ d_inner × d_state values ≈ ssm_state "rows"
        return max(1, int(getattr(cfg, "ssm_state", 0)) or 1)
    return 0
