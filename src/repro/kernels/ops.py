"""Jit'd public wrappers around the Pallas kernels.

``interpret`` auto-detects the backend: on CPU (this container) the kernel
body executes through the Pallas interpreter — bit-accurate control flow,
same BlockSpec tiling — while on TPU the same call lowers through Mosaic.
Model code calls these via ``RuntimeCfg.use_pallas``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import fp8 as fp8lib
from repro.kernels import flash_attention as fa
from repro.kernels import fp8_matmul as fm
from repro.kernels import sparse24_matmul as sm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fp8_matmul(x_q: jax.Array, w_q: jax.Array, x_inv_scale=1.0,
               w_inv_scale=1.0, out_dtype=jnp.bfloat16, **blocks) -> jax.Array:
    """Pre-quantized fp8 GEMM with scalar descale."""
    acc = fm.fp8_matmul_pallas(x_q, w_q, interpret=_interpret(), **blocks)
    return (acc * (x_inv_scale * w_inv_scale)).astype(out_dtype)


def fp8_matmul_dynamic(x: jax.Array, w: jax.Array,
                       out_dtype=jnp.bfloat16, **blocks) -> jax.Array:
    """Dynamic per-tensor scaling + Pallas fp8 GEMM. x: (..., K); w: (K, N)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    fmax = fp8lib.E4M3_MAX
    xa = jnp.maximum(jnp.max(jnp.abs(x2.astype(jnp.float32))), 1e-12)
    wa = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32))), 1e-12)
    xs, ws = fmax / xa, fmax / wa
    x_q = (x2.astype(jnp.float32) * xs).astype(fp8lib.E4M3)
    w_q = (w.astype(jnp.float32) * ws).astype(fp8lib.E4M3)
    out = fp8_matmul(x_q, w_q, 1.0 / xs, 1.0 / ws, out_dtype=out_dtype,
                     **blocks)
    return out.reshape(*lead, w.shape[-1])


def sparse24_matmul(x: jax.Array, values: jax.Array, meta: jax.Array,
                    out_dtype=jnp.bfloat16, **blocks) -> jax.Array:
    """Packed 2:4 GEMM. x: (..., K); values (K/2, N); meta (K/8, N)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = sm.sparse24_matmul_pallas(x2, values, meta,
                                    interpret=_interpret(),
                                    out_dtype=out_dtype, **blocks)
    return out.reshape(*lead, values.shape[-1])


def block24_matmul(x: jax.Array, w_packed: jax.Array, kept_idx,
                   block: int = 128, out_dtype=jnp.bfloat16) -> jax.Array:
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = sm.block24_matmul_pallas(x2, w_packed, tuple(kept_idx), block=block,
                                   out_dtype=out_dtype,
                                   interpret=_interpret())
    return out.reshape(*lead, w_packed.shape[-1])


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, **blocks) -> jax.Array:
    """q: (B, S, h, hd) (model layout); k/v: (B, S, kvh, hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = fa.flash_attention_pallas(qt, kt, vt, causal=causal,
                                    interpret=_interpret(), **blocks)
    return out.transpose(0, 2, 1, 3)
