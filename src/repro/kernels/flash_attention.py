"""Pallas TPU kernel: blocked online-softmax (flash) attention.

Used by the transformer-style case study (paper §8.1) as the fused
"increase occupancy via fusion" option the paper recommends (§9.2): QK^T,
softmax and PV stay in VMEM across the KV sweep, so the only HBM traffic is
Q/K/V/O — attention becomes grid-parallel enough to fill cores even at
modest batch (the occupancy lever the paper measures in Fig 2).

Layout: q (B, h, Sq, hd); k/v (B, kvh, Skv, hd) — GQA resolved by the
BlockSpec index map (query head h reads kv head h // group).

grid = (B, h, Sq/bq, Skv/bk), kv innermost; m/l/acc live in VMEM scratch
across the kv sweep. Causal blocks above the diagonal are masked; fully
masked blocks are skipped via ``pl.when`` (no MXU pass issued).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

DEFAULT_BQ = 512
DEFAULT_BK = 512


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  k_steps: int, bq: int, bk: int, scale: float, causal: bool):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip blocks entirely above the diagonal
    run = (j * bk <= i * bq + bq - 1) if causal else (j >= 0)

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            ki = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qi >= ki, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == k_steps - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jax.Array:
    """q: (B, h, Sq, hd); k/v: (B, kvh, Skv, hd) → (B, h, Sq, hd)."""
    B, h, sq, hd = q.shape
    _, kvh, skv, _ = k.shape
    assert h % kvh == 0
    group = h // kvh
    bq, bk = min(bq, sq), min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0
    k_steps = skv // bk
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_flash_kernel, k_steps=k_steps, bq=bq, bk=bk,
                               scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(B, h, sq // bq, k_steps),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, hh, i, j: (b, hh, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, hh, i, j, g=group: (b, hh // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, hh, i, j, g=group: (b, hh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, hh, i, j: (b, hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # m
            pltpu.VMEM((bq,), jnp.float32),        # l
            pltpu.VMEM((bq, hd), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
