"""Pallas TPU kernel: packed 2:4-sparse GEMM (paper §7, TPU-native form).

MI300A's sparse MFMA skips the pruned half of the FLOPs. TPU has no sparse
MXU, so the win is re-derived from the memory hierarchy (DESIGN.md §2): the
weight streams from HBM in *packed* form — values (K/2, N) + 2-bit metadata
(K/8, N) ≈ 0.3125× the bytes of a dense bf16 weight — and is decompressed
**in VMEM by the VPU** while the MXU consumes the previous block (the grid
pipeline double-buffers). FLOPs are unchanged; HBM weight traffic halves+.
That converts directly to speedup exactly where LLM serving is
weight-bandwidth-bound (decode) — the TPU version of the paper's
"context-dependent sparsity benefit".

Decompression per block (pure VPU ops, no gather):
  meta byte -> four 2-bit positions -> one-hot (2, 4) per group -> sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 256
DEFAULT_BK = 256          # K-block of the *dense* K dimension


def _decompress_block(vals, meta, bk: int, bn: int):
    """vals: (bk/2, bn); meta: (bk/8, bn) uint8 -> dense (bk, bn) f32."""
    # unpack 4 × 2-bit positions per byte -> (bk/2, bn) int32 in 0..3
    p0 = (meta & 0x3).astype(jnp.int32)
    p1 = ((meta >> 2) & 0x3).astype(jnp.int32)
    p2 = ((meta >> 4) & 0x3).astype(jnp.int32)
    p3 = ((meta >> 6) & 0x3).astype(jnp.int32)
    # interleave to (bk/2, bn): groups are consecutive pairs
    idx = jnp.stack([p0, p1, p2, p3], axis=1).reshape(bk // 2, bn)
    v = vals.astype(jnp.float32).reshape(bk // 4, 2, bn)
    ix = idx.reshape(bk // 4, 2, bn)
    # scatter two values into their 4-slot group via one-hot compare
    slots = jax.lax.broadcasted_iota(jnp.int32, (bk // 4, 2, 4, bn), 2)
    onehot = (ix[:, :, None, :] == slots).astype(jnp.float32)
    dense = jnp.sum(v[:, :, None, :] * onehot, axis=1)        # (bk/4, 4, bn)
    return dense.reshape(bk, bn)


def _sparse24_kernel(x_ref, v_ref, m_ref, o_ref, acc_ref, *,
                     k_steps: int, bk: int, bn: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_block = _decompress_block(v_ref[...], m_ref[...], bk, bn)  # VPU
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(                          # MXU
        x, w_block, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def sparse24_matmul_pallas(x: jax.Array, values: jax.Array, meta: jax.Array,
                           *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                           bk: int = DEFAULT_BK, out_dtype=jnp.bfloat16,
                           interpret: bool = False) -> jax.Array:
    """x: (M, K); values: (K/2, N); meta: (K/8, N) uint8 → (M, N)."""
    M, K = x.shape
    K2, N = values.shape
    assert K == 2 * K2, (x.shape, values.shape)
    assert meta.shape == (K // 8, N), meta.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    assert bk % 8 == 0
    k_steps = K // bk

    return pl.pallas_call(
        functools.partial(_sparse24_kernel, k_steps=k_steps, bk=bk, bn=bn),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // 8, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, values, meta)


# ---------------------------------------------------------------------------
# Beyond-paper: block-2:4 tile-skipping kernel — real FLOP reduction.
# The kept K-block indices are static (weights are pruned offline), so the
# grid simply iterates the kept half of K; BlockSpec index_map uses a
# compile-time lookup table.
# ---------------------------------------------------------------------------

def block24_matmul_pallas(x: jax.Array, w_packed: jax.Array,
                          kept_idx: tuple, *, block: int = 128,
                          bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                          out_dtype=jnp.bfloat16,
                          interpret: bool = False) -> jax.Array:
    """x: (M, K_dense); w_packed: (K_dense/2, N) — kept K-blocks concatenated.

    ``kept_idx``: static tuple of kept dense-K block indices (len = K/2/block).
    FLOPs: M·N·K/2 — an actual 2× reduction vs dense, unlike element 2:4.
    """
    M, K = x.shape
    Kh, N = w_packed.shape
    assert Kh == K // 2
    assert len(kept_idx) == Kh // block
    bm, bn = min(bm, M), min(bn, N)
    assert M % bm == 0 and N % bn == 0 and Kh % block == 0
    k_steps = Kh // block
    kept = tuple(int(i) for i in kept_idx)

    def kernel(x_ref, w_ref, o_ref, acc_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        @pl.when(pl.program_id(2) == k_steps - 1)
        def _store():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    def x_index(i, j, k):
        # jump to the kept dense-K block (static switch over k)
        kd = jax.lax.switch(k, [lambda v=v: jnp.int32(v) for v in kept])
        return (i, kd)

    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, block), x_index),
            pl.BlockSpec((block, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_packed)
