"""Pallas TPU kernel: paged flash-decode attention (page-table walk).

The paged serving cache (core/paging.py) stores KV in a pool of
fixed-size pages; each decode slot owns a logical→physical page table.
This kernel fuses the whole per-token attention read into one pass — the
§9.2 "increase occupancy via fusion" lever applied to the serving hot
path: one query row per active slot, online softmax over the slot's
pages, and the page table itself *scalar-prefetched* so each page's
physical block index is known before its DMA is issued
(``pltpu.PrefetchScalarGridSpec``). HBM traffic is exactly the pages a
slot actually wrote — never the dense ``max_len`` rectangle.

Layout: q ``(B, h, hd)``; pools ``(P, page_size, kvh, hd)`` (GQA resolved
by the BlockSpec index map, like kernels/flash_attention.py); page table
``(B, max_pages)`` int32 with ``-1`` = unallocated; ``lengths (B,)`` =
written positions per slot (the current token already written).

grid = (B, h, max_pages), pages innermost; m/l/acc live in VMEM scratch
across the page sweep. Unallocated or fully-past-``length`` pages are
skipped via ``pl.when`` (no MXU pass, and their index map clamps to page
0 so no out-of-bounds DMA is formed).

Like every kernel here it runs through the interpreter off-TPU
(``interpret=True``); :func:`paged_attention_reference` is the jnp
oracle the exactness tests compare against. The serving decode step
(models/transformer.py) uses an XLA gather that is *bit-exact* against
the dense path — this kernel is the fused hardware path and matches the
reference within flash-accumulation tolerance.

A ``pallas_paged`` :class:`~repro.kernels.registry.MatmulBackend` is
registered on import (GEMM entries delegate to the ``pallas`` backend) so
``resolve_policy`` can name the paged substrate and telemetry events
carry it; :func:`sweep_paged_tilings` measures the kernel across page
geometries and emits ``pagedsweep/...`` Records for the autotune store.
"""
from __future__ import annotations

import functools
import math
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import registry

NEG_INF = -1e30

# Page geometries the tiling sweep measures: one (1, page_size, hd) tile
# per grid step (one query row, one page of KV depth-``hd``).
SWEEP_PAGE_SIZES = (8, 16, 32)


# ---------------------------------------------------------------------------
# jnp reference (the oracle)
# ---------------------------------------------------------------------------

def paged_attention_reference(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, page_map: jax.Array,
                              lengths: jax.Array) -> jax.Array:
    """Gather-then-attend oracle. q ``(B, h, hd)``; pools
    ``(P, ps, kvh, hd)``; page_map ``(B, mp)``; lengths ``(B,)`` →
    ``(B, h, hd)`` f32."""
    B, h, hd = q.shape
    _, ps, kvh, _ = k_pages.shape
    mp = page_map.shape[1]
    g = h // kvh
    safe = jnp.maximum(page_map, 0)                      # (B, mp)
    k = k_pages[safe].reshape(B, mp * ps, kvh, hd)
    v = v_pages[safe].reshape(B, mp * ps, kvh, hd)
    pos = jnp.arange(mp * ps, dtype=jnp.int32)
    valid = (pos[None, :] < lengths[:, None]) \
        & jnp.repeat(page_map >= 0, ps, axis=1)          # (B, S)
    q4 = q.reshape(B, kvh, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", q4, k.astype(jnp.float32))
    s = s * (hd ** -0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, h, hd)


# ---------------------------------------------------------------------------
# The Pallas kernel
# ---------------------------------------------------------------------------

def _paged_kernel(pm_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  page_size: int, n_steps: int, scale: float):
    b, j = pl.program_id(0), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    phys = pm_ref[b, j]
    # skip pages never allocated or entirely past the written prefix
    run = (phys >= 0) & (j * page_size < length)

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32).reshape(1, -1)   # (1, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (ps, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        s = jnp.where(kpos < length, s, NEG_INF)             # (1, ps)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where((m_new > NEG_INF / 2)[:, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == n_steps - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None])[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_flash_decode_pallas(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, page_map: jax.Array,
                              lengths: jax.Array, *,
                              interpret: bool = False) -> jax.Array:
    """Fused page-walking flash decode. Shapes as in
    :func:`paged_attention_reference`; returns ``(B, h, hd)`` f32."""
    B, h, hd = q.shape
    _, ps, kvh, _ = k_pages.shape
    mp = page_map.shape[1]
    assert h % kvh == 0
    group = h // kvh
    scale = 1.0 / math.sqrt(hd)
    page_map = page_map.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    kernel = functools.partial(_paged_kernel, page_size=ps, n_steps=mp,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, h, mp),
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, hh, j, pm, ln: (b, hh, 0)),
            # physical page index comes from the prefetched table; -1
            # (skipped by pl.when) clamps to page 0 so the index is
            # always in-bounds
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, hh, j, pm, ln, g=group:
                         (jnp.maximum(pm[b, j], 0), 0, hh // g, 0)),
            pl.BlockSpec((1, ps, 1, hd),
                         lambda b, hh, j, pm, ln, g=group:
                         (jnp.maximum(pm[b, j], 0), 0, hh // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd),
                               lambda b, hh, j, pm, ln: (b, hh, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),       # m
            pltpu.VMEM((1,), jnp.float32),       # l
            pltpu.VMEM((1, hd), jnp.float32),    # acc
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, h, hd), jnp.float32),
        interpret=interpret,
    )(page_map, lengths, q, k_pages, v_pages)


def paged_decode_attention(q, k_pages, v_pages, page_map, lengths, *,
                           tracer=None) -> jax.Array:
    """Dispatch wrapper: the fused kernel (interpreted off-TPU) with a
    trace-time telemetry event so the observatory sees the paged
    substrate like any other backend's op."""
    tr = tracer
    if tr is None:
        from repro.runtime import telemetry
        tr = telemetry.get_tracer()
    B, h, hd = q.shape
    ps = k_pages.shape[1]
    if tr is not None:
        tr.record("paged_attn", m=B, k=hd, n=ps * page_map.shape[1],
                  backend="pallas_paged",
                  meta={"page_size": ps, "pages": int(k_pages.shape[0])})
    return paged_flash_decode_pallas(
        q, k_pages, v_pages, page_map, lengths,
        interpret=registry.interpret_mode())


# ---------------------------------------------------------------------------
# Backend registration — the paged substrate is nameable/observable
# ---------------------------------------------------------------------------

_pallas = registry.get_backend("pallas")
registry.register_backend(registry.MatmulBackend(
    name="pallas_paged",
    dense=_pallas.dense,
    fp8=_pallas.fp8,
    fp8_qdot=_pallas.fp8_qdot,
    sparse24=_pallas.sparse24,
    description="pallas GEMMs + fused page-walking flash decode "
                "(kernels/paged_attention.py)",
))


# ---------------------------------------------------------------------------
# Tiling sweep → autotune evidence
# ---------------------------------------------------------------------------

def _mk_pool(key, n_pages, ps, kvh, hd, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    shape = (n_pages, ps, kvh, hd)
    return (jax.random.normal(k1, shape, dtype),
            jax.random.normal(k2, shape, dtype))


def sweep_paged_tilings(batch: int = 4, kv_heads: int = 2, heads: int = 4,
                        head_dim: int = 16, seq: int = 64,
                        page_sizes: Optional[List[int]] = None,
                        iters: int = 3, record_cache: bool = True):
    """Measure the fused kernel across page geometries and return
    ``Record``s named ``pagedsweep/bf16/{B}x{S}x{hd}/{1}x{ps}x{hd}`` —
    the measured tile is one query row × one (ps, hd) page block. The
    records flow into the block-shape evidence store via
    ``autotune.AutotuneStore.add_records`` (same path as the Table-3
    blocksweep) and, with ``record_cache``, straight into the global
    ``execution.BLOCK_CACHE``."""
    from repro.core import execution as ex
    from repro.core.characterization import Record

    out = []
    key = jax.random.PRNGKey(0)
    for ps in (page_sizes or list(SWEEP_PAGE_SIZES)):
        if seq % ps:
            continue
        mp = seq // ps
        n_pages = batch * mp + 1
        kq, kp = jax.random.split(jax.random.fold_in(key, ps))
        q = jax.random.normal(kq, (batch, heads, head_dim), jnp.bfloat16)
        k_pages, v_pages = _mk_pool(kp, n_pages, ps, kv_heads, head_dim)
        page_map = jnp.arange(batch * mp, dtype=jnp.int32) \
            .reshape(batch, mp)
        lengths = jnp.full((batch,), seq, jnp.int32)
        fn = lambda: paged_flash_decode_pallas(  # noqa: E731
            q, k_pages, v_pages, page_map, lengths,
            interpret=registry.interpret_mode())
        jax.block_until_ready(fn())              # compile/warm
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn())
        secs = (time.perf_counter() - t0) / iters
        name = (f"pagedsweep/bf16/{batch}x{seq}x{head_dim}/"
                f"1x{ps}x{head_dim}")
        out.append(Record(
            name=name, us_per_call=secs * 1e6,
            derived={"page_size": ps, "pages": batch * mp,
                     "m": batch, "n": seq, "k": head_dim,
                     "kernel": "paged_flash_decode"}))
        if record_cache:
            ex.BLOCK_CACHE.record(batch, head_dim, seq, "bf16",
                                  (1, ps, head_dim), secs)
    return out
