"""Matmul backend registry — the one seam every GEMM in the system crosses.

The paper's central finding is that FP8, concurrency, and 2:4 sparsity pay
off only *context-dependently* (occupancy §5, fairness §6, break-even §7).
Instead of hard-wiring each technique at call sites, every matmul consumer
routes through a named :class:`MatmulBackend`, selected by an
``ExecutionPolicy`` (core/execution.py). Each backend exposes four entry
points with identical signatures:

  ``dense(x, w)``                   — bf16/f32 GEMM, f32 accumulation
  ``fp8(x, w)``                     — dynamic per-tensor-scaled FP8 GEMM
  ``fp8_qdot(x_q, w_q, xs, ws)``    — pre-quantized FP8 GEMM + descale
                                      (the delayed-scaling training hook)
  ``sparse24(x, values, meta)``     — packed 2:4 GEMM

Registered backends:

  ``ref``             pure-f32 oracles (numerics ground truth)
  ``jnp``             XLA ``dot_general`` paths (CPU/TPU default)
  ``pallas``          Pallas TPU kernels; on CPU the same BlockSpec tiling
                      executes through the interpreter (``interpret=True``),
                      and shapes that cannot tile fall back to ``jnp``
  ``pallas_sparse24`` Pallas with the packed-2:4 kernel as the *primary*
                      path: its ``dense`` entry prunes + packs the weight
                      on the fly (serving-style, no STE)

``x`` may carry leading batch dims; they are flattened into M. ``bm/bn/bk``
override the block shapes (``None`` → kernel defaults / autotune cache).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import concurrency as cc
from repro.core import fp8 as fp8lib
from repro.core import sparsity as sp
from repro.kernels import fp8_matmul as fm
from repro.kernels import sparse24_matmul as sm

# The four matmul flavors every backend provides — also the valid ``kind``
# values for the async :meth:`MatmulBackend.dispatch` entry point.
KINDS = ("dense", "fp8", "fp8_qdot", "sparse24")


@dataclasses.dataclass(frozen=True)
class MatmulBackend:
    """One named execution substrate for the four matmul flavors."""
    name: str
    dense: Callable
    fp8: Callable
    fp8_qdot: Callable
    sparse24: Callable
    description: str = ""

    def entry(self, kind: str) -> Callable:
        if kind not in KINDS:
            raise KeyError(
                f"unknown matmul kind {kind!r}; one of {', '.join(KINDS)}")
        return getattr(self, kind)

    def dispatch(self, kind: str, *operands, lane=None, overlap_group=-1,
                 **kw) -> "cc.LaneHandle":
        """Async entry point: enqueue ``kind`` through JAX's dispatch queue
        and return a joinable :class:`~repro.core.concurrency.LaneHandle`
        (``join()`` → ``jax.block_until_ready`` on the result). Available
        on every backend — off-TPU the pallas entries already run through
        the interpret fallback, so dispatch-and-join works on CPU CI too.

        ``lane`` threads the call onto a caller-owned
        :class:`~repro.core.concurrency.ExecutionLane` (so its tracer and
        bookkeeping see the op); without one, a throwaway lane named after
        the backend is used."""
        fn = self.entry(kind)
        if lane is None:
            lane = cc.ExecutionLane(f"{self.name}:{kind}")
        return lane.dispatch(functools.partial(fn, *operands, **kw),
                             label=f"{self.name}.{kind}",
                             overlap_group=overlap_group)


_REGISTRY: Dict[str, MatmulBackend] = {}


def register_backend(backend: MatmulBackend) -> MatmulBackend:
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> MatmulBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown matmul backend {name!r}; available: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def interpret_mode() -> bool:
    """Pallas interpret fallback: everywhere except a real TPU."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Shape plumbing
# ---------------------------------------------------------------------------

def _flatten_lead(x: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _fit(dim: int, pref: Optional[int], default: int) -> int:
    """Largest block <= pref(/default) that divides ``dim``."""
    b = min(pref or default, dim)
    if dim % b:
        b = math.gcd(dim, b)
    return max(b, 1)


def _tileable(*blocks: int) -> bool:
    """Reject sub-MXU-lane tiles — interpret grids explode and Mosaic won't
    lower them; the caller falls back to the jnp path instead."""
    return all(b % 8 == 0 for b in blocks)


# ---------------------------------------------------------------------------
# ref — exact-f32 oracles
# ---------------------------------------------------------------------------

def _f32_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a.astype(jnp.float32), b.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def _ref_dense(x, w, *, out_dtype=jnp.bfloat16, bm=None, bn=None, bk=None):
    x2, lead = _flatten_lead(x)
    return _f32_dot(x2, w).astype(out_dtype).reshape(*lead, w.shape[-1])


def _ref_fp8(x, w, *, out_dtype=jnp.bfloat16, bm=None, bn=None, bk=None):
    x2, lead = _flatten_lead(x)
    xq, xinv = fp8lib.quantize_weight_static(x2)
    wq, winv = fp8lib.quantize_weight_static(w)
    out = _f32_dot(xq, wq) * (xinv * winv)
    return out.astype(out_dtype).reshape(*lead, w.shape[-1])


def _ref_fp8_qdot(x_q, w_q, x_inv_scale=1.0, w_inv_scale=1.0, *,
                  out_dtype=jnp.float32, bm=None, bn=None, bk=None):
    x2, lead = _flatten_lead(x_q)
    out = _f32_dot(x2, w_q) * (x_inv_scale * w_inv_scale)
    return out.astype(out_dtype).reshape(*lead, w_q.shape[-1])


def _ref_sparse24(x, values, meta, *, out_dtype=jnp.bfloat16,
                  bm=None, bn=None, bk=None):
    return sp.sparse24_matmul_ref(x, values, meta, out_dtype=out_dtype)


register_backend(MatmulBackend(
    name="ref",
    dense=_ref_dense,
    fp8=_ref_fp8,
    fp8_qdot=_ref_fp8_qdot,
    sparse24=_ref_sparse24,
    description="pure-f32 jnp oracles (ground truth for allclose tests)",
))


# ---------------------------------------------------------------------------
# jnp — XLA dot_general (native operand dtypes, f32 accumulation)
# ---------------------------------------------------------------------------

def _jnp_dense(x, w, *, out_dtype=jnp.bfloat16, bm=None, bn=None, bk=None):
    acc = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return acc.astype(out_dtype)


def _jnp_fp8(x, w, *, out_dtype=jnp.bfloat16, bm=None, bn=None, bk=None):
    return fp8lib.dynamic_fp8_matmul(x, w, out_dtype=out_dtype)


def _jnp_fp8_qdot(x_q, w_q, x_inv_scale=1.0, w_inv_scale=1.0, *,
                  out_dtype=jnp.float32, bm=None, bn=None, bk=None):
    return fp8lib.fp8_dot(x_q, w_q, x_inv_scale, w_inv_scale,
                          out_dtype=out_dtype)


register_backend(MatmulBackend(
    name="jnp",
    dense=_jnp_dense,
    fp8=_jnp_fp8,
    fp8_qdot=_jnp_fp8_qdot,
    sparse24=_ref_sparse24,
    description="XLA dot_general paths (the CPU/TPU non-kernel default)",
))


# ---------------------------------------------------------------------------
# pallas — blocked TPU kernels (interpreter on CPU), jnp shape fallback.
#
# ``pallas_call`` has no AD rule, so each entry is wrapped in a custom_vjp:
# the Pallas kernel computes the forward product, and the backward pass
# differentiates the numerically-equivalent jnp reference. That keeps
# ``--backend pallas`` usable under jax.grad (training) with gradients
# identical to the jnp backend's.
# ---------------------------------------------------------------------------

def _pallas_blocks(M: int, K: int, N: int, bm, bn, bk,
                   dbm: int, dbn: int, dbk: int) -> Tuple[int, int, int]:
    return (_fit(M, bm, dbm), _fit(N, bn, dbn), _fit(K, bk, dbk))


def _fwd_with_ref_grad(pallas_fn: Callable, ref_fn: Callable, *operands):
    """Run ``pallas_fn`` forward; differentiate through ``ref_fn``."""

    @jax.custom_vjp
    def f(*args):
        return pallas_fn(*args)

    def fwd(*args):
        return pallas_fn(*args), args

    def bwd(res, g):
        _, vjp = jax.vjp(ref_fn, *res)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f(*operands)


def _pallas_dense(x, w, *, out_dtype=jnp.bfloat16, bm=None, bn=None, bk=None):
    x2, lead = _flatten_lead(x)
    (M, K), N = x2.shape, w.shape[-1]
    fbm, fbn, fbk = _pallas_blocks(M, K, N, bm, bn, bk,
                                   fm.DEFAULT_BM, fm.DEFAULT_BN, fm.DEFAULT_BK)
    if not _tileable(fbm, fbn, fbk):
        return _jnp_dense(x, w, out_dtype=out_dtype)

    def kernel(x2, w):
        acc = fm.fp8_matmul_pallas(x2, w, bm=fbm, bn=fbn, bk=fbk,
                                   interpret=interpret_mode())
        return acc.astype(out_dtype)

    out = _fwd_with_ref_grad(
        kernel, lambda a, b: _jnp_dense(a, b, out_dtype=out_dtype), x2, w)
    return out.reshape(*lead, N)


def _pallas_fp8(x, w, *, out_dtype=jnp.bfloat16, bm=None, bn=None, bk=None):
    x2, lead = _flatten_lead(x)
    (M, K), N = x2.shape, w.shape[-1]
    fbm, fbn, fbk = _pallas_blocks(M, K, N, bm, bn, bk,
                                   fm.DEFAULT_BM, fm.DEFAULT_BN, fm.DEFAULT_BK)
    if not _tileable(fbm, fbn, fbk):
        return _jnp_fp8(x, w, out_dtype=out_dtype)

    def kernel(x2, w):
        xq, xinv = fp8lib.quantize_weight_static(x2)
        wq, winv = fp8lib.quantize_weight_static(w)
        acc = fm.fp8_matmul_pallas(xq, wq, bm=fbm, bn=fbn, bk=fbk,
                                   interpret=interpret_mode())
        return (acc * (xinv * winv)).astype(out_dtype)

    out = _fwd_with_ref_grad(
        kernel, lambda a, b: _jnp_fp8(a, b, out_dtype=out_dtype), x2, w)
    return out.reshape(*lead, N)


def _pallas_fp8_qdot(x_q, w_q, x_inv_scale=1.0, w_inv_scale=1.0, *,
                     out_dtype=jnp.float32, bm=None, bn=None, bk=None):
    x2, lead = _flatten_lead(x_q)
    (M, K), N = x2.shape, w_q.shape[-1]
    fbm, fbn, fbk = _pallas_blocks(M, K, N, bm, bn, bk,
                                   fm.DEFAULT_BM, fm.DEFAULT_BN, fm.DEFAULT_BK)
    if not _tileable(fbm, fbn, fbk):
        return _jnp_fp8_qdot(x_q, w_q, x_inv_scale, w_inv_scale,
                             out_dtype=out_dtype)
    acc = fm.fp8_matmul_pallas(x2, w_q, bm=fbm, bn=fbn, bk=fbk,
                               interpret=interpret_mode())
    return (acc * (x_inv_scale * w_inv_scale)) \
        .astype(out_dtype).reshape(*lead, N)


def _pallas_sparse24(x, values, meta, *, out_dtype=jnp.bfloat16,
                     bm=None, bn=None, bk=None):
    x2, lead = _flatten_lead(x)
    (M, K), N = x2.shape, values.shape[-1]
    fbm, fbn, fbk = _pallas_blocks(M, K, N, bm, bn, bk,
                                   sm.DEFAULT_BM, sm.DEFAULT_BN, sm.DEFAULT_BK)
    if not _tileable(fbm, fbn, fbk) or fbk % 8:
        return _ref_sparse24(x, values, meta, out_dtype=out_dtype)

    def kernel(x2, values, meta):
        return sm.sparse24_matmul_pallas(x2, values, meta,
                                         bm=fbm, bn=fbn, bk=fbk,
                                         out_dtype=out_dtype,
                                         interpret=interpret_mode())

    out = _fwd_with_ref_grad(
        kernel,
        lambda a, v, m: _ref_sparse24(a, v, m, out_dtype=out_dtype),
        x2, values, meta)
    return out.reshape(*lead, N)


register_backend(MatmulBackend(
    name="pallas",
    dense=_pallas_dense,
    fp8=_pallas_fp8,
    fp8_qdot=_pallas_fp8_qdot,
    sparse24=_pallas_sparse24,
    description="blocked Pallas TPU kernels (interpret fallback on CPU)",
))


# ---------------------------------------------------------------------------
# pallas_sparse24 — packed-2:4 as the primary path: dense weights are
# pruned + packed inside the traced computation (serving-style, no STE), so
# a single policy switch measures the paper's §7 bandwidth trade on any
# workload. NOTE: the prune+pack re-executes per call — right for one-shot
# backend sweeps; steady-state serving should pre-pack once via
# ``execution.pack_weight`` and hand ``PackedWeight``s to the model, which
# routes straight to the packed kernel.
# ---------------------------------------------------------------------------

def _sparse24_primary_dense(x, w, *, out_dtype=jnp.bfloat16,
                            bm=None, bn=None, bk=None):
    if w.ndim != 2 or w.shape[0] % 8:
        return _pallas_dense(x, w, out_dtype=out_dtype, bm=bm, bn=bn, bk=bk)
    values, meta = sp.pack_24(sp.prune_24(w))
    return _pallas_sparse24(x, values, meta, out_dtype=out_dtype,
                            bm=bm, bn=bn, bk=bk)


register_backend(MatmulBackend(
    name="pallas_sparse24",
    dense=_sparse24_primary_dense,
    fp8=_pallas_fp8,
    fp8_qdot=_pallas_fp8_qdot,
    sparse24=_pallas_sparse24,
    description="Pallas with on-the-fly 2:4 prune+pack for dense weights",
))
