"""Pallas TPU kernel: blocked FP8×FP8→FP32 GEMM (paper §5 on the MXU).

MI300A's ``V_MFMA_F32_16x16x32_FP8_FP8`` operates on wavefront-level
16×16×32 tiles; the TPU analogue is a 128×128 MXU pass over VMEM-resident
blocks. The kernel is a canonical three-level blocked matmul:

  grid = (M/bm, N/bn, K/bk)   — K innermost so the f32 accumulator stays
                                 in a VMEM scratch across K steps
  BlockSpecs map (i, j, k) to (bm, bk) / (bk, bn) / (bm, bn) tiles.

Block shapes default to (256, 512, 256) — multiples of the 128-wide MXU
systolic dims; the paper's Table-3 "tile-shape latency" experiment becomes a
block-shape sweep over this kernel (benchmarks/table3_tile_latency.py).

VMEM budget at defaults: x 256·512 (fp8) + w 512·256 (fp8) + acc 256·256·4
≈ 0.5 MiB — deep double-buffering headroom within ~16 MiB/core VMEM.

Per-tensor scales multiply the f32 accumulator *outside* the kernel (they
are scalars; fusing them in would force SMEM plumbing for no bandwidth win).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _fp8_matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU pass: fp8 operands, f32 accumulation. On v5e the MXU upconverts;
    # on v6e+ this is a native FP8 pass — the contract is identical.
    x = x_ref[...]
    w = w_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def fp8_matmul_pallas(x_q: jax.Array, w_q: jax.Array, *,
                      bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                      bk: int = DEFAULT_BK, out_dtype=jnp.float32,
                      interpret: bool = False) -> jax.Array:
    """x_q: (M, K) fp8; w_q: (K, N) fp8 → (M, N) f32 (undescaled)."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2, (x_q.shape, w_q.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    k_steps = K // bk

    return pl.pallas_call(
        functools.partial(_fp8_matmul_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x_q, w_q)
