"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import sparsity as sp


def fp8_matmul_ref(x_q: jax.Array, w_q: jax.Array,
                   out_dtype=jnp.float32) -> jax.Array:
    """fp8 (M,K) × fp8 (K,N) → f32, exact f32 accumulation."""
    return jax.lax.dot_general(
        x_q.astype(jnp.float32), w_q.astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_dtype)


def sparse24_matmul_ref(x: jax.Array, values: jax.Array, meta: jax.Array,
                        out_dtype=jnp.bfloat16) -> jax.Array:
    return sp.sparse24_matmul_ref(x, values, meta, out_dtype=out_dtype)


def block24_matmul_ref(x: jax.Array, w_packed: jax.Array, kept_idx,
                       block: int = 128, out_dtype=jnp.bfloat16) -> jax.Array:
    """x (M, K_dense) × packed (K_dense/2, N), kept dense-K block list."""
    M, K = x.shape
    cols = jnp.concatenate([
        jnp.arange(i * block, (i + 1) * block) for i in kept_idx])
    xk = jnp.take(x, cols, axis=1).astype(jnp.float32)
    return (xk @ w_packed.astype(jnp.float32)).astype(out_dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """Naive full-softmax attention. q: (B,h,Sq,hd); k/v: (B,kvh,Skv,hd)."""
    B, h, sq, hd = q.shape
    _, kvh, skv, _ = k.shape
    if kvh != h:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
