"""RWKV-6 (Finch) block — data-dependent decay linear attention.

Per head (hd = head dim), per token t:
  S_t = diag(w_t) S_{t-1} + k_tᵀ v_t           (state S: (hd_k, hd_v))
  y_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)

with data-dependent decay w_t = exp(-exp(ŵ_t)). Training uses a chunked
formulation (quadratic within chunk + state across chunks) mirroring the
reference CUDA kernel; decode is the O(1) recurrence.

Sharding note (DESIGN.md §3.1): the recurrence is elementwise in the value
feature dim, so the state/values shard on ``model`` along hd_v with zero
per-step communication — 40 heads not dividing 16 is irrelevant.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import (
    RuntimeCfg, DEFAULT_RT, dense, opt_barrier, shard_tag, _init)


def _token_shift(x: jax.Array, prev: jax.Array = None) -> jax.Array:
    """x_{t-1} stream; ``prev`` is (B, 1, d) carry for decode."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return prev


def _wkv_chunk(r, k, v, w, u, S):
    """One chunk of the wkv recurrence.

    r,k,v,w: (b, Lc, nh, hd) — w is the per-step decay in (0,1].
    u: (nh, hd) bonus. S: (b, nh, hd, hd) state (k-major, v-minor).
    Returns (y (b, Lc, nh, hd), S_next).
    """
    b, Lc, nh, hd = r.shape
    logw = jnp.log(jnp.maximum(w, 1e-30))                   # (b,Lc,nh,hd)
    cum = jnp.cumsum(logw, axis=1)                          # decay start..t (incl t)
    # inter-chunk: y_inter[t] = r_t · (decay(start..t-1) ⊙ S)
    #   decay through steps 1..t-1 applied to S: exp(cum[t-1]); at t=0 -> I.
    cum_prev = jnp.concatenate(
        [jnp.zeros_like(cum[:, :1]), cum[:, :-1]], axis=1)  # (b,Lc,nh,hd)
    r_dec = r * jnp.exp(cum_prev)                            # exponent <= 0: safe
    y_inter = jnp.einsum("blhi,bhij->blhj", r_dec, S)
    # intra-chunk: y_intra[t] = sum_{s<t} (r_t ⊙ exp(cum[t-1]-cum[s])) k_s v_s
    #            + (r_t ⊙ u) k_t v_t
    # A[t,s] = sum_i r_t,i k_s,i exp(cum_prev[t]-cum[s])_i  for s < t.
    # Computed with the *pairwise* exponent (always <= 0 on causal pairs) —
    # a factorized exp(cum_prev[t])·exp(-cum[s]) overflows f32 under strong
    # decay, the pairwise difference cannot.
    seg = cum_prev[:, :, None] - cum[:, None, :]             # (b,t,s,nh,hd)
    causal_strict = jnp.tril(jnp.ones((Lc, Lc), bool), k=-1)
    decay = jnp.where(causal_strict[None, :, :, None, None], jnp.exp(seg), 0.0)
    A = jnp.einsum("blhi,bmhi,blmhi->blmh", r, k, decay)     # (b,t,s,nh)
    y_intra = jnp.einsum("blmh,bmhj->blhj", A, v)
    diag = jnp.einsum("blhi,blhi->blh", r * u[None, None], k)
    y_intra = y_intra + diag[..., None] * v
    # state: S_next = diag(decay whole chunk) S + sum_s diag(decay s+1..end) k_s v_s
    total = cum[:, -1:]                                      # (b,1,nh,hd)
    k_tail = k * jnp.exp(total - cum)
    S_next = (S * jnp.exp(total)[:, 0, :, :, None]
              + jnp.einsum("blhi,blhj->bhij", k_tail, v))
    return y_intra + y_inter, S_next


def rwkv6_block(x: jax.Array, p: Dict[str, jax.Array], cfg: ArchConfig,
                rt: RuntimeCfg = DEFAULT_RT) -> jax.Array:
    """Time-mix (wkv) sub-block. x: (B, S, d) -> (B, S, d)."""
    out, _ = _rwkv6_block_impl(x, p, cfg, rt)
    return out


def rwkv6_block_with_state(x: jax.Array, p: Dict[str, jax.Array],
                           cfg: ArchConfig, rt: RuntimeCfg = DEFAULT_RT):
    """Prefill variant: returns (out, (S_final, prev_tm))."""
    return _rwkv6_block_impl(x, p, cfg, rt)


def _rwkv6_block_impl(x: jax.Array, p: Dict[str, jax.Array], cfg: ArchConfig,
                      rt: RuntimeCfg = DEFAULT_RT):
    b, s, d = x.shape
    hd = cfg.ssm_head_dim
    nh = d // hd

    xs = _token_shift(x)
    def mix(name):
        return x + (xs - x) * p[f"mu_{name}"].astype(x.dtype)
    r = dense(mix("r"), p["w_r"], cfg, rt, "rwkv_r").reshape(b, s, nh, hd)
    k = dense(mix("k"), p["w_k"], cfg, rt, "rwkv_k").reshape(b, s, nh, hd)
    v = dense(mix("v"), p["w_v"], cfg, rt, "rwkv_v").reshape(b, s, nh, hd)
    v = shard_tag(rt, v, "rwkv_v")           # value-dim sharding: comm-free wkv
    g = dense(mix("g"), p["w_g"], cfg, rt, "rwkv_g")
    wlog = dense(mix("w"), p["w_w"], cfg, rt, "rwkv_w").reshape(b, s, nh, hd)
    w = jnp.exp(-jnp.exp(wlog.astype(jnp.float32) + p["w_bias"]
                         .reshape(nh, hd)))                   # (0,1)

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    u = p["u"].reshape(nh, hd).astype(jnp.float32)

    Lc = min(rt.ssm_chunk, cfg.ssm_chunk, s)
    assert s % Lc == 0, (s, Lc)
    nchunks = s // Lc
    S = jnp.zeros((b, nh, hd, hd), jnp.float32)
    if rt.static_loops and nchunks <= rt.max_static_chunks:
        ys = []
        for i in range(nchunks):
            sl = slice(i * Lc, (i + 1) * Lc)
            ri, ki, vi, wi = r32[:, sl], k32[:, sl], v32[:, sl], w[:, sl]
            if i:
                # bound liveness: sequence chunk temporaries behind the
                # state carry (see attention.py for rationale)
                ri, ki, vi, wi, S = opt_barrier(
                    (ri, ki, vi, wi, S))
            yi, S = _wkv_chunk(ri, ki, vi, wi, u, S)
            ys.append(yi)
        y = jnp.concatenate(ys, axis=1)
    else:
        def body(S, args):
            ri, ki, vi, wi = args
            yi, S = _wkv_chunk(ri, ki, vi, wi, u, S)
            return S, yi
        # remat: the pairwise-decay temp is O(Lc^2·d) per chunk — recompute
        # it in backward instead of letting scan save one per chunk
        body = jax.checkpoint(body)
        split = lambda t: t.reshape(b, nchunks, Lc, nh, hd).transpose(1, 0, 2, 3, 4)
        S, ys = jax.lax.scan(body, S, (split(r32), split(k32), split(v32), split(w)))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, hd)

    y = y.reshape(b, s, d)
    # group-norm per head then output gate (SiLU(g))
    yh = y.reshape(b, s, nh, hd)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 64e-5)
    y = (yh.reshape(b, s, d) * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    out = dense(y, p["w_o"], cfg, rt, "rwkv_o")
    return out, (S, x[:, -1:, :])


def rwkv6_channel_mix(x: jax.Array, p: Dict[str, jax.Array], cfg: ArchConfig,
                      rt: RuntimeCfg = DEFAULT_RT) -> jax.Array:
    xs = _token_shift(x)
    xk = x + (xs - x) * p["mu_ck"].astype(x.dtype)
    xr = x + (xs - x) * p["mu_cr"].astype(x.dtype)
    rgate = jax.nn.sigmoid(
        dense(xr, p["w_cr"], cfg, rt, "rwkv_cr").astype(jnp.float32))
    h = dense(xk, p["w_ck"], cfg, rt, "rwkv_ck")
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    return (rgate * dense(h, p["w_cv"], cfg, rt, "rwkv_cv")
            .astype(jnp.float32)).astype(x.dtype)


def rwkv6_channel_mix_decode(x: jax.Array, p: Dict[str, jax.Array],
                             cfg: ArchConfig, prev: jax.Array,
                             rt: RuntimeCfg = DEFAULT_RT):
    """One-token channel-mix; ``prev`` is the previous token's input (B,1,d).
    Returns (out, new_prev)."""
    xs = _token_shift(x, prev)
    xk = x + (xs - x) * p["mu_ck"].astype(x.dtype)
    xr = x + (xs - x) * p["mu_cr"].astype(x.dtype)
    rgate = jax.nn.sigmoid(
        dense(xr, p["w_cr"], cfg, rt, "rwkv_cr").astype(jnp.float32))
    h = dense(xk, p["w_ck"], cfg, rt, "rwkv_ck")
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    out = (rgate * dense(h, p["w_cv"], cfg, rt, "rwkv_cv")
           .astype(jnp.float32)).astype(x.dtype)
    return out, x


def rwkv6_decode(x: jax.Array, p: Dict[str, jax.Array], cfg: ArchConfig,
                 state, rt: RuntimeCfg = DEFAULT_RT):
    """One-token time-mix. state = (S (B,nh,hd,hd) f32, prev_x (B,1,d),
    prev_x_cm (B,1,d)). Returns (out_timemix_only, new_state) — channel-mix
    handled by the caller with prev_x_cm."""
    b, _, d = x.shape
    hd = cfg.ssm_head_dim
    nh = d // hd
    S, prev_x = state

    xs = _token_shift(x, prev_x)
    def mix(name):
        return x + (xs - x) * p[f"mu_{name}"].astype(x.dtype)
    r = dense(mix("r"), p["w_r"], cfg, rt, "rwkv_r").reshape(b, nh, hd)
    k = dense(mix("k"), p["w_k"], cfg, rt, "rwkv_k").reshape(b, nh, hd)
    v = dense(mix("v"), p["w_v"], cfg, rt, "rwkv_v").reshape(b, nh, hd)
    g = dense(mix("g"), p["w_g"], cfg, rt, "rwkv_g")
    wlog = dense(mix("w"), p["w_w"], cfg, rt, "rwkv_w").reshape(b, nh, hd)
    w = jnp.exp(-jnp.exp(wlog.astype(jnp.float32) + p["w_bias"].reshape(nh, hd)))
    u = p["u"].reshape(nh, hd).astype(jnp.float32)

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    kv = jnp.einsum("bhi,bhj->bhij", k32, v32)
    y = jnp.einsum("bhi,bhij->bhj", r32, S + u[None, :, :, None] * kv)
    S = S * w[:, :, :, None] + kv

    yh = y.reshape(b, nh, hd)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 64e-5)
    y = (yh.reshape(b, 1, d) * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    out = dense(y, p["w_o"], cfg, rt, "rwkv_o")
    return out, (S, x)


def init_rwkv6(key, cfg: ArchConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.ssm_head_dim
    nh = d // hd
    ks = jax.random.split(key, 10)
    p = {
        "w_r": _init(ks[0], (d, d), dtype),
        "w_k": _init(ks[1], (d, d), dtype),
        "w_v": _init(ks[2], (d, d), dtype),
        "w_g": _init(ks[3], (d, d), dtype),
        "w_w": _init(ks[4], (d, d), dtype, scale=0.01),
        "w_o": _init(ks[5], (d, d), dtype),
        "w_bias": jnp.full((nh * hd,), -0.6, jnp.float32),
        "u": jnp.zeros((nh * hd,), jnp.float32),
        "w_cr": _init(ks[6], (d, d), dtype),
        "w_ck": _init(ks[7], (d, f), dtype),
        "w_cv": _init(ks[8], (f, d), dtype),
    }
    for name in ("r", "k", "v", "g", "w"):
        p[f"mu_{name}"] = jnp.full((d,), 0.5, jnp.float32)
    p["mu_ck"] = jnp.full((d,), 0.5, jnp.float32)
    p["mu_cr"] = jnp.full((d,), 0.5, jnp.float32)
    return p
